//! Quickstart: load the AOT artifacts, build an engine, generate one
//! completion, and print serving metrics. (Also used as a staged smoke
//! probe of each runtime layer.)

use anyhow::Result;
use lazyeviction::bench_harness::artifacts_dir;
use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::runtime::{Client, Manifest};

fn main() -> Result<()> {
    let manifest = Manifest::load(artifacts_dir())?;
    eprintln!("[1] manifest: {} variants", manifest.variants.len());
    let client = Client::cpu()?;
    eprintln!("[2] pjrt client: {}", client.platform());

    let cfg = EngineConfig {
        batch: 1,
        cache: 256,
        budget: 192,
        policy: "lazy".into(),
        ..Default::default()
    };
    let mut engine = Engine::new(&client, &manifest, cfg)?;
    eprintln!("[3] engine ready (policy={})", engine.policy_name());

    let responses = engine.run_all(vec![Request {
        id: 1,
        prompt: "#A=3;B=7;C=2;\n>".into(),
        template: "A=?;B=?;A+B=?;\n".into(),
        max_new: 64,
        resume: None,
    }])?;
    eprintln!("[4] generation done");
    for r in &responses {
        println!("output: {:?}", r.text);
        println!("holes : {:?}", r.hole_predictions);
        println!(
            "timing: ttft {:.1} ms, total {:.1} ms, {} tokens, {} evictions",
            r.metrics.ttft_s * 1e3,
            r.metrics.total_s * 1e3,
            r.metrics.tokens_out,
            r.metrics.evictions
        );
    }
    println!(
        "engine: mean step {:.2} ms, throughput {:.1} tok/s",
        engine.metrics.step_summary_ms().mean,
        engine.metrics.throughput()
    );
    Ok(())
}
