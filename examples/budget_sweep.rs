//! Budget sweep on the REAL served model: measure answer accuracy vs KV
//! budget for several policies (the engine-tier miniature of Fig. 5).
//!
//!   cargo run --release --example budget_sweep -- [--samples 12]

use anyhow::Result;
use lazyeviction::bench_harness::artifacts_dir;
use lazyeviction::bench_harness::table::Table;
use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::runtime::{Client, Manifest};
use lazyeviction::trace::workload::{gen_reasoning_sample, score_sample};
use lazyeviction::util::cli::Args;
use lazyeviction::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("samples", 12);
    let manifest = Manifest::load(artifacts_dir())?;
    let client = Client::cpu()?;

    // long reasoning chains so the budget actually binds
    let mut rng = Rng::new(7);
    let samples: Vec<_> = (0..n).map(|_| gen_reasoning_sample(&mut rng, 6, 28)).collect();

    let budgets = [64usize, 96, 128, 192];
    println!("\nbudget sweep — real engine, {n} samples, ~{} forced tokens each",
             samples[0].template.len());
    let mut header = vec!["Policy".to_string()];
    header.extend(budgets.iter().map(|b| format!("B={b}")));
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hrefs);

    for policy in ["full", "tova", "h2o", "raas", "lazy"] {
        let mut row = vec![policy.to_string()];
        for &budget in &budgets {
            if policy == "full" && budget != budgets[budgets.len() - 1] {
                row.push("-".into());
                continue;
            }
            let mut cfg = EngineConfig {
                batch: 4,
                cache: 256,
                budget: if policy == "full" { 256 } else { budget },
                policy: policy.into(),
                record_live: false,
                ..Default::default()
            };
            cfg.params.window = 12;
            cfg.params.recent = 12;
            let mut engine = Engine::new(&client, &manifest, cfg)?;
            let reqs: Vec<Request> = samples
                .iter()
                .enumerate()
                .map(|(i, s)| Request {
                    id: i as u64,
                    prompt: s.prompt.clone(),
                    template: s.template.clone(),
                    max_new: s.template.chars().count() + 2,
                    resume: None,
                })
                .collect();
            let responses = engine.run_all(reqs)?;
            let mut acc = 0.0;
            for r in &responses {
                acc += score_sample(&samples[r.id as usize], &r.hole_predictions);
            }
            row.push(format!("{:.1}%", 100.0 * acc / responses.len().max(1) as f64));
        }
        t.row(row);
    }
    t.print();
    println!("(accuracy must fall as B shrinks; lazy should degrade most gracefully)");
    Ok(())
}
