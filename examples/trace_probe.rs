//! Perf probe: pallas-interpret vs pure-jnp attention in the step executable.
use anyhow::Result;
use lazyeviction::runtime::{Client, Manifest};
use std::time::Instant;
fn main() -> Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let client = Client::cpu()?;
    let weights_flat = manifest.load_weights()?;
    let mut bufs = Vec::new();
    for p in &manifest.params {
        bufs.push(client.upload_f32(&weights_flat[p.offset_f32..p.offset_f32+p.size_f32], &p.shape)?);
    }
    let (b, l, h, s, dh) = (1usize, 4, 2, 256, 64);
    let zeros = vec![0f32; b*l*h*s*dh];
    for path in ["/tmp/step_ref.hlo.txt", "/tmp/step_pallas.hlo.txt"] {
        let exe = client.compile_file(path)?;
        let kc = client.upload_f32(&zeros, &[b,l,h,s,dh])?;
        let vc = client.upload_f32(&zeros, &[b,l,h,s,dh])?;
        let mut mask = vec![0f32; b*s]; mask[..128].fill(1.0);
        let maskb = client.upload_f32(&mask, &[b,s])?;
        let tok = client.upload_i32(&[3], &[b])?;
        let pos = client.upload_i32(&[128], &[b])?;
        let mut args: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        args.push(&kc); args.push(&vc); args.push(&maskb); args.push(&tok); args.push(&pos);
        for _ in 0..5 { exe.execute_b(&args)?; }
        let n = 50; let t0 = Instant::now();
        for _ in 0..n { let o = exe.execute_b(&args)?; let _ = o[0][0].to_literal_sync()?; }
        println!("{path}: {:.3} ms/step", t0.elapsed().as_secs_f64()*1e3/n as f64);
    }
    Ok(())
}
