//! Offline TIR analysis (no artifacts needed): generate synthetic traces
//! for every dataset profile, report recurrence fractions, MRI percentiles
//! and the paper's suggested observation window W per (model, dataset) —
//! i.e. the §4 offline pre-analysis step as a tool.
//!
//!   cargo run --release --example trace_analysis -- [--samples 8]

use lazyeviction::bench_harness::table::Table;
use lazyeviction::trace::workload::{dataset_profile, model_profile, DATASETS, MODELS};
use lazyeviction::trace::{generator, mri};
use lazyeviction::util::cli::Args;
use lazyeviction::util::stats;

fn main() {
    let args = Args::from_env();
    let n = args.usize_or("samples", 8) as u64;
    println!("\nTIR offline analysis (the paper's W-selection preprocessing)");
    let mut t = Table::new(&[
        "model", "dataset", "mean len", "recur %", "MRI p50", "MRI p80", "suggested W",
    ]);
    for model in MODELS {
        for dataset in DATASETS {
            let wp = dataset_profile(dataset);
            let mp = model_profile(model);
            let traces: Vec<_> =
                (0..n).map(|s| generator::generate(&wp, &mp, 31_000 + s)).collect();
            let mris = mri::measure_mri(&traces, mp.alpha);
            let frac = mri::recurrence_fraction(&traces, mp.alpha);
            let mean_len: f64 = traces.iter().map(|t| t.total_len as f64).sum::<f64>()
                / traces.len() as f64;
            let w = mri::suggest_window(&traces, mp.alpha, 0.8);
            t.row(vec![
                model.into(),
                dataset.into(),
                format!("{mean_len:.0}"),
                format!("{:.1}", frac * 100.0),
                format!("{:.0}", stats::percentile(&mris, 0.5)),
                format!("{:.0}", stats::percentile(&mris, 0.8)),
                w.to_string(),
            ]);
        }
    }
    t.print();
    println!("Reasoning profiles must show large MRIs (W ≈ tens-hundreds);");
    println!("pg19 (LM) must show MRI < 10 — the paper's Limitations case.");
}
