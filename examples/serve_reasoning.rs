//! End-to-end serving driver (EXPERIMENTS.md §E2E): starts the TCP server on
//! a background thread with a continuous-batching engine (batch 4), fires a
//! wave of concurrent reasoning requests through real sockets, scores the
//! model's answers against ground truth, and reports latency/throughput.
//!
//!   cargo run --release --example serve_reasoning -- [--requests N]
//!     [--policy lazy] [--budget 192] [--clients 4]

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;
use lazyeviction::bench_harness::artifacts_dir;
use lazyeviction::coordinator::{Engine, EngineConfig};
use lazyeviction::runtime::{Client, Manifest};
use lazyeviction::trace::workload::{gen_reasoning_sample, score_sample, ReasoningSample};
use lazyeviction::util::cli::Args;
use lazyeviction::util::json::Json;
use lazyeviction::util::rng::Rng;
use lazyeviction::util::stats::Summary;

fn main() -> Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize_or("requests", 24);
    let n_clients = args.usize_or("clients", 4);
    let policy = args.str_or("policy", "lazy");
    let budget = args.usize_or("budget", 192);
    let addr = "127.0.0.1:8197";

    let manifest = Manifest::load(artifacts_dir())?;
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        // the PJRT client/engine are thread-affine (Rc internals) — build
        // them inside the server thread rather than moving them across
        let shutdown = shutdown.clone();
        let manifest = manifest.clone();
        let policy_t = policy.clone();
        std::thread::spawn(move || -> Result<()> {
            let client = Client::cpu()?;
            let mut cfg = EngineConfig {
                batch: 4,
                cache: 256,
                budget,
                policy: policy_t.clone(),
                record_live: false,
                ..Default::default()
            };
            cfg.params.window = 16;
            cfg.params.recent = 16;
            cfg.collect_sketches = policy_t.starts_with("rkv");
            let engine = Engine::new(&client, &manifest, cfg)?;
            lazyeviction::server::serve(engine, addr, shutdown)
        });
    }
    // wait for the engine to compile + the listener to bind
    for _ in 0..300 {
        if TcpStream::connect(addr).is_ok() {
            break;
        }
        std::thread::sleep(Duration::from_millis(200));
    }

    // generate the workload
    let mut rng = Rng::new(args.u64_or("seed", 42));
    let samples: Vec<ReasoningSample> = (0..n_requests)
        .map(|_| gen_reasoning_sample(&mut rng, 4, 10))
        .collect();

    // fire requests from n_clients concurrent connections
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let mine: Vec<(usize, ReasoningSample)> = samples
            .iter()
            .enumerate()
            .filter(|(i, _)| i % n_clients == c)
            .map(|(i, s)| (i, s.clone()))
            .collect();
        handles.push(std::thread::spawn(move || -> Result<Vec<(usize, Json, f64)>> {
            let stream = TcpStream::connect(addr)?;
            let mut reader = BufReader::new(stream.try_clone()?);
            let mut out = Vec::new();
            for (i, s) in mine {
                let req = Json::obj()
                    .set("prompt", s.prompt.as_str())
                    .set("template", s.template.as_str())
                    .set("max_new", s.template.chars().count() + 2);
                let t = Instant::now();
                writeln!(&stream, "{}", req.to_string())?;
                let mut line = String::new();
                reader.read_line(&mut line)?;
                out.push((i, Json::parse(&line).map_err(anyhow::Error::new)?, t.elapsed().as_secs_f64()));
            }
            Ok(out)
        }));
    }

    let mut latencies = Vec::new();
    let mut total_tokens = 0usize;
    let mut acc_sum = 0.0;
    let mut scored = 0usize;
    for h in handles {
        for (i, resp, lat) in h.join().unwrap()? {
            latencies.push(lat * 1e3);
            total_tokens += resp.usize_at("tokens").unwrap_or(0);
            let holes: Vec<char> = resp
                .str_at("holes")
                .unwrap_or_default()
                .chars()
                .collect();
            acc_sum += score_sample(&samples[i], &holes);
            scored += 1;
        }
    }
    shutdown.store(true, Ordering::Relaxed);
    let wall = t0.elapsed().as_secs_f64();
    let s = Summary::of(&latencies);

    println!("== serve_reasoning E2E ==");
    println!("policy           : {policy} (budget {budget}, batch 4)");
    println!("requests         : {n_requests} over {n_clients} connections");
    println!("answer accuracy  : {:.1}%", 100.0 * acc_sum / scored.max(1) as f64);
    println!("wall time        : {wall:.2} s");
    println!("tokens served    : {total_tokens} ({:.1} tok/s aggregate)", total_tokens as f64 / wall);
    println!(
        "request latency  : mean {:.0} ms  p50 {:.0}  p90 {:.0}  p99 {:.0}",
        s.mean, s.p50, s.p90, s.p99
    );
    Ok(())
}
