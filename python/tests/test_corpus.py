"""Synthetic reasoning corpus invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus
from compile.configs import CHARSET


class TestEncoding:
    def test_roundtrip(self):
        s = "#A=3;B=7;\n>A+B=0;\n"
        assert corpus.decode(corpus.encode(s)) == s

    def test_charset_closed(self):
        rng = np.random.default_rng(0)
        for _ in range(50):
            s = corpus.gen_sample(rng)
            assert set(s.text) <= set(CHARSET)


class TestSample:
    def test_answers_at_positions(self):
        rng = np.random.default_rng(1)
        for _ in range(100):
            s = corpus.gen_sample(rng)
            for p, a in zip(s.answer_pos, s.answers):
                assert s.text[p] == a

    def test_arithmetic_is_mod10_consistent(self):
        rng = np.random.default_rng(2)
        for _ in range(50):
            s = corpus.gen_sample(rng, chain_prob=0.0, recall_prob=0.0)
            env = {}
            for frag in s.text.split("\n")[0][1:].split(";"):
                if frag:
                    v, d = frag.split("=")
                    env[v] = int(d)
            for frag in s.text.split("\n")[1][1:].split(";"):
                if frag:
                    expr, d = frag.rsplit("=", 1)
                    a, b = expr.split("+")
                    assert (env[a] + env[b]) % 10 == int(d)

    def test_prompt_len_points_past_gt(self):
        rng = np.random.default_rng(3)
        s = corpus.gen_sample(rng)
        assert s.text[s.prompt_len - 1] == ">"

    def test_chained_vars_recur(self):
        # with chain_prob=1 some derived var must be reused by later queries
        rng = np.random.default_rng(4)
        found = False
        for _ in range(50):
            s = corpus.gen_sample(rng, n_facts=2, n_queries=8, chain_prob=1.0,
                                  recall_prob=0.0)
            q = s.text.split("\n")[1]
            frags = [f for f in q[1:].split(";") if f]
            seen_defs = set()
            for f in frags:
                parts = f.split("=")
                expr = parts[-2] if len(parts) == 3 else parts[0]
                a, b = expr.split("+")
                if a in seen_defs or b in seen_defs:
                    found = True
                if len(parts) == 3:
                    seen_defs.add(parts[0])
            if found:
                break
        assert found

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10**6), nf=st.integers(2, 8), nq=st.integers(1, 12))
    def test_hypothesis_structure(self, seed, nf, nq):
        rng = np.random.default_rng(seed)
        s = corpus.gen_sample(rng, nf, nq)
        assert s.text.startswith("#") and s.text.endswith("\n")
        assert s.text.count(">") == 1
        assert len(s.answers) == nq


class TestPacking:
    def test_shapes(self):
        rng = np.random.default_rng(0)
        toks, mask = corpus.pack_sequences(rng, 4, 128)
        assert toks.shape == (4, 128) and mask.shape == (4, 127)
        assert toks.dtype == np.int32

    def test_mask_has_answer_weights(self):
        rng = np.random.default_rng(1)
        _, mask = corpus.pack_sequences(rng, 4, 256)
        assert (mask == 10.0).sum() > 0
        assert set(np.unique(mask)) <= {0.0, 1.0, 10.0}

    def test_tokens_in_vocab(self):
        rng = np.random.default_rng(2)
        toks, _ = corpus.pack_sequences(rng, 2, 128)
        assert toks.min() >= 0 and toks.max() < len(CHARSET)

    def test_eval_batch_targets_valid(self):
        rng = np.random.default_rng(3)
        toks, targets = corpus.eval_batch(rng, 8, 128)
        assert len(targets) > 0
        for row, tp, ans in targets:
            assert 0 <= row < 8 and 0 <= tp < 127
            # target slot predicts the answer at tp+1
            assert toks[row, tp + 1] == ans
