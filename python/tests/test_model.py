"""L2 model tests: decode/prefill/training-path consistency and cache ops."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import corpus, model
from compile.configs import ModelConfig

TINY = ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=64)


@pytest.fixture(scope="module")
def params():
    return model.init_params(TINY, jax.random.PRNGKey(0))


def _tokens(seed, n):
    rng = np.random.default_rng(seed)
    return rng.integers(0, TINY.vocab, size=n).astype(np.int32)


class TestParams:
    def test_spec_count_matches(self, params):
        assert len(params) == len(TINY.param_specs())

    def test_shapes(self, params):
        for p, (_, shape) in zip(params, TINY.param_specs()):
            assert p.shape == shape

    def test_bytes_roundtrip(self, params):
        raw = model.params_to_bytes(params)
        back = model.params_from_bytes(TINY, raw)
        for a, b in zip(params, back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_bytes_size_mismatch_raises(self, params):
        raw = model.params_to_bytes(params)
        with pytest.raises(ValueError):
            model.params_from_bytes(TINY, raw + b"\x00" * 4)


class TestRope:
    def test_norm_preserved(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 2, 8)), jnp.float32)
        pos = jnp.asarray([3, 11], jnp.int32)
        y = model.rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1), atol=1e-5)

    def test_relative_property(self):
        # <rope(q,i), rope(k,j)> depends only on i-j
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 1, 8)), jnp.float32)
        def dot(i, j):
            qi = model.rope(q, jnp.asarray([i], jnp.int32), 10000.0)
            kj = model.rope(k, jnp.asarray([j], jnp.int32), 10000.0)
            return float(jnp.sum(qi * kj))
        assert abs(dot(5, 2) - dot(103, 100)) < 1e-3

    def test_pos_zero_identity(self):
        x = jnp.asarray(np.random.default_rng(2).normal(size=(1, 2, 8)), jnp.float32)
        y = model.rope(x, jnp.asarray([0], jnp.int32), 10000.0)
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


class TestDecodeConsistency:
    """Prefill + incremental decode must reproduce teacher-forced logits."""

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_decode_matches_forward(self, params, use_pallas):
        T, S, P = 24, 32, 16
        toks = _tokens(0, T)[None, :]
        ref_logits = model.forward_train(TINY, params, jnp.asarray(toks))
        n_pre = 12
        vm = np.zeros((1, P), np.float32); vm[0, :n_pre] = 1
        pt = np.zeros((1, P), np.int32); pt[0, :n_pre] = toks[0, :n_pre]
        kc, vc, attn_last, ll = model.prefill(
            TINY, params, jnp.asarray(pt), jnp.asarray(vm), S, use_pallas=use_pallas)
        np.testing.assert_allclose(
            np.asarray(ll[0]), np.asarray(ref_logits[0, n_pre - 1]), atol=2e-4)
        mask = np.zeros((1, S), np.float32); mask[0, :n_pre] = 1
        for i in range(n_pre, T):
            lg, ag, kn, vn = model.decode_step(
                TINY, params, kc, vc, jnp.asarray(mask),
                jnp.asarray(toks[:, i]), jnp.asarray([i], np.int32),
                use_pallas=use_pallas)
            np.testing.assert_allclose(
                np.asarray(lg[0]), np.asarray(ref_logits[0, i]), atol=2e-4)
            kc = model.cache_append(kc, kn, jnp.asarray([i], np.int32))
            vc = model.cache_append(vc, vn, jnp.asarray([i], np.int32))
            mask[0, i] = 1

    def test_attention_agg_shape_and_range(self, params):
        S, B = 16, 2
        kc = jnp.zeros((B, TINY.n_layers, TINY.n_heads, S, TINY.d_head))
        vc = jnp.zeros_like(kc)
        mask = jnp.ones((B, S))
        lg, ag, kn, vn = model.decode_step(
            TINY, params, kc, vc, mask,
            jnp.asarray([1, 2], jnp.int32), jnp.asarray([5, 5], jnp.int32),
            use_pallas=False)
        assert ag.shape == (B, S)
        a = np.asarray(ag)
        assert (a >= 0).all() and (a <= 1.0 + 1e-5).all()

    def test_trace_variant_full_attention(self, params):
        S = 16
        kc = jnp.zeros((1, TINY.n_layers, TINY.n_heads, S, TINY.d_head))
        vc = jnp.zeros_like(kc)
        mask = jnp.ones((1, S))
        _, w, _, _ = model.decode_step(
            TINY, params, kc, vc, mask, jnp.asarray([1], jnp.int32),
            jnp.asarray([3], jnp.int32), full_attn=True, use_pallas=False)
        assert w.shape == (1, TINY.n_layers, TINY.n_heads, S)


class TestCacheOps:
    def _cache(self, B=2, S=8):
        L, H, dh = TINY.n_layers, TINY.n_heads, TINY.d_head
        rng = np.random.default_rng(0)
        return jnp.asarray(rng.normal(size=(B, L, H, S, dh)), jnp.float32)

    def test_append_writes_slot(self):
        c = self._cache()
        B, L, H, S, dh = c.shape
        new = jnp.ones((B, L, H, dh))
        idx = jnp.asarray([3, 5], jnp.int32)
        out = model.cache_append(c, new, idx)
        np.testing.assert_allclose(np.asarray(out[0, :, :, 3]), 1.0)
        np.testing.assert_allclose(np.asarray(out[1, :, :, 5]), 1.0)
        # other slots untouched
        np.testing.assert_array_equal(
            np.asarray(out[0, :, :, :3]), np.asarray(c[0, :, :, :3]))

    def test_gather_permutes(self):
        c = self._cache()
        B, L, H, S, dh = c.shape
        perm = np.stack([np.roll(np.arange(S), 1), np.arange(S)])
        out = model.cache_gather(c, jnp.asarray(perm, jnp.int32))
        np.testing.assert_array_equal(
            np.asarray(out[0, :, :, 1]), np.asarray(c[0, :, :, 0]))
        np.testing.assert_array_equal(np.asarray(out[1]), np.asarray(c[1]))

    def test_gather_compaction_duplicates_allowed(self):
        c = self._cache(B=1)
        S = c.shape[3]
        idx = np.zeros((1, S), np.int32)  # everything = slot 0
        out = model.cache_gather(c, jnp.asarray(idx))
        for j in range(S):
            np.testing.assert_array_equal(
                np.asarray(out[0, :, :, j]), np.asarray(c[0, :, :, 0]))

    def test_insert_replaces_row(self):
        c = self._cache()
        _, L, H, S, dh = c.shape
        seq = jnp.full((L, H, S, dh), 7.0)
        out = model.cache_insert(c, seq, jnp.asarray(1, jnp.int32))
        np.testing.assert_allclose(np.asarray(out[1]), 7.0)
        np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(c[0]))


class TestLoss:
    def test_loss_decreases_with_fit(self, params):
        toks = jnp.asarray(_tokens(3, 16)[None, :])
        l0 = model.lm_loss(TINY, params, toks)
        assert np.isfinite(float(l0)) and float(l0) > 0

    def test_mask_weighting(self, params):
        toks = jnp.asarray(_tokens(4, 16)[None, :])
        m_uniform = jnp.ones((1, 15))
        l_u = model.lm_loss(TINY, params, toks, m_uniform)
        l_none = model.lm_loss(TINY, params, toks)
        np.testing.assert_allclose(float(l_u), float(l_none), rtol=1e-6)

    def test_grad_finite(self, params):
        toks = jnp.asarray(_tokens(5, 16)[None, :])
        g = jax.grad(lambda p: model.lm_loss(TINY, p, toks))(params)
        for gi in g:
            assert np.isfinite(np.asarray(gi)).all()
