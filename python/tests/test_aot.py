"""AOT lowering tests: every variant lowers to parseable HLO text with the
expected parameter count; manifest layout is self-consistent."""

import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import aot, model
from compile.configs import ArtifactVariant, BuildConfig, ModelConfig

TINY = ModelConfig(d_model=32, n_layers=2, n_heads=2, d_head=8, d_ff=64)
N_PARAMS = len(TINY.param_specs())


def lower(kind, b, s, p=0):
    fn, specs = aot.build_variant(TINY, kind, b, s, p)
    return aot.to_hlo_text(fn, *specs), specs


class TestLowering:
    @pytest.mark.parametrize("kind,extra_args", [
        ("step", 5), ("trace", 5), ("prefill", 2),
    ])
    def test_model_variants_lower(self, kind, extra_args):
        text, specs = lower(kind, 1, 32, 16)
        assert "ENTRY" in text
        assert len(specs) == N_PARAMS + extra_args
        # every spec appears as an entry parameter (Arg_N); nested fusion
        # computations declare their own parameters, so count distinct Arg ids
        import re
        args = {m.group(1) for m in re.finditer(r"Arg_(\d+)", text)}
        assert len(args) == len(specs)

    @pytest.mark.parametrize("kind,nargs", [
        ("append", 3), ("gather", 2), ("insert", 3),
    ])
    def test_cache_variants_lower(self, kind, nargs):
        text, specs = lower(kind, 2, 16)
        assert "ENTRY" in text and len(specs) == nargs

    def test_step_output_tuple_shapes(self):
        # root tuple: logits [B,V], attn [B,S], k_new, v_new
        B, S = 2, 32
        text, _ = lower("step", B, S)
        assert f"f32[{B},{TINY.vocab}]" in text
        assert f"f32[{B},{S}]" in text

    def test_gather_root_is_cache_shaped(self):
        B, S = 2, 16
        text, _ = lower("gather", B, S)
        shape = f"f32[{B},{TINY.n_layers},{TINY.n_heads},{S},{TINY.d_head}]"
        assert shape in text


class TestVariants:
    def test_names(self):
        assert ArtifactVariant("step", 4, 256).name == "step_b4_s256"
        assert ArtifactVariant("prefill", 1, 256, 64).name == "prefill_b1_s256_p64"

    def test_build_config_unique_names(self):
        names = [v.name for v in BuildConfig().variants()]
        assert len(names) == len(set(names))

    def test_build_config_covers_all_kinds(self):
        kinds = {v.kind for v in BuildConfig().variants()}
        assert kinds == {"step", "stepf", "append", "gather", "insert",
                         "prefill", "trace"}


class TestParamLayout:
    def test_offsets_contiguous(self):
        offset = 0
        for name, shape in TINY.param_specs():
            size = int(np.prod(shape))
            offset += size
        params = model.init_params(TINY, jax.random.PRNGKey(0))
        raw = model.params_to_bytes(params)
        assert len(raw) == offset * 4

    def test_manifest_roundtrip_layout(self):
        # mimic aot.main()'s manifest param table
        offset = 0
        table = []
        for name, shape in TINY.param_specs():
            size = int(np.prod(shape))
            table.append((name, list(shape), offset, size))
            offset += size
        # reconstruct params from bytes using the table
        params = model.init_params(TINY, jax.random.PRNGKey(1))
        raw = model.params_to_bytes(params)
        flat = np.frombuffer(raw, np.float32)
        for (name, shape, off, size), p in zip(table, params):
            np.testing.assert_array_equal(
                flat[off:off + size].reshape(shape), np.asarray(p))
