"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes / mask densities / magnitudes; deterministic tests
pin the edge cases (empty cache, single slot, non-multiple blocking).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attn, ref

ATOL = 2e-5


def _rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def make_decode_inputs(seed, B, H, S, dh, density=0.7, scale=1.0):
    rng = np.random.default_rng(seed)
    q = _rand(rng, B, H, dh, scale=scale)
    k = _rand(rng, B, H, S, dh, scale=scale)
    v = _rand(rng, B, H, S, dh)
    mask = jnp.asarray((rng.random((B, S)) < density).astype(np.float32))
    kn = _rand(rng, B, H, dh, scale=scale)
    vn = _rand(rng, B, H, dh)
    return q, k, v, mask, kn, vn


def assert_decode_matches(args, **kw):
    cr, wr = ref.decode_attention_ref(*args)
    ck, wk = attn.decode_attention(*args, **kw)
    np.testing.assert_allclose(np.asarray(cr), np.asarray(ck), atol=ATOL)
    np.testing.assert_allclose(np.asarray(wr), np.asarray(wk), atol=ATOL)


class TestDecodeSingleBlock:
    def test_basic(self):
        assert_decode_matches(make_decode_inputs(0, 2, 2, 128, 64))

    def test_batch1_head1(self):
        assert_decode_matches(make_decode_inputs(1, 1, 1, 32, 16))

    def test_full_mask(self):
        assert_decode_matches(make_decode_inputs(2, 2, 4, 64, 32, density=1.0))

    def test_sparse_mask(self):
        assert_decode_matches(make_decode_inputs(3, 2, 2, 64, 32, density=0.05))

    def test_single_valid_slot(self):
        q, k, v, _, kn, vn = make_decode_inputs(4, 1, 2, 16, 8)
        mask = np.zeros((1, 16), np.float32)
        mask[0, 7] = 1.0
        assert_decode_matches((q, k, v, jnp.asarray(mask), kn, vn))

    def test_empty_cache_returns_self(self):
        q, k, v, _, kn, vn = make_decode_inputs(5, 2, 2, 16, 8)
        mask = jnp.zeros((2, 16), jnp.float32)
        ctx, w = attn.decode_attention(q, k, v, mask, kn, vn)
        np.testing.assert_allclose(np.asarray(ctx), np.asarray(vn), atol=ATOL)
        assert float(jnp.abs(w).max()) == 0.0

    def test_large_scores_stable(self):
        # online-softmax must not overflow for large logits
        assert_decode_matches(make_decode_inputs(6, 1, 1, 64, 32, scale=12.0))

    def test_weights_sum_below_one(self):
        # cache weights + (hidden) self weight = 1, so sum(w) <= 1
        args = make_decode_inputs(7, 2, 2, 64, 32)
        _, w = attn.decode_attention(*args)
        s = np.asarray(jnp.sum(w, axis=-1))
        assert (s <= 1.0 + 1e-5).all() and (s >= 0).all()

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        B=st.integers(1, 3),
        H=st.integers(1, 4),
        S=st.sampled_from([16, 64, 96, 256]),
        dh=st.sampled_from([8, 32, 64]),
        density=st.floats(0.05, 1.0),
    )
    def test_hypothesis_sweep(self, seed, B, H, S, dh, density):
        assert_decode_matches(make_decode_inputs(seed, B, H, S, dh, density))


class TestDecodeBlocked:
    def test_basic(self):
        assert_decode_matches(
            make_decode_inputs(0, 2, 2, 256, 64),
            max_single_block=128, block_s=64,
        )

    def test_one_block_degenerate(self):
        # blocked path with a single S-block must equal single-block path
        assert_decode_matches(
            make_decode_inputs(1, 1, 2, 64, 32),
            max_single_block=32, block_s=64,
        )

    def test_max_in_last_block(self):
        q, k, v, mask, kn, vn = make_decode_inputs(2, 1, 1, 128, 32)
        k = k.at[0, 0, 120].set(q[0, 0] * 4.0)  # spike at the tail block
        assert_decode_matches((q, k, v, mask, kn, vn),
                              max_single_block=64, block_s=32)

    def test_block_of_all_masked(self):
        q, k, v, _, kn, vn = make_decode_inputs(3, 1, 2, 128, 32)
        mask = np.ones((1, 128), np.float32)
        mask[0, 32:64] = 0.0  # an entire interior block masked out
        assert_decode_matches((q, k, v, jnp.asarray(mask), kn, vn),
                              max_single_block=64, block_s=32)

    def test_large_scores_stable(self):
        assert_decode_matches(
            make_decode_inputs(4, 1, 1, 128, 32, scale=10.0),
            max_single_block=64, block_s=32,
        )

    def test_non_multiple_raises(self):
        args = make_decode_inputs(5, 1, 1, 96, 16)
        with pytest.raises(AssertionError):
            attn.decode_attention(*args, max_single_block=64, block_s=64)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        S=st.sampled_from([128, 256]),
        block=st.sampled_from([32, 64, 128]),
        density=st.floats(0.05, 1.0),
    )
    def test_hypothesis_sweep(self, seed, S, block, density):
        assert_decode_matches(
            make_decode_inputs(seed, 2, 2, S, 32, density),
            max_single_block=64, block_s=block,
        )


class TestPrefill:
    def _inputs(self, seed, B, H, P, dh, lens):
        rng = np.random.default_rng(seed)
        q = _rand(rng, B, H, P, dh)
        k = _rand(rng, B, H, P, dh)
        v = _rand(rng, B, H, P, dh)
        vm = np.zeros((B, P), np.float32)
        for b, ln in enumerate(lens):
            vm[b, :ln] = 1.0
        return q, k, v, jnp.asarray(vm)

    def _check(self, args):
        q, k, v, vm = args
        cr, wr = ref.prefill_attention_ref(q, k, v, vm)
        ck, wk = attn.prefill_attention(q, k, v, vm)
        sel = np.asarray(vm)[:, None, :, None]
        np.testing.assert_allclose(
            np.asarray(cr) * sel, np.asarray(ck) * sel, atol=ATOL)
        np.testing.assert_allclose(
            np.asarray(wr) * sel, np.asarray(wk) * sel, atol=ATOL)

    def test_full_lengths(self):
        self._check(self._inputs(0, 2, 2, 64, 32, [64, 64]))

    def test_ragged_lengths(self):
        self._check(self._inputs(1, 3, 2, 64, 32, [1, 13, 64]))

    def test_causality(self):
        # perturbing token j must not change rows < j
        q, k, v, vm = self._inputs(2, 1, 1, 32, 16, [32])
        c1, _ = attn.prefill_attention(q, k, v, vm)
        k2 = k.at[0, 0, 20].add(3.0)
        v2 = v.at[0, 0, 20].add(3.0)
        c2, _ = attn.prefill_attention(q, k2, v2, vm)
        np.testing.assert_allclose(
            np.asarray(c1[0, 0, :20]), np.asarray(c2[0, 0, :20]), atol=ATOL)
        assert float(jnp.abs(c1[0, 0, 20:] - c2[0, 0, 20:]).max()) > 1e-3

    def test_rows_sum_to_one(self):
        q, k, v, vm = self._inputs(3, 2, 2, 32, 16, [17, 32])
        _, w = attn.prefill_attention(q, k, v, vm)
        s = np.asarray(jnp.sum(w, axis=-1))
        valid = np.broadcast_to(np.asarray(vm)[:, None, :], s.shape)
        np.testing.assert_allclose(s * valid, valid, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        seed=st.integers(0, 10_000),
        B=st.integers(1, 3),
        P=st.sampled_from([16, 64]),
        dh=st.sampled_from([8, 32]),
    )
    def test_hypothesis_sweep(self, seed, B, P, dh):
        rng = np.random.default_rng(seed)
        lens = [int(rng.integers(1, P + 1)) for _ in range(B)]
        self._check(self._inputs(seed, B, 2, P, dh, lens))
