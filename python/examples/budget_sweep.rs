fn main() { println!("example stub: budget_sweep"); }
