fn main() { println!("example stub: quickstart"); }
