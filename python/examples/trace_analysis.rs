fn main() { println!("example stub: trace_analysis"); }
