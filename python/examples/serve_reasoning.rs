fn main() { println!("example stub: serve_reasoning"); }
