"""AOT compile path: lower every executable variant to HLO *text*.

HLO text — NOT ``lowered.compile()`` / proto ``.serialize()`` — is the
interchange format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction
ids which the runtime's xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Outputs into ``--out`` (default ../artifacts):
  <variant>.hlo.txt      one per ArtifactVariant (step/append/gather/...)
  weights.bin            trained parameters, flat f32 in param_specs order
  manifest.json          everything the Rust runtime needs (charset, dims,
                         param layout, variant table, signatures)

Usage:  python -m compile.aot [--out DIR] [--random] [--train-steps N]
  --random     skip training, random-init weights (fast CI builds)
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model
from .configs import CHARSET, BuildConfig, ModelConfig, TrainConfig


def to_hlo_text(fn, *specs, return_tuple: bool = True) -> str:
    """Lower to HLO text. Multi-output model functions use return_tuple=True;
    single-output cache ops MUST use return_tuple=False — a 1-tuple root
    compiles to a tuple (pointer-table) buffer that cannot be chained back
    into an array parameter via execute_b (observed as an 8-byte buffer
    where the cache was expected)."""
    lowered = jax.jit(fn).lower(*specs)
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(lowered.compiler_ir("stablehlo")), use_tuple_args=False,
        return_tuple=return_tuple,
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs_jax(cfg: ModelConfig):
    return [_spec(s) for _, s in cfg.param_specs()]


def build_variant(cfg: ModelConfig, kind: str, batch: int, cache: int, prefill: int,
                  blocks: int = 0, block: int = 0):
    """Return (fn, arg_specs) for one artifact variant."""
    B, S, P = batch, cache, prefill
    L, H, dh = cfg.n_layers, cfg.n_heads, cfg.d_head
    i32 = jnp.int32
    cache_spec = _spec((B, L, H, S, dh))
    arena_spec = _spec((blocks, block, L, H, dh))
    if kind in ("step", "stepf", "trace"):
        full = kind == "trace"
        use_pallas = kind != "stepf"

        def fn(*args):
            params = args[: -5]
            k_cache, v_cache, slot_mask, token, pos = args[-5:]
            return model.decode_step(
                cfg, params, k_cache, v_cache, slot_mask, token, pos,
                full_attn=full, use_pallas=use_pallas,
            )

        specs = param_specs_jax(cfg) + [
            cache_spec, cache_spec, _spec((B, S)), _spec((B,), i32), _spec((B,), i32),
        ]
        return fn, specs
    if kind == "prefill":

        def fn(*args):
            params = args[:-2]
            tokens, valid_mask = args[-2:]
            return model.prefill(cfg, params, tokens, valid_mask, S)

        specs = param_specs_jax(cfg) + [_spec((B, P), i32), _spec((B, P))]
        return fn, specs
    if kind == "append":
        fn = model.cache_append
        return fn, [cache_spec, _spec((B, L, H, dh)), _spec((B,), i32)]
    if kind == "gather":
        fn = model.cache_gather
        return fn, [cache_spec, _spec((B, S), i32)]
    if kind == "insert":
        fn = model.cache_insert
        return fn, [cache_spec, _spec((L, H, S, dh)), _spec((), i32)]
    if kind == "stepp":
        # paged step: K/V gathered through [B, MB] block tables + [B] lens
        MB = S // block

        def fn(*args):
            params = args[:-6]
            k_arena, v_arena, tables, lens, token, pos = args[-6:]
            return model.decode_step_paged(
                cfg, params, k_arena, v_arena, tables, lens, token, pos,
            )

        specs = param_specs_jax(cfg) + [
            arena_spec, arena_spec, _spec((B, MB), i32), _spec((B,), i32),
            _spec((B,), i32), _spec((B,), i32),
        ]
        return fn, specs
    if kind == "blockw":
        fn = model.arena_row_write
        return fn, [arena_spec, _spec((L, H, dh)), _spec((), i32)]
    if kind == "blockg":
        fn = model.arena_row_gather
        return fn, [arena_spec, _spec((blocks * block,), i32)]
    raise ValueError(kind)


SIGNATURES = {
    "step": {
        "inputs": ["params...", "k_cache[B,L,H,S,dh]", "v_cache[B,L,H,S,dh]",
                   "slot_mask[B,S]", "token[B]i32", "pos[B]i32"],
        "outputs": ["logits[B,V]", "attn_agg[B,S]", "k_new[B,L,H,dh]", "v_new[B,L,H,dh]"],
    },
    "stepf": {"inputs": ["same as step (XLA-fused attention fast path)"],
              "outputs": ["same as step"]},
    "trace": {
        "inputs": ["params...", "k_cache", "v_cache", "slot_mask", "token", "pos"],
        "outputs": ["logits[B,V]", "attn_full[B,L,H,S]", "k_new", "v_new"],
    },
    "prefill": {
        "inputs": ["params...", "tokens[B,P]i32", "valid_mask[B,P]"],
        "outputs": ["k_cache[B,L,H,S,dh]", "v_cache", "attn_last[B,P]", "logits_last[B,V]"],
    },
    "append": {"inputs": ["cache", "new[B,L,H,dh]", "idx[B]i32"], "outputs": ["cache"]},
    "gather": {"inputs": ["cache", "idx[B,S]i32"], "outputs": ["cache"]},
    "insert": {"inputs": ["cache", "seq[L,H,S,dh]", "b[]i32"], "outputs": ["cache"]},
    "stepp": {
        "inputs": ["params...", "k_arena[N,bs,L,H,dh]", "v_arena[N,bs,L,H,dh]",
                   "block_tables[B,MB]i32", "seq_lens[B]i32", "token[B]i32",
                   "pos[B]i32"],
        "outputs": ["logits[B,V]", "attn_agg[B,MB*bs]", "k_new[B,L,H,dh]",
                    "v_new[B,L,H,dh]"],
    },
    "blockw": {"inputs": ["arena[N,bs,L,H,dh]", "row[L,H,dh]", "slot[]i32"],
               "outputs": ["arena"]},
    "blockg": {"inputs": ["arena[N,bs,L,H,dh]", "idx[N*bs]i32"],
               "outputs": ["arena"]},
}


def load_or_train_weights(cfg: ModelConfig, out_dir: str, random_init: bool,
                          train_steps, log=print):
    wpath = os.path.join(out_dir, "weights.bin")
    if random_init:
        log("weights: random init (--random)")
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        with open(wpath, "wb") as f:
            f.write(model.params_to_bytes(params))
        return
    if os.path.exists(wpath):
        log(f"weights: reusing {wpath}")
        return
    from . import train as train_mod

    tc = TrainConfig(steps=train_steps) if train_steps else TrainConfig()
    log(f"weights: training {tc.steps} steps ...")
    train_mod.train(cfg, tc, out_dir, log=log)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--random", action="store_true")
    ap.add_argument("--train-steps", type=int, default=None)
    ap.add_argument("--only", default=None, help="comma list of variant names")
    args = ap.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    bc = BuildConfig()
    cfg = bc.model
    load_or_train_weights(cfg, out_dir, args.random, args.train_steps)

    only = set(args.only.split(",")) if args.only else None
    variants_meta = []
    for v in bc.variants():
        path = os.path.join(out_dir, v.name + ".hlo.txt")
        variants_meta.append({
            "kind": v.kind, "name": v.name, "file": v.name + ".hlo.txt",
            "batch": v.batch, "cache": v.cache, "prefill": v.prefill,
            "blocks": v.blocks, "block": v.block,
        })
        if only and v.name not in only:
            continue
        if os.path.exists(path):
            print(f"  {v.name}: cached")
            continue
        fn, specs = build_variant(cfg, v.kind, v.batch, v.cache, v.prefill,
                                  v.blocks, v.block)
        single = v.kind in ("append", "gather", "insert", "blockw", "blockg")
        text = to_hlo_text(fn, *specs, return_tuple=not single)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {v.name}: {len(text) / 1e6:.2f} MB hlo text")

    offset = 0
    params_meta = []
    for name, shape in cfg.param_specs():
        size = int(np.prod(shape))
        params_meta.append({
            "name": name, "shape": list(shape), "offset_f32": offset, "size_f32": size,
        })
        offset += size

    manifest = {
        "charset": CHARSET,
        "model": {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads, "d_head": cfg.d_head, "d_ff": cfg.d_ff,
            "rope_base": cfg.rope_base,
        },
        "weights_file": "weights.bin",
        "total_param_f32": offset,
        "params": params_meta,
        "variants": variants_meta,
        "signatures": SIGNATURES,
        "prefill_bucket": bc.prefill_bucket,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(variants_meta)} variants, {offset} f32 params")


if __name__ == "__main__":
    main()
