"""Synthetic multi-step reasoning corpus (the GSM8K-sim training data).

Each sample plants single-digit *facts* and then asks a chain of queries that
must *recall* those facts (and intermediate results) from many tokens back —
the Token Importance Recurrence mechanism of the paper, by construction:

    #A=3;B=7;C=2;
    >A+B=0;C=A+C=5;Q=B+C=9;Q+A=9;

Grammar (over configs.CHARSET):
  facts:   '#' (VAR '=' DIGIT ';')+ '\n'
  queries: '>' (VAR '+' VAR '=' DIGIT ';' | NEWVAR '=' VAR '+' VAR '=' DIGIT ';')+ '\n'
All arithmetic is mod 10 so every answer is one token. Chained queries define
new variables whose *values* only exist in the generated text — exactly the
"intermediate results reactivated in later steps" of Fig. 3(b).
"""

from dataclasses import dataclass
from typing import List, Tuple

import numpy as np

from .configs import CHARSET

VARS = "ABCDEFGHIJKLMNOPQRSTUVWXYZ"
_LOOKUP = {c: i for i, c in enumerate(CHARSET)}


def encode(text: str) -> np.ndarray:
    return np.asarray([_LOOKUP[c] for c in text], np.int32)


def decode(ids) -> str:
    return "".join(CHARSET[int(i)] for i in ids)


@dataclass
class Sample:
    text: str
    # index of each answer digit within text (position of the digit itself)
    answer_pos: List[int]
    answers: List[str]

    @property
    def prompt_len(self) -> int:
        """Length of the fact block incl. '>' — what the server gets as prompt."""
        return self.text.index(">") + 1


def gen_sample(rng: np.random.Generator, n_facts: int = 4, n_queries: int = 6,
               chain_prob: float = 0.25, recall_prob: float = 0.4) -> Sample:
    """One reasoning sample. Query mix (curriculum for the tiny model):
      * recall   `A=3;`      — re-state a planted fact (pure retrieval);
      * add      `A+B=0;`    — retrieve two facts and add mod 10;
      * chain    `E=A+B=0;`  — define an intermediate result that later
                               queries can reference (TIR of intermediates).
    """
    n_facts = max(2, n_facts)
    names = list(rng.permutation(list(VARS))[: n_facts + n_queries])
    env = {}
    parts = ["#"]
    for v in names[:n_facts]:
        env[v] = int(rng.integers(0, 10))
        parts.append(f"{v}={env[v]};")
    parts.append("\n>")
    text = "".join(parts)
    answer_pos, answers = [], []
    next_new = n_facts
    for _ in range(n_queries):
        known = list(env.keys())
        r = rng.random()
        if r < recall_prob:
            a = known[int(rng.integers(0, len(known)))]
            val = env[a]
            frag = f"{a}={val};"
        else:
            a = known[int(rng.integers(0, len(known)))]
            b = known[int(rng.integers(0, len(known)))]
            val = (env[a] + env[b]) % 10
            if r < recall_prob + chain_prob and next_new < len(names):
                nv = names[next_new]
                next_new += 1
                frag = f"{nv}={a}+{b}={val};"
                env[nv] = val
            else:
                frag = f"{a}+{b}={val};"
        # answer digit is the char right before ';'
        answer_pos.append(len(text) + len(frag) - 2)
        answers.append(str(val))
        text += frag
    text += "\n"
    return Sample(text, answer_pos, answers)


def pack_sequences(rng: np.random.Generator, n_seqs: int, seq_len: int,
                   n_facts=(3, 7), n_queries=(4, 10)) -> Tuple[np.ndarray, np.ndarray]:
    """Pack samples into [n_seqs, seq_len] token blocks + loss-weight mask.

    Mask is 1.0 everywhere a real token sits and ANSWER_WEIGHT at answer
    digits (targets are shifted by one inside lm_loss, hence pos-1 below).
    """
    ANSWER_WEIGHT = 10.0
    toks = np.full((n_seqs, seq_len), _LOOKUP[" "], np.int32)
    mask = np.zeros((n_seqs, seq_len - 1), np.float32)
    for i in range(n_seqs):
        cursor = 0
        while cursor < seq_len - 8:
            s = gen_sample(
                rng,
                int(rng.integers(n_facts[0], n_facts[1] + 1)),
                int(rng.integers(n_queries[0], n_queries[1] + 1)),
            )
            ids = encode(s.text)
            take = min(len(ids), seq_len - cursor)
            toks[i, cursor : cursor + take] = ids[:take]
            mask[i, cursor : cursor + take - 1] = 1.0
            for p in s.answer_pos:
                tp = cursor + p - 1  # target slot predicting the answer digit
                if 0 <= tp < seq_len - 1 and p < take:
                    mask[i, tp] = ANSWER_WEIGHT
            cursor += take
    return toks, mask


def eval_batch(rng: np.random.Generator, n: int, seq_len: int, **kw):
    """Samples padded to seq_len with per-sample answer target positions."""
    toks = np.full((n, seq_len), _LOOKUP[" "], np.int32)
    targets = []  # list of (row, target_pos, answer_id) — target_pos predicts it
    for i in range(n):
        s = gen_sample(rng, **kw) if kw else gen_sample(rng)
        ids = encode(s.text)[:seq_len]
        toks[i, : len(ids)] = ids
        for p, a in zip(s.answer_pos, s.answers):
            if p < len(ids):
                targets.append((i, p - 1, _LOOKUP[a]))
    return toks, targets
