"""Build-time training of the served model on the synthetic reasoning corpus.

Hand-rolled AdamW over the flat parameter tuple (no optax in this
environment). Saves artifacts/weights.bin + artifacts/train_log.json.
Usage: python -m compile.train [--steps N] [--out DIR] [--quick]
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model
from .configs import ModelConfig, TrainConfig


def adamw_init(params):
    z = lambda: tuple(jnp.zeros_like(p) for p in params)
    return {"m": z(), "v": z(), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, wd, clip, b1=0.9, b2=0.95, eps=1e-8):
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    t = state["t"] + 1
    new_m, new_v, new_p = [], [], []
    for p, g, m, v in zip(params, grads, state["m"], state["v"]):
        g = g * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t.astype(jnp.float32))
        vhat = v / (1 - b2 ** t.astype(jnp.float32))
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
        new_m.append(m)
        new_v.append(v)
        new_p.append(p)
    return tuple(new_p), {"m": tuple(new_m), "v": tuple(new_v), "t": t}, gnorm


def lr_schedule(tc: TrainConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / tc.warmup)
    prog = jnp.clip((step - tc.warmup) / max(1, tc.steps - tc.warmup), 0.0, 1.0)
    return tc.lr * warm * (0.5 * (1 + jnp.cos(jnp.pi * prog)))


def teacher_forced_accuracy(cfg, params, toks, targets, batch=16):
    """Exact-match accuracy of answer digits under teacher forcing."""
    hits = total = 0
    logits_all = []
    for i in range(0, toks.shape[0], batch):
        logits_all.append(
            np.asarray(model.forward_train(cfg, params, jnp.asarray(toks[i : i + batch])))
        )
    logits = np.concatenate(logits_all, axis=0)
    for row, tp, ans in targets:
        if int(np.argmax(logits[row, tp])) == ans:
            hits += 1
        total += 1
    return hits / max(1, total)


def train(cfg: ModelConfig, tc: TrainConfig, out_dir: str, log=print):
    rng = np.random.default_rng(tc.seed)
    key = jax.random.PRNGKey(tc.seed)
    params = model.init_params(cfg, key)
    opt = adamw_init(params)

    n_train_seqs = 512
    toks, mask = corpus.pack_sequences(rng, n_train_seqs, tc.seq_len)
    ev_toks, ev_targets = corpus.eval_batch(
        np.random.default_rng(tc.seed + 1), tc.eval_samples, tc.seq_len
    )

    loss_fn = lambda p, t, m: model.lm_loss(cfg, p, t, m)

    @jax.jit
    def step_fn(params, opt, batch_toks, batch_mask, lr):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch_toks, batch_mask)
        params, opt, gnorm = adamw_update(
            params, grads, opt, lr, tc.weight_decay, tc.clip
        )
        return params, opt, loss, gnorm

    history = []
    t0 = time.time()
    for step in range(tc.steps):
        idx = rng.integers(0, n_train_seqs, tc.batch_size)
        lr = lr_schedule(tc, step)
        params, opt, loss, gnorm = step_fn(
            params, opt, jnp.asarray(toks[idx]), jnp.asarray(mask[idx]), lr
        )
        if step % 10 == 0 or step == tc.steps - 1:
            rec = {
                "step": step,
                "loss": float(loss),
                "gnorm": float(gnorm),
                "lr": float(lr),
                "elapsed_s": round(time.time() - t0, 1),
            }
            if step % tc.eval_every == 0 or step == tc.steps - 1:
                rec["answer_acc"] = round(
                    teacher_forced_accuracy(cfg, params, ev_toks, ev_targets), 4
                )
            history.append(rec)
            log(f"  {rec}")

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        f.write(model.params_to_bytes(params))
    final_acc = teacher_forced_accuracy(cfg, params, ev_toks, ev_targets)
    meta = {
        "steps": tc.steps,
        "final_loss": history[-1]["loss"],
        "final_answer_acc": round(final_acc, 4),
        "wall_s": round(time.time() - t0, 1),
        "history": history,
    }
    with open(os.path.join(out_dir, "train_log.json"), "w") as f:
        json.dump(meta, f, indent=1)
    log(f"trained: loss={meta['final_loss']:.3f} answer_acc={final_acc:.3f} "
        f"({meta['wall_s']}s)")
    return params, meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="30-step smoke run")
    args = ap.parse_args()
    tc = TrainConfig()
    if args.quick:
        tc = TrainConfig(steps=30, eval_every=30)
    elif args.steps:
        tc = TrainConfig(steps=args.steps)
    train(ModelConfig(), tc, args.out)


if __name__ == "__main__":
    main()
