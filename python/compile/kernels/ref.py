"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: pytest/hypothesis assert the Pallas
kernels (interpret=True) match these within tolerance. They are also used by
train.py for the training-time forward pass (XLA fuses them well on CPU).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, slot_mask, k_new, v_new):
    """Single-token attention over a slot cache plus the current token.

    Args:
      q:         [B, H, dh]  query for the current token (RoPE applied).
      k_cache:   [B, H, S, dh] cached keys (RoPE applied at write time).
      v_cache:   [B, H, S, dh] cached values.
      slot_mask: [B, S] 1.0 for valid slots, 0.0 for empty/evicted.
      k_new:     [B, H, dh]  current token's key (attends to itself).
      v_new:     [B, H, dh]  current token's value.

    Returns:
      ctx:  [B, H, dh]  attention output (includes the self position).
      w:    [B, H, S]   normalized attention weights over cache slots only
                        (the self weight is part of the softmax denominator
                        but not exported — trackers score *cached* tokens).
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s_cache = jnp.einsum("bhd,bhsd->bhs", q, k_cache) * scale  # [B,H,S]
    s_cache = jnp.where(slot_mask[:, None, :] > 0, s_cache, NEG_INF)
    s_self = jnp.einsum("bhd,bhd->bh", q, k_new)[..., None] * scale  # [B,H,1]
    s_all = jnp.concatenate([s_cache, s_self], axis=-1)  # [B,H,S+1]
    m = jnp.max(s_all, axis=-1, keepdims=True)
    p = jnp.exp(s_all - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    w_all = p / denom
    w, w_self = w_all[..., :-1], w_all[..., -1:]
    ctx = jnp.einsum("bhs,bhsd->bhd", w, v_cache) + w_self * v_new
    return ctx, w


def prefill_attention_ref(q, k, v, valid_mask):
    """Causal attention over a padded prompt.

    Args:
      q, k, v:    [B, H, P, dh] (RoPE already applied to q and k).
      valid_mask: [B, P] 1.0 for real tokens, 0.0 for padding.

    Returns:
      ctx: [B, H, P, dh]
      w:   [B, H, P, P]  normalized weights (rows for padded queries are
                         garbage-but-finite; callers mask by valid_mask).
    """
    dh = q.shape[-1]
    P = q.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    causal = jnp.tril(jnp.ones((P, P), dtype=bool))
    s = jnp.where(causal[None, None], s, NEG_INF)
    s = jnp.where(valid_mask[:, None, None, :] > 0, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    w = p / jnp.sum(p, axis=-1, keepdims=True)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", w, v)
    return ctx, w
