"""Layer-1 Pallas attention kernels (interpret=True on CPU PJRT).

Two kernels implement the serving hot spot — masked decode attention over a
budget-bounded slot cache with attention-weight export (what makes TS/MRI
tracking affordable every step), plus a causal prefill kernel.

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation):
  * the decode kernel computes *cache-only* flash statistics (m, l) and an
    unnormalized ctx; the current token's self-position and the final
    normalization are merged in jnp (`merge_self`) — this keeps the kernel a
    pure HBM→VMEM streaming reduction, the shape a TPU wants.
  * single-block variant: one [S, dh] K/V tile per (batch, head) program —
    fits VMEM comfortably up to S=2048 (f32: 2·S·dh·4 = 1 MiB).
  * blocked variant (S > max_single_block): grid adds an S dimension;
    VMEM scratch carries the online-softmax (m, l, acc) across S-blocks,
    i.e. the flash-decoding split-K schedule expressed with BlockSpec.

All kernels must be lowered with interpret=True: real-TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
TINY = 1e-30


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------


def _decode_kernel_single(q_ref, k_ref, v_ref, mask_ref, ctx_ref, p_ref, norm_ref):
    """One (batch, head) program; the whole cache row in one VMEM tile.

    Outputs cache-only flash stats:
      ctx_ref:  [dh]  Σ p_j v_j / max(l, TINY)
      p_ref:    [S]   unnormalized exp(s_j - m) · mask_j
      norm_ref: [2]   (m, l)
    """
    q = q_ref[0, 0, :]  # [dh]
    k = k_ref[0, 0]  # [S, dh]
    v = v_ref[0, 0]  # [S, dh]
    mask = mask_ref[0]  # [S]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # [S]
    s = jnp.where(mask > 0, s, NEG_INF)
    m = jnp.max(s)
    p = jnp.exp(s - m) * mask  # masked lanes contribute exactly 0
    l = jnp.sum(p)
    ctx = jnp.dot(p, v, preferred_element_type=jnp.float32) / jnp.maximum(l, TINY)
    ctx_ref[0, 0, :] = ctx
    p_ref[0, 0, :] = p
    norm_ref[0, 0, 0] = m
    norm_ref[0, 0, 1] = l


def _decode_kernel_blocked(
    q_ref, k_ref, v_ref, mask_ref, ctx_ref, p_ref, mblk_ref, norm_ref, acc_ref, ml_ref
):
    """Grid (B, H, nS): online-softmax accumulation across S-blocks.

    Per-block outputs are *locally* shifted (exp(s - m_blk)); the jnp wrapper
    rescales them by exp(m_blk - m_final). VMEM scratch:
      acc_ref: [dh]   running Σ p v (rescaled on every new max)
      ml_ref:  [2]    running (m, l)
    """
    sb = pl.program_id(2)
    n_sb = pl.num_programs(2)

    q = q_ref[0, 0, :]
    k = k_ref[0, 0]  # [block_s, dh]
    v = v_ref[0, 0]
    mask = mask_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))

    @pl.when(sb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        ml_ref[0] = NEG_INF
        ml_ref[1] = 0.0

    s = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask > 0, s, NEG_INF)
    m_blk = jnp.max(s)
    p_blk = jnp.exp(s - m_blk) * mask  # local shift
    l_blk = jnp.sum(p_blk)

    m_prev, l_prev = ml_ref[0], ml_ref[1]
    m_new = jnp.maximum(m_prev, m_blk)
    alpha = jnp.exp(m_prev - m_new)  # rescale old accumulator
    beta = jnp.exp(m_blk - m_new)  # rescale this block
    l_new = l_prev * alpha + l_blk * beta
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p_blk, v, preferred_element_type=jnp.float32
    ) * beta
    ml_ref[0] = m_new
    ml_ref[1] = l_new

    p_ref[0, 0, :] = p_blk
    mblk_ref[0, 0, 0] = m_blk

    @pl.when(sb == n_sb - 1)
    def _fin():
        ctx_ref[0, 0, :] = acc_ref[...] / jnp.maximum(ml_ref[1], TINY)
        norm_ref[0, 0, 0] = ml_ref[0]
        norm_ref[0, 0, 1] = ml_ref[1]


def _decode_cache_single(q, k_cache, v_cache, slot_mask):
    B, H, S, dh = k_cache.shape
    f32 = jnp.float32
    return pl.pallas_call(
        _decode_kernel_single,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, S, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, S, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, S), lambda b, h: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, S), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, 2), lambda b, h: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, dh), f32),
            jax.ShapeDtypeStruct((B, H, S), f32),
            jax.ShapeDtypeStruct((B, H, 2), f32),
        ],
        interpret=True,
    )(q, k_cache, v_cache, slot_mask)


def _decode_cache_blocked(q, k_cache, v_cache, slot_mask, block_s):
    B, H, S, dh = k_cache.shape
    assert S % block_s == 0, f"cache size {S} not a multiple of block_s {block_s}"
    n_sb = S // block_s
    f32 = jnp.float32
    ctx, p, m_blk, norm = pl.pallas_call(
        _decode_kernel_blocked,
        grid=(B, H, n_sb),
        in_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda b, h, s: (b, h, s, 0)),
            pl.BlockSpec((1, block_s), lambda b, h, s: (b, s)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, dh), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, block_s), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, 1), lambda b, h, s: (b, h, s)),
            pl.BlockSpec((1, 1, 2), lambda b, h, s: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, dh), f32),
            jax.ShapeDtypeStruct((B, H, S), f32),
            jax.ShapeDtypeStruct((B, H, n_sb), f32),
            jax.ShapeDtypeStruct((B, H, 2), f32),
        ],
        scratch_shapes=[
            pltpu.VMEM((dh,), f32),
            pltpu.VMEM((2,), f32),
        ],
        interpret=True,
    )(q, k_cache, v_cache, slot_mask)
    # Rescale per-block local shifts to the global max.
    m = norm[..., 0:1]  # [B,H,1]
    scale = jnp.exp(jnp.repeat(m_blk, block_s, axis=-1) - m)
    return ctx, p * scale, norm


def merge_self(q, k_new, v_new, ctx_c, p_c, norm_c):
    """Fold the current token's self-position into cache-only flash stats.

    Returns (ctx, w): final attention output [B,H,dh] and normalized weights
    over cache slots [B,H,S] (self weight is in the denominator only).
    """
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    s_self = jnp.sum(q * k_new, axis=-1) * scale  # [B,H]
    m_c, l_c = norm_c[..., 0], norm_c[..., 1]
    m_f = jnp.maximum(m_c, s_self)
    a_c = jnp.exp(m_c - m_f)  # cache rescale
    a_s = jnp.exp(s_self - m_f)  # self rescale
    l_f = l_c * a_c + a_s
    ctx = (
        ctx_c * (l_c * a_c)[..., None] + a_s[..., None] * v_new
    ) / l_f[..., None]
    w = p_c * (a_c / l_f)[..., None]
    return ctx, w


def decode_attention(
    q, k_cache, v_cache, slot_mask, k_new, v_new, *, block_s=128, max_single_block=2048
):
    """Pallas decode attention; drop-in for ref.decode_attention_ref."""
    S = k_cache.shape[2]
    if S <= max_single_block:
        ctx_c, p_c, norm_c = _decode_cache_single(q, k_cache, v_cache, slot_mask)
    else:
        ctx_c, p_c, norm_c = _decode_cache_blocked(
            q, k_cache, v_cache, slot_mask, block_s
        )
    return merge_self(q, k_new, v_new, ctx_c, p_c, norm_c)


# ---------------------------------------------------------------------------
# Prefill attention
# ---------------------------------------------------------------------------


def _prefill_kernel(q_ref, k_ref, v_ref, mask_ref, ctx_ref, w_ref):
    """One (batch, head) program: full causal attention over a P-token tile."""
    q = q_ref[0, 0]  # [P, dh]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    mask = mask_ref[0]  # [P]
    P = q.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [P,P]
    rows = jax.lax.broadcasted_iota(jnp.int32, (P, P), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (P, P), 1)
    s = jnp.where(cols <= rows, s, NEG_INF)
    s = jnp.where(mask[None, :] > 0, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p * mask[None, :]
    # Diagonal is always valid for valid rows; for padded rows l can be 0.
    l = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), TINY)
    w = p / l
    ctx_ref[0, 0] = jnp.dot(w, v, preferred_element_type=jnp.float32)
    w_ref[0, 0] = w


def prefill_attention(q, k, v, valid_mask):
    """Pallas causal prefill; drop-in for ref.prefill_attention_ref.

    Padded-query rows return w rows that are zero except (possibly) valid
    columns; callers must mask by valid_mask — same contract as the oracle.
    """
    B, H, P, dh = q.shape
    f32 = jnp.float32
    return pl.pallas_call(
        _prefill_kernel,
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, P, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, P), lambda b, h: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, P, dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, P, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, P, dh), f32),
            jax.ShapeDtypeStruct((B, H, P, P), f32),
        ],
        interpret=True,
    )(q, k, v, valid_mask)
