"""Model and artifact configuration shared by the compile path.

The Rust side never imports this; everything it needs is emitted into
``artifacts/manifest.json`` by ``aot.py``.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

# Byte-level restricted charset. Index == token id. The Rust tokenizer
# (rust/src/tokenizer) reads this exact string from manifest.json.
CHARSET = "0123456789+-*=();ABCDEFGHIJKLMNOPQRSTUVWXYZ?.,# >\n"
VOCAB = len(CHARSET)  # 51
PAD_ID = CHARSET.index(" ")


@dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer dimensions (RoPE + RMSNorm + SwiGLU)."""

    vocab: int = VOCAB
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 2
    d_head: int = 64
    d_ff: int = 256
    rope_base: float = 10000.0
    # Pallas decode kernel: single-block up to this cache size, two-pass
    # blocked kernel above it.
    max_single_block: int = 2048
    block_s: int = 128

    @property
    def d_attn(self) -> int:
        return self.n_heads * self.d_head

    def param_specs(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Canonical (name, shape) order of the flat parameter tuple.

        This order IS the executable argument order and the layout of
        weights.bin; keep in sync with model.init_params / model.PARAM_ORDER.
        """
        specs: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model)),
        ]
        for l in range(self.n_layers):
            specs += [
                (f"l{l}.ln1", (self.d_model,)),
                (f"l{l}.wq", (self.d_model, self.d_attn)),
                (f"l{l}.wk", (self.d_model, self.d_attn)),
                (f"l{l}.wv", (self.d_model, self.d_attn)),
                (f"l{l}.wo", (self.d_attn, self.d_model)),
                (f"l{l}.ln2", (self.d_model,)),
                (f"l{l}.w_gate", (self.d_model, self.d_ff)),
                (f"l{l}.w_up", (self.d_model, self.d_ff)),
                (f"l{l}.w_down", (self.d_ff, self.d_model)),
            ]
        specs.append(("ln_f", (self.d_model,)))
        # Output head is tied to the embedding (embed.T); no extra param.
        return specs


@dataclass(frozen=True)
class ArtifactVariant:
    """One compiled executable variant."""

    kind: str  # step | stepp | append | gather | insert | prefill | trace | blockw | blockg
    batch: int
    cache: int  # number of KV slots S
    prefill: int = 0  # prompt bucket length P (prefill only)
    blocks: int = 0  # paged arena: number of blocks N (stepp/blockw/blockg)
    block: int = 0  # paged arena: tokens per block

    @property
    def name(self) -> str:
        if self.kind == "prefill":
            return f"prefill_b{self.batch}_s{self.cache}_p{self.prefill}"
        if self.kind == "stepp":
            return f"stepp_b{self.batch}_s{self.cache}_n{self.blocks}x{self.block}"
        if self.kind in ("blockw", "blockg"):
            return f"{self.kind}_n{self.blocks}x{self.block}"
        return f"{self.kind}_b{self.batch}_s{self.cache}"


@dataclass
class BuildConfig:
    """What `make artifacts` produces."""

    model: ModelConfig = field(default_factory=ModelConfig)
    # (batch, cache) engine shapes. cache = device slot capacity S.
    engine_shapes: List[Tuple[int, int]] = field(
        default_factory=lambda: [(1, 256), (4, 256), (1, 512), (1, 2048), (4, 1024)]
    )
    prefill_bucket: int = 64
    trace_cache: int = 512
    # Paged-KV arena geometry: tokens per block, and pool size as a multiple
    # of the dense per-shape footprint (blocks = batch * cache / block_size,
    # i.e. the same bytes the removed worst-case buffers would have held).
    pool_block_size: int = 16

    def variants(self) -> List[ArtifactVariant]:
        out: List[ArtifactVariant] = []
        for b, s in self.engine_shapes:
            out.append(ArtifactVariant("step", b, s))
            # fused variant: same step, pure-jnp (XLA-fused) attention —
            # 2.5x faster under CPU PJRT where Pallas runs interpreted
            # (EXPERIMENTS.md §Perf); numerics verified identical in tests.
            out.append(ArtifactVariant("stepf", b, s))
            out.append(ArtifactVariant("append", b, s))
            out.append(ArtifactVariant("gather", b, s))
            out.append(ArtifactVariant("insert", b, s))
            out.append(ArtifactVariant("prefill", 1, s, self.prefill_bucket))
            # paged-KV executables for this shape: arena sized to the same
            # bytes as the dense caches it replaces (block_size must divide
            # the cache so MB * block_size == S)
            bs = self.pool_block_size
            assert s % bs == 0, f"block size {bs} must divide cache {s}"
            n_blocks = b * s // bs
            out.append(ArtifactVariant("stepp", b, s, 0, n_blocks, bs))
            out.append(ArtifactVariant("blockw", 0, 0, 0, n_blocks, bs))
            out.append(ArtifactVariant("blockg", 0, 0, 0, n_blocks, bs))
        out.append(ArtifactVariant("trace", 1, self.trace_cache))
        # Dedup (prefill shared across batches with same cache; blockw/blockg
        # shared across shapes with the same arena geometry).
        seen, uniq = set(), []
        for v in out:
            if v.name not in seen:
                seen.add(v.name)
                uniq.append(v)
        return uniq


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    seq_len: int = 256
    batch_size: int = 24
    steps: int = 1500
    lr: float = 2e-3
    warmup: int = 60
    weight_decay: float = 0.01
    clip: float = 1.0
    eval_every: int = 50
    eval_samples: int = 64
