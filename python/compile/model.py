"""Layer-2 JAX model: byte-level decoder transformer (RoPE, RMSNorm, SwiGLU).

Parameters travel as a FLAT TUPLE in the canonical order given by
``ModelConfig.param_specs()`` — that order is the executable argument order
the Rust runtime replays from manifest.json, so never reorder it.

Functions lowered to artifacts (see aot.py):
  decode_step   one token for a whole batch over the slot cache
  decode_trace  batch-1 step that also exports per-layer/head attention
  prefill       bucketed prompt ingestion producing the initial caches
  append/gather/insert  single-output cache maintenance ops (device-chained)

The training forward (full causal, pure-jnp attention) lives here too so the
fwd/bwd used by train.py and the served decode path share every weight and
every layernorm — the decode path is the same function, incrementalized.
"""

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import attn as attn_kernels
from .kernels import ref as attn_ref

EPS = 1e-6


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Tuple[jnp.ndarray, ...]:
    """Initialize the flat parameter tuple (truncated-normal / ones)."""
    params: List[jnp.ndarray] = []
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2")) or name == "ln_f":
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "embed":
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * 0.02
            )
        else:
            fan_in = shape[0]
            params.append(
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return tuple(params)


def params_to_bytes(params: Sequence[jnp.ndarray]) -> bytes:
    import numpy as np

    return b"".join(np.asarray(p, np.float32).tobytes() for p in params)


def params_from_bytes(cfg: ModelConfig, raw: bytes) -> Tuple[jnp.ndarray, ...]:
    import numpy as np

    out, off = [], 0
    for _, shape in cfg.param_specs():
        n = int(np.prod(shape)) * 4
        arr = np.frombuffer(raw[off : off + n], np.float32).reshape(shape)
        out.append(jnp.asarray(arr))
        off += n
    if off != len(raw):
        raise ValueError(f"weights.bin size mismatch: used {off}, have {len(raw)}")
    return tuple(out)


class _P:
    """Name-indexed view over the flat tuple (compile-time sugar only)."""

    def __init__(self, cfg: ModelConfig, flat: Sequence[jnp.ndarray]):
        names = [n for n, _ in cfg.param_specs()]
        assert len(names) == len(flat), (len(names), len(flat))
        self._d = dict(zip(names, flat))

    def __getitem__(self, k: str) -> jnp.ndarray:
        return self._d[k]


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------


def rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + EPS)


def rope(x, pos, base: float):
    """Rotary embedding. x: [..., H, dh] with matching pos: [...] (int32)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -jnp.log(base) * jnp.arange(0, half, dtype=jnp.float32) / half
    )  # [half]
    angles = pos[..., None, None].astype(jnp.float32) * freqs  # [..., 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


def _split_heads(x, n_heads, d_head):
    return x.reshape(x.shape[:-1] + (n_heads, d_head))


# ---------------------------------------------------------------------------
# Decode step (the serving hot path)
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params, k_cache, v_cache, slot_mask, token, pos,
                *, full_attn: bool = False, use_pallas: bool = True):
    """One decode step for a batch.

    Args:
      params:    flat tuple (see param_specs).
      k_cache:   [B, L, H, S, dh] keys, RoPE applied at write time.
      v_cache:   [B, L, H, S, dh].
      slot_mask: [B, S] float 1/0.
      token:     [B] int32 current input token ids.
      pos:       [B] int32 absolute positions of `token`.

    Returns:
      logits:   [B, V]
      attn_agg: [B, S]  mean-over-layers of max-over-heads slot attention
                (or [B, L, H, S] when full_attn=True — the trace artifact).
      k_new:    [B, L, H, dh]  this token's keys (RoPE applied).
      v_new:    [B, L, H, dh]
    """
    p = _P(cfg, params)
    H, dh = cfg.n_heads, cfg.d_head
    x = p["embed"][token]  # [B, d]
    k_news, v_news, attn_maps = [], [], []
    attention = (
        functools.partial(
            attn_kernels.decode_attention,
            block_s=cfg.block_s,
            max_single_block=cfg.max_single_block,
        )
        if use_pallas
        else attn_ref.decode_attention_ref
    )
    for l in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{l}.ln1"])
        q = rope(_split_heads(h @ p[f"l{l}.wq"], H, dh), pos, cfg.rope_base)
        k_new = rope(_split_heads(h @ p[f"l{l}.wk"], H, dh), pos, cfg.rope_base)
        v_new = _split_heads(h @ p[f"l{l}.wv"], H, dh)
        ctx, w = attention(
            q, k_cache[:, l], v_cache[:, l], slot_mask, k_new, v_new
        )  # ctx [B,H,dh], w [B,H,S]
        x = x + ctx.reshape(ctx.shape[0], -1) @ p[f"l{l}.wo"]
        x = x + swiglu(rmsnorm(x, p[f"l{l}.ln2"]), p[f"l{l}.w_gate"],
                       p[f"l{l}.w_up"], p[f"l{l}.w_down"])
        k_news.append(k_new)
        v_news.append(v_new)
        attn_maps.append(w)
    logits = rmsnorm(x, p["ln_f"]) @ p["embed"].T  # tied head, [B, V]
    w_all = jnp.stack(attn_maps, axis=1)  # [B, L, H, S]
    if full_attn:
        attn_agg = w_all
    else:
        attn_agg = jnp.mean(jnp.max(w_all, axis=2), axis=1)  # [B, S]
    k_new = jnp.stack(k_news, axis=1)  # [B, L, H, dh]
    v_new = jnp.stack(v_news, axis=1)
    return logits, attn_agg, k_new, v_new


def decode_step_paged(cfg: ModelConfig, params, k_arena, v_arena, block_tables,
                      seq_lens, token, pos, *, use_pallas: bool = True):
    """One decode step reading K/V through per-row block tables (paged KV).

    Args:
      k_arena:      [N, bs, L, H, dh] pool-shaped key storage.
      v_arena:      [N, bs, L, H, dh].
      block_tables: [B, MB] int32 block ids per row (entries past a row's
                    mapped blocks may be -1; they are clipped, and their
                    rows masked out via seq_lens).
      seq_lens:     [B] int32 live token count per row (0 = inactive).
      token/pos:    as decode_step.

    Returns the decode_step outputs with S = MB * bs: the device-side
    gather materializes each row's view from the arena, then the same
    attention path (Pallas kernel included) runs over it. `bs` must divide
    the engine cache size so MB * bs == S.
    """
    N, bs, L, H, dh = k_arena.shape
    B, MB = block_tables.shape
    S = MB * bs
    tbl = jnp.clip(block_tables, 0, N - 1).reshape(-1)  # [B*MB]

    def through_tables(arena):
        g = jnp.take(arena, tbl, axis=0)                # [B*MB, bs, L, H, dh]
        g = g.reshape(B, S, L, H, dh)
        return g.transpose(0, 2, 3, 1, 4)               # [B, L, H, S, dh]

    k_cache = through_tables(k_arena)
    v_cache = through_tables(v_arena)
    slot_mask = (
        jnp.arange(S, dtype=jnp.int32)[None, :] < seq_lens[:, None]
    ).astype(jnp.float32)                               # [B, S]
    return decode_step(cfg, params, k_cache, v_cache, slot_mask, token, pos,
                       use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, tokens, valid_mask, cache_slots: int,
            *, use_pallas: bool = True):
    """Ingest a padded prompt bucket.

    Args:
      tokens:     [B, P] int32 (padded with arbitrary ids past the length).
      valid_mask: [B, P] float 1/0.
      cache_slots: S — capacity of the target cache (S >= P).

    Returns:
      k_cache: [B, L, H, S, dh]  slots [0, P) filled, rest zero.
      v_cache: [B, L, H, S, dh]
      attn_last: [B, P]  last-valid-row attention, aggregated like decode
                 (initializes the importance tracker for prompt tokens).
      logits_last: [B, V]  logits at the last valid position.
    """
    p = _P(cfg, params)
    B, P = tokens.shape
    H, dh = cfg.n_heads, cfg.d_head
    S = cache_slots
    assert S >= P
    pos = jnp.arange(P, dtype=jnp.int32)[None, :].repeat(B, axis=0)  # [B,P]
    attention = attn_kernels.prefill_attention if use_pallas else attn_ref.prefill_attention_ref
    x = p["embed"][tokens]  # [B, P, d]
    ks, vs, attn_maps = [], [], []
    for l in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{l}.ln1"])
        q = rope(_split_heads(h @ p[f"l{l}.wq"], H, dh), pos, cfg.rope_base)
        k = rope(_split_heads(h @ p[f"l{l}.wk"], H, dh), pos, cfg.rope_base)
        v = _split_heads(h @ p[f"l{l}.wv"], H, dh)
        # kernels take [B, H, P, dh]
        ctx, w = attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), valid_mask
        )  # ctx [B,H,P,dh], w [B,H,P,P]
        x = x + ctx.transpose(0, 2, 1, 3).reshape(B, P, -1) @ p[f"l{l}.wo"]
        x = x + swiglu(rmsnorm(x, p[f"l{l}.ln2"]), p[f"l{l}.w_gate"],
                       p[f"l{l}.w_up"], p[f"l{l}.w_down"])
        ks.append(k.transpose(0, 2, 1, 3))  # [B,H,P,dh]
        vs.append(v.transpose(0, 2, 1, 3))
        attn_maps.append(w)
    x = rmsnorm(x, p["ln_f"])
    last = (jnp.sum(valid_mask, axis=1).astype(jnp.int32) - 1).clip(0)  # [B]
    logits_last = jnp.take_along_axis(
        x, last[:, None, None], axis=1
    ).squeeze(1) @ p["embed"].T
    w_all = jnp.stack(attn_maps, axis=1)  # [B, L, H, P, P]
    w_last = jnp.take_along_axis(
        w_all, last[:, None, None, None, None], axis=3
    ).squeeze(3)  # [B, L, H, P]
    attn_last = jnp.mean(jnp.max(w_last, axis=2), axis=1) * valid_mask  # [B, P]
    k_cache = jnp.stack(ks, axis=1)  # [B, L, H, P, dh]
    v_cache = jnp.stack(vs, axis=1)
    pad = [(0, 0), (0, 0), (0, 0), (0, S - P), (0, 0)]
    # Zero out padded-token K/V so stale contents never alias a real slot.
    k_cache = jnp.pad(k_cache * valid_mask[:, None, None, :, None], pad)
    v_cache = jnp.pad(v_cache * valid_mask[:, None, None, :, None], pad)
    return k_cache, v_cache, attn_last, logits_last


# ---------------------------------------------------------------------------
# Cache maintenance ops (single-output => device-chainable buffers)
# ---------------------------------------------------------------------------


def cache_append(cache, new, idx):
    """Write new [B, L, H, dh] into slot idx[b] of cache [B, L, H, S, dh]."""

    def one(c, n, i):
        return jax.lax.dynamic_update_slice(c, n[:, :, None, :], (0, 0, i, 0))

    return jax.vmap(one)(cache, new, idx)


def cache_gather(cache, idx):
    """Permute/compact slots: out[b, :, :, j] = cache[b, :, :, idx[b, j]]."""

    def one(c, ix):
        return jnp.take(c, ix, axis=2)

    return jax.vmap(one)(cache, idx)


def cache_insert(cache, seq, b):
    """Insert a single sequence cache [L, H, S, dh] at batch row b."""
    return jax.lax.dynamic_update_slice(
        cache, seq[None], (b, 0, 0, 0, 0)
    )


def arena_row_write(arena, row, slot):
    """Write one [L, H, dh] K or V row at linear slot block*bs + off of a
    [N, bs, L, H, dh] arena. Single-output (device-chainable buffer)."""
    N, bs, L, H, dh = arena.shape
    flat = arena.reshape(N * bs, L, H, dh)
    out = jax.lax.dynamic_update_slice(flat, row[None], (slot, 0, 0, 0))
    return out.reshape(N, bs, L, H, dh)


def arena_row_gather(arena, idx):
    """Permute arena rows by a [N*bs] linear index: out[j] = in[idx[j]].

    One executable serves both copy-on-write block duplication (idx maps the
    fresh block's rows to the shared source's) and eviction compaction (idx
    relocates every surviving row); gather reads the whole input before the
    output exists, so overlapping src/dst need no two-phase staging."""
    N, bs, L, H, dh = arena.shape
    flat = arena.reshape(N * bs, L, H, dh)
    return jnp.take(flat, idx, axis=0).reshape(N, bs, L, H, dh)


# ---------------------------------------------------------------------------
# Training forward / loss (fwd+bwd used by train.py)
# ---------------------------------------------------------------------------


def forward_train(cfg: ModelConfig, params, tokens):
    """Full causal forward over packed sequences. tokens: [B, T] → [B, T, V]."""
    p = _P(cfg, params)
    B, T = tokens.shape
    H, dh = cfg.n_heads, cfg.d_head
    pos = jnp.arange(T, dtype=jnp.int32)[None, :].repeat(B, axis=0)
    ones = jnp.ones((B, T), jnp.float32)
    x = p["embed"][tokens]
    for l in range(cfg.n_layers):
        h = rmsnorm(x, p[f"l{l}.ln1"])
        q = rope(_split_heads(h @ p[f"l{l}.wq"], H, dh), pos, cfg.rope_base)
        k = rope(_split_heads(h @ p[f"l{l}.wk"], H, dh), pos, cfg.rope_base)
        v = _split_heads(h @ p[f"l{l}.wv"], H, dh)
        ctx, _ = attn_ref.prefill_attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), ones
        )
        x = x + ctx.transpose(0, 2, 1, 3).reshape(B, T, -1) @ p[f"l{l}.wo"]
        x = x + swiglu(rmsnorm(x, p[f"l{l}.ln2"]), p[f"l{l}.w_gate"],
                       p[f"l{l}.w_up"], p[f"l{l}.w_down"])
    return rmsnorm(x, p["ln_f"]) @ p["embed"].T


def lm_loss(cfg: ModelConfig, params, tokens, loss_mask=None):
    """Next-token cross-entropy; optional [B, T-1] mask over target slots."""
    logits = forward_train(cfg, params, tokens)[:, :-1]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).squeeze(-1)
    if loss_mask is None:
        return jnp.mean(nll)
    return jnp.sum(nll * loss_mask) / jnp.maximum(jnp.sum(loss_mask), 1.0)
