fn main() { println!("bench stub: table7"); }
