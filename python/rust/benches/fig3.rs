fn main() { println!("bench stub: fig3"); }
