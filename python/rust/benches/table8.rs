fn main() { println!("bench stub: table8"); }
