fn main() { println!("bench stub: table3"); }
