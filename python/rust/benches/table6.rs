fn main() { println!("bench stub: table6"); }
