fn main() { println!("bench stub: table5"); }
