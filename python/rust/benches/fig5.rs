fn main() { println!("bench stub: fig5"); }
