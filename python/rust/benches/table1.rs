fn main() { println!("bench stub: table1"); }
