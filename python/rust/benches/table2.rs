fn main() { println!("bench stub: table2"); }
