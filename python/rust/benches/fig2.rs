fn main() { println!("bench stub: fig2"); }
