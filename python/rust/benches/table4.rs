fn main() { println!("bench stub: table4"); }
