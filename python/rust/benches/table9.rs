fn main() { println!("bench stub: table9"); }
