fn main() { println!("bench stub: fig6"); }
