fn main() { println!("bench stub: table10"); }
