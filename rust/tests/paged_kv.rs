//! Physical paged-KV regression tests (public API, sim backend).
//!
//! The two properties this file pins down:
//!  1. a prefix-cache hit performs ZERO prefill backend executions, and
//!  2. a copy-on-write of a shared partial tail block duplicates the real
//!     K/V bytes — so after one appended token the fork's tail block
//!     diverges from the donor's, while the donor's bytes are untouched.

use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::kvpool::{BlockPool, BlockTable, PoolConfig};
use lazyeviction::runtime::{DecodeBackend, SimBackend};

fn pool(n_blocks: usize, block_size: usize) -> BlockPool {
    BlockPool::new(PoolConfig {
        block_size,
        n_blocks,
        low_watermark: 0,
        high_watermark: 0,
    })
    .unwrap()
}

/// Distinct, recognizable rows for slot `i` of a test sequence.
fn row_for(re: usize, tag: f32, i: usize) -> (Vec<f32>, Vec<f32>) {
    let k: Vec<f32> = (0..re).map(|j| tag + i as f32 + j as f32 * 0.01).collect();
    let v: Vec<f32> = k.iter().map(|x| -x).collect();
    (k, v)
}

#[test]
fn cow_tail_block_bytes_diverge_from_donor_after_one_push() {
    let mut backend = SimBackend::new(1, 32);
    backend.init_paged(8, 4).unwrap();
    let re = backend.dims().n_layers * backend.dims().n_heads * backend.dims().d_head;
    let mut p = pool(8, 4);

    // donor: 8 tokens = 2 full blocks, bytes written through its table
    let mut donor = BlockTable::new(4);
    for i in 0..8 {
        assert!(donor.push_token(&mut p));
        let (blk, off) = donor.locate(i).unwrap();
        let (k, v) = row_for(re, 100.0, i);
        backend.write_kv_rows(blk, off, &k, &v).unwrap();
    }
    let donor_blk0 = donor.blocks()[0];

    // fork the whole prefix, then truncate into the middle of block 0:
    // the tail block is now shared AND partial
    let mut fork = BlockTable::fork_prefix(&donor, 8, &mut p);
    fork.truncate(2, &mut p);
    assert!(fork.tail_is_shared(&p));

    // one appended token: the push CoWs the shared tail and reports the
    // byte duplication; apply it, then write the new token's row
    let mut copies = Vec::new();
    assert!(fork.push_token_cow(&mut p, &mut copies));
    assert_eq!(copies.len(), 1);
    assert_eq!(copies[0].src, donor_blk0);
    assert_eq!(copies[0].rows, 2, "only the occupied prefix is duplicated");
    let fork_blk = copies[0].dst;
    assert_ne!(fork_blk, donor_blk0);
    backend.copy_block(copies[0]).unwrap();
    let (k_new, v_new) = row_for(re, 500.0, 2);
    backend.write_kv_rows(fork_blk, 2, &k_new, &v_new).unwrap();

    // the shared prefix rows were copied byte-for-byte...
    for i in 0..2 {
        let (dk, dv) = backend.debug_kv_row(donor_blk0, i).unwrap();
        let (fk, fv) = backend.debug_kv_row(fork_blk, i).unwrap();
        assert_eq!(dk, fk, "prefix row {i} must match after CoW");
        assert_eq!(dv, fv);
    }
    // ...the appended row makes the fork's tail block diverge...
    let (dk2, dv2) = backend.debug_kv_row(donor_blk0, 2).unwrap();
    let (fk2, fv2) = backend.debug_kv_row(fork_blk, 2).unwrap();
    assert_ne!(dk2, fk2, "fork tail K must diverge after one appended token");
    assert_ne!(dv2, fv2, "fork tail V must diverge after one appended token");
    // ...and the donor's bytes are exactly what was written originally
    let (want_k, want_v) = row_for(re, 100.0, 2);
    assert_eq!(dk2, want_k, "donor bytes must be untouched by the fork");
    assert_eq!(dv2, want_v);

    fork.release_all(&mut p);
    donor.release_all(&mut p);
    assert_eq!(p.free_blocks(), 8);
}

#[test]
fn prefix_hit_runs_zero_prefill_backend_calls() {
    let cfg = EngineConfig {
        batch: 2,
        cache: 64,
        budget: 48,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks: 24,
            low_watermark: 0,
            high_watermark: 0,
        }),
        ..Default::default()
    };
    let mut e = Engine::new_sim(cfg).unwrap();
    let req = |id| Request {
        id,
        prompt: "#A=3;B=7;C=2;\n>".into(),
        template: String::new(),
        max_new: 24,
        resume: None,
    };
    let cold = e.run_all(vec![req(1)]).unwrap();
    let after_cold = e.exec_counts();
    assert_eq!(after_cold.prefill, 1);
    assert!(after_cold.row_writes > 0, "paged prefill scatters K/V rows");

    // three identical admissions: every one skips prefill
    let warm = e.run_all(vec![req(2), req(3), req(4)]).unwrap();
    let after_warm = e.exec_counts();
    assert_eq!(
        after_warm.prefill, 1,
        "prefix hits must perform zero prefill backend calls"
    );
    let g = e.pool_gauges().unwrap();
    assert_eq!(g.prefix_prefill_skips, 3);
    for w in &warm {
        assert_eq!(w.text, cold[0].text, "request {} diverged", w.id);
    }
    // physical byte accounting rides the pool, not batch x max_len:
    // 24 blocks x 8 tokens x (2 layers * 2 heads * 4 dh) x 2 (K+V) x 4 bytes
    assert_eq!(g.kv_arena_bytes, 24 * 8 * 16 * 2 * 4);
    assert!(g.kv_bytes_in_use <= g.kv_arena_bytes);
}
