//! Runtime invariant audits over real engine traffic (ISSUE 9 acceptance).
//!
//! The unit tests in `kvpool::audit` prove each conservation law *trips* on
//! injected violations; this suite proves the laws *hold* on the live
//! engine across every pool/tier scenario the stack serves — steady pooled
//! decode, prefix sharing, recompute- and swap-mode preemption, tier
//! demotion/promotion, and client aborts. Each scenario audits at step
//! boundaries (non-strict while preempted snapshots ride the caller's
//! queue, with the queue passed as `external` so pins stay attributed) and
//! strictly after the drain, when every pinned tier byte must be owned.
//! In debug builds the engine additionally self-audits inside every
//! `step()`, so a mid-step violation fails these runs even between the
//! explicit checkpoints.

use std::collections::VecDeque;

use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::kvpool::{PoolConfig, PrefixCacheConfig};
use lazyeviction::kvtier::HostTierConfig;

fn mk(id: u64, max_new: usize) -> Request {
    Request {
        id,
        prompt: "#A=3;B=7;\n>".into(),
        template: String::new(),
        max_new,
        resume: None,
    }
}

fn pooled_cfg(batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: "lazy".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 0,
            high_watermark: 0,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

/// Drive requests to completion serve-loop style, auditing at every step
/// with the pending queue visible, then strictly at the drain.
fn drive_audited(e: &mut Engine, reqs: Vec<Request>) -> usize {
    let mut pending: VecDeque<Request> = reqs.into_iter().collect();
    let mut finished = 0usize;
    let mut steps = 0usize;
    loop {
        while !pending.is_empty() && e.has_free_row() {
            let r = pending.front().expect("nonempty").clone();
            if !e.submit(r, 0.0).expect("submit") {
                break; // pool pressure: hold and retry next step
            }
            pending.pop_front();
        }
        if e.active() == 0 && pending.is_empty() {
            break;
        }
        finished += e.step().expect("step").len();
        e.drain_token_events();
        for r in e.take_preempted().into_iter().rev() {
            pending.push_front(r);
        }
        // every snapshot is either in a row or in our queue: with the
        // queue passed as external, even the pin direction is exact
        let external: Vec<&Request> = pending.iter().collect();
        e.audit_invariants(&external, true, "audited drive step");
        steps += 1;
        assert!(steps < 10_000, "scenario failed to converge");
    }
    e.audit_invariants(&[], true, "audited drive drain");
    finished
}

#[test]
fn steady_pooled_decode_holds_every_law() {
    let mut e = Engine::new_sim(pooled_cfg(4, 64)).unwrap();
    let n = drive_audited(&mut e, (0..4).map(|i| mk(i, 50)).collect());
    assert_eq!(n, 4);
}

#[test]
fn prefix_sharing_accounts_every_fork() {
    // identical prompts across a batch: cache entries and row forks hold
    // overlapping references, the exact case refcount conservation is for
    let mut cfg = pooled_cfg(2, 64);
    cfg.prefix_cache = Some(PrefixCacheConfig::default());
    let mut e = Engine::new_sim(cfg).unwrap();
    let n = drive_audited(&mut e, (0..6).map(|i| mk(i, 40)).collect());
    assert_eq!(n, 6);
    let g = e.pool_gauges().expect("pooled engine");
    assert!(g.prefix_hits > 0, "the scenario must actually share");
}

#[test]
fn recompute_preemption_round_trip_stays_conserved() {
    // 9 blocks behind 2 rows: contention guarantees preemption, and the
    // snapshot round trip (engine -> queue -> resume) is where stale
    // table references would surface as refcount drift
    let mut e = Engine::new_sim(pooled_cfg(2, 9)).unwrap();
    let n = drive_audited(&mut e, (0..3).map(|i| mk(i, 50)).collect());
    assert_eq!(n, 3);
    assert!(e.metrics.preemptions > 0, "the scenario must preempt");
    assert!(e.metrics.resumes > 0);
}

#[test]
fn tier_demotion_promotion_conserves_bytes() {
    let mut cfg = pooled_cfg(1, 16);
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    let mut e = Engine::new_sim(cfg).unwrap();
    let n = drive_audited(&mut e, vec![mk(0, 60)]);
    assert_eq!(n, 1);
    assert!(e.metrics.demoted_blocks > 0, "evictions must park blocks");
    assert!(e.metrics.promotions > 0, "recurrence must promote");
}

#[test]
fn swap_preemption_pins_are_owned_end_to_end() {
    let mut cfg = pooled_cfg(2, 9);
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    cfg.preempt_mode = PreemptMode::Swap;
    let mut e = Engine::new_sim(cfg).unwrap();
    let n = drive_audited(&mut e, (0..3).map(|i| mk(i, 50)).collect());
    assert_eq!(n, 3);
    assert!(e.metrics.swap_preempts > 0, "the scenario must swap-preempt");
    assert_eq!(
        e.pool_gauges().expect("pooled").parked_blocks,
        0,
        "a drained engine must hold no parked tier state"
    );
}

#[test]
fn client_abort_releases_everything_it_owned() {
    let mut cfg = pooled_cfg(2, 64);
    cfg.prefix_cache = Some(PrefixCacheConfig::default());
    let mut e = Engine::new_sim(cfg).unwrap();
    assert!(e.submit(mk(0, 200), 0.0).unwrap());
    assert!(e.submit(mk(1, 40), 0.0).unwrap());
    for _ in 0..5 {
        e.step().unwrap();
        e.drain_token_events();
    }
    assert!(e.abort_request(0), "request 0 is mid-decode");
    e.audit_invariants(&[], true, "post-abort");
    // the survivor must still run to completion on conserved state
    let mut finished = 0;
    while e.active() > 0 {
        finished += e.step().unwrap().len();
        e.drain_token_events();
    }
    e.audit_invariants(&[], true, "post-abort drain");
    assert_eq!(finished, 1);
}
