//! Tiered-KV end-to-end: the host spill tier under real serving traffic.
//!
//! Two properties the tier must deliver (ISSUE 5 acceptance):
//!
//! * swap-out → swap-in round trips are byte-identical — pinned by driving
//!   serve past pool exhaustion in swap preempt-mode over TCP (every
//!   response must match a solo control byte for byte, and the sim
//!   backend's stored-key identity check makes corrupted swapped bytes
//!   derail recurrence tracking rather than pass silently), and by a
//!   promotion run whose live K/V rows are compared byte-for-byte against
//!   a never-evicted FullKV control;
//! * the recurrence phenomenon is *served*: a lazy run on the deterministic
//!   recurrence-heavy sim trace reports `promotions > 0` with zero output
//!   divergence.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::util::json::Json;

fn tier_cfg(batch: usize, n_blocks: usize, mode: PreemptMode) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: "lazy".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 0,
            high_watermark: 0,
        }),
        host_tier: Some(HostTierConfig { max_bytes: 1 << 20 }),
        preempt_mode: mode,
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

fn serve_on(addr: &'static str, engine_cfg: EngineConfig, shutdown: &Arc<AtomicBool>) {
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = Engine::new_sim(engine_cfg).expect("sim engine");
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            drop(s);
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    panic!("server did not come up within 4s");
}

fn solo_text(max_new: usize) -> String {
    let mut cfg = tier_cfg(1, 16, PreemptMode::Recompute);
    cfg.host_tier = None;
    let mut e = Engine::new_sim(cfg).unwrap();
    let r = e
        .run_all(vec![Request {
            id: 0,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            resume: None,
        }])
        .unwrap();
    r[0].text.clone()
}

#[test]
fn swap_mode_serving_past_exhaustion_is_byte_identical() {
    // 9 blocks behind 2 rows: two ~6-block rows near budget must collide,
    // so swap-mode preemption fires under real serving traffic. Every
    // client's output must equal the uncontended solo control — which can
    // only hold if the swap-out → swap-in round trips preserved the bytes
    // (the resumed rows decode on exactly the restored K/V).
    let addr = "127.0.0.1:8957";
    let shutdown = Arc::new(AtomicBool::new(false));
    serve_on(addr, tier_cfg(2, 9, PreemptMode::Swap), &shutdown);
    let solo = solo_text(50);

    let mut handles = Vec::new();
    for _ in 0..4u32 {
        handles.push(std::thread::spawn(move || -> String {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(&stream, r#"{{"prompt":"#A=3;B=7;\n>","max_new":50}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        }));
    }
    let mut max_swap_out = 0usize;
    let mut max_swap_in = 0usize;
    let mut max_swaps = 0usize;
    for h in handles {
        let line = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(j.get("error").is_none(), "server returned an error: {line}");
        assert_eq!(j.usize_at("tokens").unwrap(), 50);
        assert_eq!(
            j.str_at("text").unwrap(),
            solo,
            "a swap round trip corrupted this row"
        );
        let pool = j.req("pool").expect("pool gauges attached");
        max_swap_out = max_swap_out.max(pool.usize_at("swap_out_bytes").unwrap());
        max_swap_in = max_swap_in.max(pool.usize_at("swap_in_bytes").unwrap());
        max_swaps = max_swaps.max(pool.usize_at("swap_preempts").unwrap());
        assert_eq!(
            pool.usize_at("recomputed_tokens").unwrap(),
            0,
            "swap mode must not pay recompute"
        );
    }
    assert!(max_swaps >= 1, "the contended pool must swap-preempt");
    assert!(max_swap_out > 0 && max_swap_in > 0);
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn promotion_after_eviction_matches_never_evicted_control() {
    // A lazy run with the tier on: eviction parks blocks, recurrence brings
    // some back. Every live slot — promoted ones included — must then hold
    // exactly the K/V bytes a never-evicted FullKV control holds for the
    // same position (the sim stores the birth position inside the key row,
    // so any mis-restored byte shows up here).
    let mut e = Engine::new_sim(tier_cfg(1, 16, PreemptMode::Recompute)).unwrap();
    assert!(e
        .submit(
            Request {
                id: 1,
                prompt: "#A=3;B=7;\n>".into(),
                template: String::new(),
                max_new: 60,
                resume: None,
            },
            0.0,
        )
        .unwrap());
    let mut c = Engine::new_sim(EngineConfig {
        batch: 1,
        cache: 128,
        budget: 120,
        policy: "full".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        }),
        ..Default::default()
    })
    .unwrap();
    assert!(c
        .submit(
            Request {
                id: 1,
                prompt: "#A=3;B=7;\n>".into(),
                template: String::new(),
                max_new: 60,
                resume: None,
            },
            0.0,
        )
        .unwrap());
    for _ in 0..52 {
        e.step().unwrap();
        c.step().unwrap();
    }
    let g = e.pool_gauges().unwrap();
    assert!(g.demoted_blocks > 0, "evictions must park blocks");
    assert!(g.promotions > 0, "recurrence must promote parked tokens back");
    assert!(g.false_evictions_avoided > 0);

    let control: HashMap<u32, (u32, usize)> = c
        .debug_row_slots(0)
        .unwrap()
        .into_iter()
        .map(|(pos, b, o)| (pos, (b, o)))
        .collect();
    let slots = e.debug_row_slots(0).unwrap();
    assert!(!slots.is_empty());
    for (pos, blk, off) in slots {
        let (k, v) = e.backend_kv_row(blk, off).unwrap();
        let &(cb, co) = control.get(&pos).expect("control keeps every position");
        let (ck, cv) = c.backend_kv_row(cb, co).unwrap();
        assert_eq!(k, ck, "pos {pos}: K bytes diverged across the tier");
        assert_eq!(v, cv, "pos {pos}: V bytes diverged across the tier");
        assert_eq!(k[0] as u32, pos, "stored-key identity check");
    }
}

#[test]
fn tiered_serving_reports_promotions_with_identical_output() {
    // The serving-visible half of the promotion acceptance: a lazy run over
    // TCP with the tier on completes with byte-identical output and its
    // pool gauges report promotions > 0.
    let addr = "127.0.0.1:8958";
    let shutdown = Arc::new(AtomicBool::new(false));
    serve_on(addr, tier_cfg(1, 16, PreemptMode::Recompute), &shutdown);
    let solo = solo_text(60);
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(&stream, r#"{{"prompt":"#A=3;B=7;\n>","max_new":60}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).expect("json response line");
    assert!(j.get("error").is_none(), "server returned an error: {line}");
    assert_eq!(j.str_at("text").unwrap(), solo, "the tier changed the output");
    let pool = j.req("pool").expect("pool gauges attached");
    assert!(pool.usize_at("demoted_blocks").unwrap() > 0);
    assert!(
        pool.usize_at("promotions").unwrap() > 0,
        "a recurrence-heavy lazy run must promote: {line}"
    );
    assert!(pool.usize_at("false_evictions_avoided").unwrap() > 0);
    shutdown.store(true, Ordering::Relaxed);
}
