//! Fleet serving end-to-end over localhost TCP — the multi-engine router
//! acceptance tests. A 3-replica fleet must: (1) generate byte-identical
//! output to a single-engine control for every eviction policy (routing
//! changes placement, never content); (2) route repeats of a prompt to the
//! same replica, observable as per-replica `prefix_hits` concentration in
//! the labeled `/metrics` exposition plus `routed_affinity` counters; (3)
//! contain a mid-decode disconnect to the victim's home replica — its
//! blocks and tier bytes reclaimed, every other replica untouched; and
//! (4) survive a replica kill mid-serve with every in-flight request
//! either finished on a survivor or deterministically failed — no hung
//! connections.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::scheduler::Routing;
use lazyeviction::server::FleetOptions;
use lazyeviction::telemetry::{spawn_metrics_listener, Telemetry};
use lazyeviction::util::json::Json;

// pool_e2e.rs owns 8953-8956, telemetry_e2e.rs 8960-8963, streaming_e2e.rs
// 8970-8977; this binary uses 8980-8995 so all four run in parallel.
const IDENTITY_PORTS: [(&str, &str, &str); 4] = [
    ("full", "127.0.0.1:8980", "127.0.0.1:8984"),
    ("h2o", "127.0.0.1:8981", "127.0.0.1:8985"),
    ("tova", "127.0.0.1:8982", "127.0.0.1:8986"),
    ("lazy", "127.0.0.1:8983", "127.0.0.1:8987"),
];
const AFFINITY_ADDR: &str = "127.0.0.1:8988";
const AFFINITY_METRICS: &str = "127.0.0.1:8989";
const DISCONNECT_ADDR: &str = "127.0.0.1:8990";
const DISCONNECT_METRICS: &str = "127.0.0.1:8991";
const KILL_ADDR: &str = "127.0.0.1:8992";
const KILL_METRICS: &str = "127.0.0.1:8993";
const ORPHAN_ADDR: &str = "127.0.0.1:8994";
const ORPHAN_METRICS: &str = "127.0.0.1:8995";

fn pooled_cfg(policy: &str, batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: policy.into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 2,
            high_watermark: 4,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

/// Spawn an N-replica fleet for `cfg` and wait for its listener.
fn serve_fleet_on(
    addr: &'static str,
    cfg: EngineConfig,
    replicas: usize,
    opts: FleetOptions,
    shutdown: &Arc<AtomicBool>,
    telemetry: Option<Arc<Telemetry>>,
) {
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engines: Vec<Engine> = (0..replicas)
                .map(|_| Engine::new_sim(cfg.clone()).expect("sim engine"))
                .collect();
            let _ = lazyeviction::server::serve_fleet(engines, addr, shutdown, telemetry, opts);
        });
    }
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            drop(s);
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("fleet server did not come up within 4s");
}

/// One request → one terminal line on a fresh connection.
fn roundtrip(addr: &str, request: &str) -> Json {
    let stream = TcpStream::connect(addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(&stream, "{request}").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).expect("terminal line");
    Json::parse(&line).unwrap_or_else(|e| panic!("bad reply '{line}': {e}"))
}

/// One HTTP/1.0 exchange against the scrape listener → body.
fn http_get_body(addr: &str, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect scrape listener");
    write!(stream, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read scrape response");
    buf.split_once("\r\n\r\n").expect("head/body").1.to_string()
}

/// Value of the unlabeled `name value` sample, if present.
fn metric(body: &str, name: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        l.strip_prefix(name)?
            .strip_prefix(' ')?
            .trim()
            .parse::<f64>()
            .ok()
    })
}

/// Value of the per-replica `name{replica="i"} value` sample, if present.
fn labeled_metric(body: &str, name: &str, replica: usize) -> Option<f64> {
    let key = format!("{name}{{replica=\"{replica}\"}}");
    body.lines().find_map(|l| {
        l.strip_prefix(&key)?
            .strip_prefix(' ')?
            .trim()
            .parse::<f64>()
            .ok()
    })
}

#[test]
fn fleet_output_is_byte_identical_to_single_engine_control() {
    // For each policy: the same prompts through a single-engine control
    // and a 3-replica fleet. Whatever replica the router picks runs the
    // identical engine config, so every byte of every response must match.
    let prompts = [
        r#"{"prompt":"#A=3;B=7;\n>","max_new":32}"#,
        r#"{"prompt":"#C=2;D=9;E=4;\n>","max_new":24}"#,
    ];
    for (policy, control_addr, fleet_addr) in IDENTITY_PORTS {
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let cfg = pooled_cfg(policy, 2, 16);
            let shutdown = shutdown.clone();
            std::thread::spawn(move || {
                let engine = Engine::new_sim(cfg).expect("sim engine");
                let _ = lazyeviction::server::serve(engine, control_addr, shutdown);
            });
        }
        serve_fleet_on(
            fleet_addr,
            pooled_cfg(policy, 2, 16),
            3,
            FleetOptions::default(),
            &shutdown,
            None,
        );
        for _ in 0..200 {
            if TcpStream::connect(control_addr).is_ok() {
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }

        for request in prompts {
            let control = roundtrip(control_addr, request);
            let fleet = roundtrip(fleet_addr, request);
            assert!(
                control.get("error").is_none() && fleet.get("error").is_none(),
                "policy {policy}: request failed: {control:?} / {fleet:?}"
            );
            assert_eq!(
                fleet.str_at("text").unwrap(),
                control.str_at("text").unwrap(),
                "policy {policy}: fleet output diverged from control"
            );
            assert_eq!(
                fleet.usize_at("tokens").unwrap(),
                control.usize_at("tokens").unwrap(),
                "policy {policy}: token counts diverged"
            );
        }
        shutdown.store(true, Ordering::Relaxed);
    }
}

#[test]
fn identical_prompts_concentrate_on_one_replica() {
    // Three distinct prompt groups, four requests each, sequential. The
    // router's first sight of a group places it by pressure; every repeat
    // must follow it home (sticky map / digest match). Each repeat then
    // hits the home replica's prefix cache — so across the whole fleet
    // exactly 9 prefix hits (3 per group) and 9 affinity routes exist. Any
    // group migrating between replicas would re-seed a cache and lose a
    // hit, so the totals are the concentration proof.
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(AFFINITY_METRICS, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    serve_fleet_on(
        AFFINITY_ADDR,
        pooled_cfg("lazy", 2, 16),
        3,
        FleetOptions::default(),
        &shutdown,
        Some(telemetry),
    );

    let groups = [
        r#"{"prompt":"#A=1;B=1;\n>","max_new":16}"#,
        r#"{"prompt":"#B=2;C=2;\n>","max_new":16}"#,
        r#"{"prompt":"#C=3;D=3;\n>","max_new":16}"#,
    ];
    for round in 0..4 {
        for (g, request) in groups.iter().enumerate() {
            let j = roundtrip(AFFINITY_ADDR, request);
            assert!(
                j.get("error").is_none(),
                "group {g} round {round} failed: {j:?}"
            );
        }
    }

    // the pump publishes router counters and each actor its labeled pool
    // gauges within a tick; poll for the settled totals
    let mut body = String::new();
    let mut settled = false;
    for _ in 0..250 {
        body = http_get_body(AFFINITY_METRICS, "/metrics");
        let hits: f64 = (0..3)
            .map(|r| labeled_metric(&body, "lazyeviction_pool_prefix_hits", r).unwrap_or(0.0))
            .sum();
        if hits == 9.0
            && metric(&body, "lazyeviction_router_routed_affinity_total") == Some(9.0)
        {
            settled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(settled, "routing/prefix counters never settled:\n{body}");
    assert_eq!(
        metric(&body, "lazyeviction_router_routed_pressure_total"),
        Some(3.0),
        "exactly the first request of each group routes by pressure"
    );
    assert_eq!(
        metric(&body, "lazyeviction_router_rebalances_total"),
        Some(0.0),
        "an uncontended fleet never rebalances"
    );
    assert_eq!(metric(&body, "lazyeviction_replicas_alive"), Some(3.0));
    // per-replica concentration: hits only ever come in whole groups of 3
    for r in 0..3 {
        let hits = labeled_metric(&body, "lazyeviction_pool_prefix_hits", r).unwrap_or(0.0);
        assert_eq!(
            hits as u64 % 3,
            0,
            "replica {r}: {hits} hits — a group split across replicas"
        );
    }

    // kill_replica is a chaos verb: without --fault-injection it must be
    // refused, and the fleet introspection command must answer
    let refused = roundtrip(AFFINITY_ADDR, r#"{"cmd":"kill_replica","replica":0}"#);
    assert!(
        refused.str_at("error").unwrap().contains("fault"),
        "kill_replica must be gated: {refused:?}"
    );
    let fleet = roundtrip(AFFINITY_ADDR, r#"{"cmd":"fleet"}"#);
    let replicas = fleet.get("fleet").and_then(|v| v.as_arr()).expect("fleet array");
    assert_eq!(replicas.len(), 3);
    assert!(replicas.iter().all(|r| r.f64_at("alive").ok() == Some(1.0)));
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn mid_decode_disconnect_reclaims_only_the_home_replica() {
    // One streaming client hangs up mid-decode on a 3-replica swap-tier
    // fleet. The cancel must route to the victim's home replica alone:
    // exactly one replica counts the cancellation and returns its blocks
    // and parked tier bytes to idle; the other two never owned anything.
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(DISCONNECT_METRICS, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    let mut cfg = pooled_cfg("lazy", 2, 9);
    cfg.prefix_cache = None;
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    cfg.preempt_mode = PreemptMode::Swap;
    serve_fleet_on(
        DISCONNECT_ADDR,
        cfg,
        3,
        FleetOptions::default(),
        &shutdown,
        Some(telemetry),
    );

    {
        let stream = TcpStream::connect(DISCONNECT_ADDR).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        writeln!(
            &stream,
            r#"{{"prompt":"#A=3;B=7;\n>","max_new":4096,"stream":true}}"#
        )
        .unwrap();
        for i in 0..5 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).expect("token line");
            assert_eq!(j.str_at("event").unwrap(), "token", "line {i}: {line}");
        }
        // drop both halves: the reader thread sees EOF mid-decode
    }

    let mut body = String::new();
    let mut settled = false;
    for _ in 0..250 {
        body = http_get_body(DISCONNECT_METRICS, "/metrics");
        let cancelled: f64 = (0..3)
            .map(|r| {
                labeled_metric(&body, "lazyeviction_cancelled_rows_total", r).unwrap_or(0.0)
            })
            .sum();
        let drained = (0..3).all(|r| {
            labeled_metric(&body, "lazyeviction_pool_free_blocks", r) == Some(9.0)
                && labeled_metric(&body, "lazyeviction_pool_parked_bytes", r) == Some(0.0)
        });
        if cancelled == 1.0 && drained {
            settled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        settled,
        "abort did not reclaim exactly the home replica's state:\n{body}"
    );
    // exactly one replica owned the request — and it streamed the tokens
    let home: Vec<usize> = (0..3)
        .filter(|&r| {
            labeled_metric(&body, "lazyeviction_cancelled_rows_total", r) == Some(1.0)
        })
        .collect();
    assert_eq!(home.len(), 1, "one home replica, not {home:?}");
    assert!(
        labeled_metric(&body, "lazyeviction_streamed_tokens_total", home[0]).unwrap() >= 5.0,
        "the streamed events must be counted on the home replica"
    );
    for r in 0..3 {
        assert_eq!(
            labeled_metric(&body, "lazyeviction_requests_finished_total", r),
            Some(0.0),
            "no replica ever finished the abandoned request"
        );
    }

    // the fleet stays healthy: a fresh client is served to completion
    let j = roundtrip(DISCONNECT_ADDR, r#"{"prompt":"#A=1;\n>","max_new":8}"#);
    assert!(j.get("error").is_none(), "post-abort request failed: {j:?}");
    assert_eq!(j.usize_at("tokens").unwrap(), 8);
    shutdown.store(true, Ordering::Relaxed);
}

/// Depth-first flatten of one `/trace/spans` tree node into `out`.
fn flatten<'a>(node: &'a Json, out: &mut Vec<&'a Json>) {
    out.push(node);
    if let Some(kids) = node.get("children").and_then(|v| v.as_arr()) {
        for k in kids {
            flatten(k, out);
        }
    }
}

#[test]
fn orphan_span_tree_stitches_across_replicas() {
    // The span-tracing acceptance test: 3 replicas, all four requests
    // stacked on one by affinity, home replica killed mid-decode. For an
    // orphan that finished on a survivor, `GET /trace/spans?req=N` alone
    // must reconstruct the whole story: the router's decision for the dead
    // replica AND for the survivor (two `route` spans with different
    // replica details), the `reroute` hop naming the dead replica, the
    // survivor-side queue/prefill/decode spans — all stitched under one
    // root with monotone timestamps.
    let shutdown = Arc::new(AtomicBool::new(false));
    // 4 × 4096-token decodes emit ~650 spans each (evict passes dominate);
    // an oversized ring keeps the early route/reroute spans from being
    // pushed out before the trees are queried
    let telemetry = Telemetry::with_trace(16384, None).expect("telemetry");
    spawn_metrics_listener(ORPHAN_METRICS, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    let opts = FleetOptions {
        routing: Routing::Affinity,
        fault_injection: true,
        ..FleetOptions::default()
    };
    serve_fleet_on(
        ORPHAN_ADDR,
        pooled_cfg("lazy", 1, 16),
        3,
        opts,
        &shutdown,
        Some(telemetry),
    );

    let request = r#"{"prompt":"#A=3;B=7;\n>","max_new":4096}"#;
    let mut clients = Vec::new();
    for _ in 0..4 {
        let stream = TcpStream::connect(ORPHAN_ADDR).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        writeln!(&stream, "{request}").unwrap();
        clients.push(stream);
    }

    let admin = TcpStream::connect(ORPHAN_ADDR).unwrap();
    admin
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut admin_reader = BufReader::new(admin.try_clone().unwrap());
    let mut ask = |cmd: &str| -> Json {
        writeln!(&admin, "{cmd}").unwrap();
        let mut line = String::new();
        admin_reader.read_line(&mut line).expect("admin reply");
        Json::parse(&line).unwrap_or_else(|e| panic!("bad admin reply '{line}': {e}"))
    };
    let mut home = None;
    for _ in 0..250 {
        let fleet = ask(r#"{"cmd":"fleet"}"#);
        let replicas = fleet.get("fleet").and_then(|v| v.as_arr()).expect("fleet array");
        home = replicas.iter().enumerate().find_map(|(i, r)| {
            (r.f64_at("active").ok() == Some(1.0) && r.f64_at("queue_len").ok() == Some(3.0))
                .then_some(i)
        });
        if home.is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let home = home.expect("all four requests must stack on one decoding replica");
    let killed = ask(&format!(r#"{{"cmd":"kill_replica","replica":{home}}}"#));
    assert_eq!(killed.usize_at("killed").ok(), Some(home), "kill refused: {killed:?}");

    // drain every client; the orphans complete on survivors
    let mut completed = 0usize;
    for (i, stream) in clients.into_iter().enumerate() {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .unwrap_or_else(|e| panic!("client {i} hung after the kill: {e}"));
        let j = Json::parse(&line).unwrap_or_else(|e| panic!("client {i}: bad '{line}': {e}"));
        if j.get("error").is_none() {
            completed += 1;
        }
    }
    assert_eq!(completed, 3, "every orphan must finish on a survivor");

    // the four clients took request ids 1..=4; find an orphan's tree — a
    // closed root whose descendants include a reroute hop. Roots close
    // (with flush) right after the reply line, so poll briefly.
    let mut orphan_root = None;
    'search: for _ in 0..250 {
        for req in 1..=4u64 {
            let body =
                http_get_body(ORPHAN_METRICS, &format!("/trace/spans?req={req}&limit=4096"));
            let tree = Json::parse(&body).expect("span tree body is JSON");
            let roots = tree.get("spans").and_then(|v| v.as_arr()).expect("spans array");
            let found = roots
                .iter()
                .find(|r| r.str_at("name").ok() == Some("request"))
                .cloned();
            if let Some(root) = found {
                let mut nodes = Vec::new();
                flatten(&root, &mut nodes);
                if nodes.iter().any(|n| n.str_at("name").ok() == Some("reroute")) {
                    orphan_root = Some(root);
                    break 'search;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let root = orphan_root.expect("no orphaned request ever produced a rerouted span tree");

    // one root, terminal, parented at 0
    assert_eq!(root.f64_at("parent").unwrap(), 0.0);
    assert!(root.f64_at("dur_ms").unwrap() >= 0.0, "the root must be closed");
    let trace = root.f64_at("span").unwrap();
    let req = root.f64_at("req").unwrap();

    let mut nodes = Vec::new();
    flatten(&root, &mut nodes);
    // stitched: every span in the tree carries the root's trace id and the
    // request's id — nothing from another request leaks into this story
    for n in &nodes {
        assert_eq!(n.f64_at("trace").unwrap(), trace, "foreign trace id: {n:?}");
        assert_eq!(n.f64_at("req").unwrap(), req, "foreign request id: {n:?}");
        assert!(n.f64_at("dur_ms").unwrap() >= 0.0, "unclosed span in tree: {n:?}");
    }
    // monotone: a child never starts before its parent
    fn check_monotone(node: &Json) {
        let t0 = node.f64_at("t_s").unwrap();
        if let Some(kids) = node.get("children").and_then(|v| v.as_arr()) {
            for k in kids {
                assert!(
                    k.f64_at("t_s").unwrap() >= t0,
                    "child starts before parent: {k:?}"
                );
                check_monotone(k);
            }
        }
    }
    check_monotone(&root);

    // the router decided twice — once for the dead replica, once for a
    // survivor — and the reroute hop names the dead replica
    let route_targets: Vec<f64> = nodes
        .iter()
        .filter(|n| n.str_at("name").ok() == Some("route"))
        .map(|n| n.f64_at("detail").unwrap())
        .collect();
    assert!(
        route_targets.len() >= 2,
        "both routing decisions must be in the tree: {route_targets:?}"
    );
    assert!(
        route_targets.contains(&(home as f64)),
        "the first decision targeted the dead replica {home}: {route_targets:?}"
    );
    assert!(
        route_targets.iter().any(|&t| t != home as f64),
        "the re-route decision must target a survivor: {route_targets:?}"
    );
    let reroutes: Vec<f64> = nodes
        .iter()
        .filter(|n| n.str_at("name").ok() == Some("reroute"))
        .map(|n| n.f64_at("detail").unwrap())
        .collect();
    assert_eq!(
        reroutes,
        vec![home as f64],
        "exactly one reroute hop, naming the dead replica"
    );
    // the survivor-side lifecycle is all there
    for stage in ["queue_wait", "prefill", "decode_window"] {
        assert!(
            nodes.iter().any(|n| n.str_at("name").ok() == Some(stage)),
            "missing {stage} span in the stitched tree"
        );
    }
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn killed_replica_drains_to_survivors_with_no_hung_connections() {
    // Four clients send the same long prompt — affinity stacks all four on
    // one replica (batch = 1: one decodes, three queue). Killing that
    // replica mid-serve must resolve every one of them: the active row
    // fails deterministically, the queued fresh requests are orphaned back
    // to the router and finish on the survivors. No connection may hang.
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(KILL_METRICS, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    let opts = FleetOptions {
        routing: Routing::Affinity,
        fault_injection: true,
        ..FleetOptions::default()
    };
    serve_fleet_on(
        KILL_ADDR,
        pooled_cfg("lazy", 1, 16),
        3,
        opts,
        &shutdown,
        Some(telemetry),
    );

    let request = r#"{"prompt":"#A=3;B=7;\n>","max_new":4096}"#;
    let mut clients = Vec::new();
    for _ in 0..4 {
        let stream = TcpStream::connect(KILL_ADDR).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .unwrap();
        writeln!(&stream, "{request}").unwrap();
        clients.push(stream);
    }

    // admin connection: wait until the home replica is actually decoding,
    // identify it, then kill it
    let admin = TcpStream::connect(KILL_ADDR).unwrap();
    admin
        .set_read_timeout(Some(Duration::from_secs(30)))
        .unwrap();
    let mut admin_reader = BufReader::new(admin.try_clone().unwrap());
    let mut ask = |cmd: &str| -> Json {
        writeln!(&admin, "{cmd}").unwrap();
        let mut line = String::new();
        admin_reader.read_line(&mut line).expect("admin reply");
        Json::parse(&line).unwrap_or_else(|e| panic!("bad admin reply '{line}': {e}"))
    };
    let mut home = None;
    for _ in 0..250 {
        let fleet = ask(r#"{"cmd":"fleet"}"#);
        let replicas = fleet.get("fleet").and_then(|v| v.as_arr()).expect("fleet array");
        home = replicas.iter().enumerate().find_map(|(i, r)| {
            (r.f64_at("active").ok() == Some(1.0) && r.f64_at("queue_len").ok() == Some(3.0))
                .then_some(i)
        });
        if home.is_some() {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let home = home.expect("all four requests must stack on one decoding replica");
    let killed = ask(&format!(r#"{{"cmd":"kill_replica","replica":{home}}}"#));
    assert_eq!(killed.usize_at("killed").ok(), Some(home), "kill refused: {killed:?}");

    // every connection resolves: the active row fails with the kill error,
    // the three orphans complete on the survivors
    let mut errors = 0usize;
    let mut completed = 0usize;
    for (i, stream) in clients.into_iter().enumerate() {
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader
            .read_line(&mut line)
            .unwrap_or_else(|e| panic!("client {i} hung after the kill: {e}"));
        let j = Json::parse(&line).unwrap_or_else(|e| panic!("client {i}: bad '{line}': {e}"));
        match j.get("error").and_then(|v| v.as_str()) {
            Some(msg) => {
                assert!(
                    msg.contains("killed"),
                    "client {i}: unexpected failure '{msg}'"
                );
                errors += 1;
            }
            None => {
                assert_eq!(j.usize_at("tokens").unwrap(), 4096, "client {i} truncated");
                completed += 1;
            }
        }
    }
    assert_eq!(errors, 1, "exactly the active row dies with its replica");
    assert_eq!(completed, 3, "every orphan must finish on a survivor");

    // the fleet reports the death and keeps serving
    let mut alive_ok = false;
    let mut body = String::new();
    for _ in 0..250 {
        body = http_get_body(KILL_METRICS, "/metrics");
        if metric(&body, "lazyeviction_replicas_alive") == Some(2.0) {
            alive_ok = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(alive_ok, "replicas_alive never dropped to 2:\n{body}");
    let j = roundtrip(KILL_ADDR, r#"{"prompt":"#B=5;\n>","max_new":8}"#);
    assert!(j.get("error").is_none(), "post-kill request failed: {j:?}");
    assert_eq!(j.usize_at("tokens").unwrap(), 8);
    shutdown.store(true, Ordering::Relaxed);
}
