//! Integration tests over the full runtime (skipped gracefully when the AOT
//! artifacts have not been built — run `make artifacts` first).

use lazyeviction::coordinator::{Engine, EngineConfig, FinishReason, Request};
use lazyeviction::eviction::PolicyParams;
use lazyeviction::runtime::{Client, Manifest};

fn artifacts() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).expect("manifest parses"))
    } else {
        eprintln!("integration: artifacts missing, skipping");
        None
    }
}

fn engine(manifest: &Manifest, policy: &str, batch: usize, cache: usize, budget: usize) -> Engine {
    let client = Client::cpu().expect("pjrt client");
    let mut cfg = EngineConfig {
        batch,
        cache,
        budget,
        policy: policy.into(),
        record_live: true,
        ..Default::default()
    };
    cfg.params = PolicyParams {
        window: 12,
        recent: 12,
        ..Default::default()
    };
    cfg.collect_sketches = policy.starts_with("rkv");
    Engine::new(&client, manifest, cfg).expect("engine builds")
}

fn req(id: u64, prompt: &str, template: &str, max_new: usize) -> Request {
    Request {
        id,
        prompt: prompt.into(),
        template: template.into(),
        max_new,
        resume: None,
    }
}

#[test]
fn manifest_has_complete_engine_shapes() {
    let Some(m) = artifacts() else { return };
    let shapes = m.engine_shapes();
    assert!(shapes.contains(&(1, 256)), "{shapes:?}");
    assert!(shapes.contains(&(4, 256)), "{shapes:?}");
    assert_eq!(m.charset.chars().count(), m.model.vocab);
}

#[test]
fn generation_is_deterministic() {
    let Some(m) = artifacts() else { return };
    let mut e1 = engine(&m, "full", 1, 256, 256);
    let mut e2 = engine(&m, "full", 1, 256, 256);
    let r1 = e1.run_all(vec![req(1, "#A=3;B=7;\n>", "", 32)]).unwrap();
    let r2 = e2.run_all(vec![req(1, "#A=3;B=7;\n>", "", 32)]).unwrap();
    assert_eq!(r1[0].text, r2[0].text);
    assert_eq!(r1[0].finish, FinishReason::MaxTokens);
    assert_eq!(r1[0].metrics.tokens_out, 32);
}

#[test]
fn template_holes_are_filled_and_forced_chars_kept() {
    let Some(m) = artifacts() else { return };
    let mut e = engine(&m, "full", 1, 256, 256);
    let tmpl = "A=?;B=?;\n";
    let r = e
        .run_all(vec![req(1, "#A=3;B=7;\n>", tmpl, 64)])
        .unwrap();
    assert_eq!(r[0].finish, FinishReason::TemplateDone);
    assert_eq!(r[0].hole_predictions.len(), 2);
    // forced scaffold must be preserved verbatim around the holes
    let text: Vec<char> = r[0].text.chars().collect();
    assert_eq!(text[0], 'A');
    assert_eq!(text[1], '=');
    assert_eq!(text[3], ';');
    assert_eq!(text[4], 'B');
}

#[test]
fn eviction_policies_run_under_tight_budget() {
    let Some(m) = artifacts() else { return };
    for policy in ["tova", "h2o", "raas", "rkv", "lazy", "streaming", "h2o+window"] {
        let mut e = engine(&m, policy, 1, 256, 48);
        let r = e
            .run_all(vec![req(1, "#A=3;B=7;C=2;\n>", "", 120)])
            .unwrap();
        assert_eq!(r[0].metrics.tokens_out, 120, "{policy}");
        assert!(
            r[0].metrics.evictions > 0,
            "{policy} never evicted under budget 48 / 120 tokens"
        );
        // live token count must never exceed physical capacity
        assert!(r[0].live_curve.iter().all(|&l| l <= 256), "{policy}");
        // …and must be clamped near the budget after eviction kicks in
        let tail_max = *r[0].live_curve.iter().rev().take(20).max().unwrap();
        assert!(tail_max <= 48 + 12 + 1, "{policy}: tail live {tail_max}");
    }
}

#[test]
fn full_and_bounded_agree_before_budget_binds() {
    // Greedy-safety check: with budget larger than the whole generation,
    // every policy must produce FullKV's exact output.
    let Some(m) = artifacts() else { return };
    let mut base = engine(&m, "full", 1, 256, 256);
    let expected = base
        .run_all(vec![req(1, "#A=3;B=7;\n>", "", 48)])
        .unwrap()[0]
        .text
        .clone();
    for policy in ["tova", "h2o", "raas", "lazy"] {
        let mut e = engine(&m, policy, 1, 256, 200);
        let r = e.run_all(vec![req(1, "#A=3;B=7;\n>", "", 48)]).unwrap();
        assert_eq!(r[0].text, expected, "{policy} diverged with slack budget");
        assert_eq!(r[0].metrics.evictions, 0, "{policy}");
    }
}

#[test]
fn continuous_batching_serves_more_requests_than_rows() {
    let Some(m) = artifacts() else { return };
    let mut e = engine(&m, "lazy", 4, 256, 128);
    let reqs: Vec<Request> = (0..10)
        .map(|i| req(i, "#A=3;B=7;C=2;\n>", "", 20 + (i as usize % 3) * 10))
        .collect();
    let responses = e.run_all(reqs).unwrap();
    assert_eq!(responses.len(), 10);
    let mut ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..10).collect::<Vec<_>>());
    for r in &responses {
        assert!(r.metrics.tokens_out >= 20);
    }
}

#[test]
fn batch_rows_isolated() {
    // The same prompt in different rows of a batch-4 engine must produce
    // identical outputs (no cross-row contamination through the caches).
    let Some(m) = artifacts() else { return };
    let mut e = engine(&m, "full", 4, 256, 256);
    let reqs: Vec<Request> = (0..4).map(|i| req(i, "#D=5;E=1;\n>", "", 24)).collect();
    let responses = e.run_all(reqs).unwrap();
    let first = &responses[0].text;
    for r in &responses[1..] {
        assert_eq!(&r.text, first);
    }
}

#[test]
fn server_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    let Some(m) = artifacts() else { return };
    let addr = "127.0.0.1:8941";
    let shutdown = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    {
        // engine is thread-affine: build it inside the server thread
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let e = engine(&m, "lazy", 1, 256, 128);
            let _ = lazyeviction::server::serve(e, addr, shutdown);
        });
    }
    // engine compile takes seconds — poll-connect
    let mut stream = None;
    for _ in 0..300 {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(200)),
        }
    }
    let stream = stream.expect("server did not come up within 60s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(
        &stream,
        r##"{{"prompt":"#A=3;B=7;\n>","template":"A=?;","max_new":16}}"##
    )
    .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = lazyeviction::util::json::Json::parse(&line).expect("json response");
    assert_eq!(j.str_at("finish").unwrap(), "template_done");
    assert_eq!(j.str_at("holes").unwrap().len(), 1);
    shutdown.store(true, std::sync::atomic::Ordering::Relaxed);
}
