//! End-to-end paged-KV serving over localhost TCP — no artifacts needed:
//! the engine runs the deterministic sim backend. Six concurrent clients
//! contend for a 12-block pool behind a batch-2 engine, which drives the
//! serve loop past the admission watermark (and through preemption when
//! two rows' growth collides); every client must still get a well-formed
//! response carrying the pool gauges.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lazyeviction::coordinator::{Engine, EngineConfig};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::util::json::Json;

fn sim_engine() -> Engine {
    let mut cfg = EngineConfig {
        batch: 2,
        cache: 64,
        budget: 40,
        policy: "lazy".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks: 12,
            low_watermark: 2,
            high_watermark: 4,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    Engine::new_sim(cfg).expect("sim engine")
}

#[test]
fn pooled_serve_past_admission_watermark() {
    let addr = "127.0.0.1:8953";
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = sim_engine();
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    // wait for the listener
    let mut probe = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                probe = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    drop(probe.expect("server did not come up within 4s"));

    // 6 concurrent requests: 2 rows, ~6 blocks each near budget — far more
    // demand than 12 blocks admit at once, so the watermark holds the queue
    let mut handles = Vec::new();
    for c in 0..6u32 {
        handles.push(std::thread::spawn(move || -> String {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(&stream, r#"{{"prompt":"#A={c};B=7;\n>","max_new":48}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        }));
    }

    let mut served = 0;
    for h in handles {
        let line = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(
            j.get("error").is_none(),
            "server returned an error: {line}"
        );
        assert_eq!(j.usize_at("tokens").unwrap(), 48);
        assert_eq!(j.str_at("finish").unwrap(), "max_tokens");
        let pool = j.req("pool").expect("pool gauges attached in paged mode");
        assert_eq!(pool.usize_at("total_blocks").unwrap(), 12);
        assert!(pool.usize_at("free_blocks").unwrap() <= 12);
        let util = pool.f64_at("utilization").unwrap();
        assert!((0.0..=1.0).contains(&util), "utilization {util}");
        served += 1;
    }
    assert_eq!(served, 6);
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn malformed_and_clamped_requests_get_deterministic_lines() {
    let addr = "127.0.0.1:8954";
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = sim_engine();
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    let mut stream = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up within 4s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // bad json → error line, connection stays usable
    writeln!(&stream, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());

    // max_new 0 → rejected before it reaches the scheduler
    writeln!(&stream, r#"{{"prompt":"#A=1;\n>","max_new":0}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.str_at("error").unwrap().contains("max_new"));

    // a good request on the same connection still completes
    writeln!(&stream, r#"{{"prompt":"#A=1;\n>","max_new":8}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_none(), "line: {line}");
    assert_eq!(j.usize_at("tokens").unwrap(), 8);
    shutdown.store(true, Ordering::Relaxed);
}
