//! End-to-end paged-KV serving over localhost TCP — no artifacts needed:
//! the engine runs the deterministic sim backend. Six concurrent clients
//! contend for a 12-block pool behind a batch-2 engine, which drives the
//! serve loop past the admission watermark (and through preemption when
//! two rows' growth collides); every client must still get a well-formed
//! response carrying the pool gauges.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use lazyeviction::coordinator::{Engine, EngineConfig, Request};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::util::json::Json;

fn pooled_cfg(batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: "lazy".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 2,
            high_watermark: 4,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

fn sim_engine() -> Engine {
    Engine::new_sim(pooled_cfg(2, 12)).expect("sim engine")
}

/// Spawn a serve loop and wait for its listener.
fn serve_on(addr: &'static str, engine_cfg: EngineConfig, shutdown: &Arc<AtomicBool>) {
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = Engine::new_sim(engine_cfg).expect("sim engine");
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            drop(s);
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
    panic!("server did not come up within 4s");
}

#[test]
fn pooled_serve_past_admission_watermark() {
    let addr = "127.0.0.1:8953";
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = sim_engine();
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    // wait for the listener
    let mut probe = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                probe = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    drop(probe.expect("server did not come up within 4s"));

    // 6 concurrent requests: 2 rows, ~6 blocks each near budget — far more
    // demand than 12 blocks admit at once, so the watermark holds the queue
    let mut handles = Vec::new();
    for c in 0..6u32 {
        handles.push(std::thread::spawn(move || -> String {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(&stream, r#"{{"prompt":"#A={c};B=7;\n>","max_new":48}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        }));
    }

    let mut served = 0;
    for h in handles {
        let line = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(
            j.get("error").is_none(),
            "server returned an error: {line}"
        );
        assert_eq!(j.usize_at("tokens").unwrap(), 48);
        assert_eq!(j.str_at("finish").unwrap(), "max_tokens");
        let pool = j.req("pool").expect("pool gauges attached in paged mode");
        assert_eq!(pool.usize_at("total_blocks").unwrap(), 12);
        assert!(pool.usize_at("free_blocks").unwrap() <= 12);
        let util = pool.f64_at("utilization").unwrap();
        assert!((0.0..=1.0).contains(&util), "utilization {util}");
        served += 1;
    }
    assert_eq!(served, 6);
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn identical_prompts_share_blocks_past_private_admission() {
    // 9 blocks x 8 tokens behind batch 2: one 19-token-prompt row decoding
    // to 30 tokens peaks near 7 blocks, so private admission can cover at
    // most one growing row at a time. Six clients send the *identical*
    // prompt: every submission after the first forks the cached two-block
    // prefix instead of allocating it, and all six must be served.
    let addr = "127.0.0.1:8955";
    let shutdown = Arc::new(AtomicBool::new(false));
    serve_on(addr, pooled_cfg(2, 9), &shutdown);

    let mut handles = Vec::new();
    for _ in 0..6u32 {
        handles.push(std::thread::spawn(move || -> String {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(
                &stream,
                r#"{{"prompt":"#A=3;B=7;C=2;D=5;\n>","max_new":30}}"#
            )
            .unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        }));
    }

    let mut max_hits = 0;
    let mut max_lookups = 0;
    for h in handles {
        let line = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(j.get("error").is_none(), "server returned an error: {line}");
        assert_eq!(j.usize_at("tokens").unwrap(), 30);
        let pool = j.req("pool").expect("pool gauges attached");
        let hits = pool.usize_at("prefix_hits").unwrap();
        let misses = pool.usize_at("prefix_misses").unwrap();
        max_hits = max_hits.max(hits);
        max_lookups = max_lookups.max(hits + misses);
        assert!(pool.usize_at("prefix_entries").unwrap() <= 64);
        assert!(pool.usize_at("free_blocks").unwrap() <= 9);
    }
    // the chronologically-last completion postdates every first submission:
    // its cumulative counters have seen a lookup per request, and under an
    // identical prompt at least one of them must have shared the prefix
    // (under this much churn — preemption, CoW shedding — the exact hit
    // count varies; the engine-level tests pin the precise admission math)
    assert!(max_lookups >= 6, "every submission consults the cache");
    assert!(max_hits >= 1, "identical prompts must share at least once");
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn divergent_tails_match_solo_outputs_over_tcp() {
    // Three prompts share their first 8-token block and then diverge; each
    // served output must equal a solo, sharing-free engine's output for the
    // same prompt — proving copy-on-write isolates the rows.
    let prompts = ["#A=3;B=7;C=2;\n>", "#A=3;B=7;D=9;\n>", "#A=3;B=7;E=1;\n>"];
    let solo: Vec<String> = prompts
        .iter()
        .map(|p| {
            let mut cfg = pooled_cfg(1, 16);
            cfg.pool = None;
            cfg.prefix_cache = None;
            let mut e = Engine::new_sim(cfg).unwrap();
            let r = e
                .run_all(vec![Request {
                    id: 0,
                    prompt: (*p).into(),
                    template: String::new(),
                    max_new: 32,
                    resume: None,
                }])
                .unwrap();
            r[0].text.clone()
        })
        .collect();

    let addr = "127.0.0.1:8956";
    let shutdown = Arc::new(AtomicBool::new(false));
    serve_on(addr, pooled_cfg(2, 16), &shutdown);

    let mut handles = Vec::new();
    for (i, p) in prompts.iter().enumerate() {
        // the prompt holds a real newline — Json::to_string escapes it
        let req_line = Json::obj()
            .set("prompt", p.to_string())
            .set("max_new", 32usize)
            .to_string();
        handles.push(std::thread::spawn(move || -> (usize, String) {
            let stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(&stream, "{req_line}").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            (i, line)
        }));
    }
    for h in handles {
        let (i, line) = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(j.get("error").is_none(), "server returned an error: {line}");
        assert_eq!(
            j.str_at("text").unwrap(),
            solo[i],
            "prompt {i} corrupted by cross-row sharing"
        );
    }
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn malformed_and_clamped_requests_get_deterministic_lines() {
    let addr = "127.0.0.1:8954";
    let shutdown = Arc::new(AtomicBool::new(false));
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = sim_engine();
            let _ = lazyeviction::server::serve(engine, addr, shutdown);
        });
    }
    let mut stream = None;
    for _ in 0..200 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server did not come up within 4s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // bad json → error line, connection stays usable
    writeln!(&stream, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());

    // max_new 0 → rejected before it reaches the scheduler
    writeln!(&stream, r#"{{"prompt":"#A=1;\n>","max_new":0}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.str_at("error").unwrap().contains("max_new"));

    // a good request on the same connection still completes
    writeln!(&stream, r#"{{"prompt":"#A=1;\n>","max_new":8}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_none(), "line: {line}");
    assert_eq!(j.usize_at("tokens").unwrap(), 8);
    shutdown.store(true, Ordering::Relaxed);
}
