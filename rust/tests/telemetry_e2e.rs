//! End-to-end telemetry: a sim-backed engine served over TCP with the
//! metrics listener attached, scraped mid-serve (counters must be monotone)
//! and after (registry must agree with what the clients saw); plus
//! engine-level flight-recorder checks that the preempt → swap → resume
//! lifecycle comes out as an ordered event sequence consistent with the
//! final counters, both in the in-memory ring and in the `--trace-out`
//! JSONL replay.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::metrics::PoolGauges;
use lazyeviction::telemetry::{event, spawn_metrics_listener, Telemetry};
use lazyeviction::util::json::Json;

// pool_e2e.rs owns 8953-8956; keep this binary's ports disjoint
const SERVE_ADDR: &str = "127.0.0.1:8960";
const METRICS_ADDR: &str = "127.0.0.1:8961";

fn pooled_cfg(batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: "lazy".into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 2,
            high_watermark: 4,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

/// The quick-bench's host-tier configuration (benches/pool.rs): watermarks
/// off so `run_all` drives admission itself, a 1 MiB tier, and the given
/// preemption mode.
fn tier_cfg(mode: PreemptMode, batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = pooled_cfg(batch, n_blocks);
    {
        let p = cfg.pool.as_mut().unwrap();
        p.low_watermark = 0;
        p.high_watermark = 0;
    }
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    cfg.preempt_mode = mode;
    cfg
}

fn mk(id: u64, max_new: usize) -> Request {
    Request {
        id,
        prompt: "#A=3;B=7;\n>".into(),
        template: String::new(),
        max_new,
        resume: None,
    }
}

/// One HTTP/1.0 exchange against the scrape listener → (head, body).
fn http_get(addr: &str, path: &str) -> (String, String) {
    let mut stream = TcpStream::connect(addr).expect("connect scrape listener");
    write!(stream, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read scrape response");
    let (head, body) = buf.split_once("\r\n\r\n").expect("response head/body");
    (head.to_string(), body.to_string())
}

/// Value of the `name value` sample line in a text exposition, if present.
/// Anchored on `name` + a space so `foo` never matches `foo_count`.
fn metric(body: &str, name: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        l.strip_prefix(name)?
            .strip_prefix(' ')?
            .trim()
            .parse::<f64>()
            .ok()
    })
}

#[test]
fn scrape_stats_and_trace_during_and_after_serving() {
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(METRICS_ADDR, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    {
        let shutdown = shutdown.clone();
        let t = telemetry.clone();
        std::thread::spawn(move || {
            let engine = Engine::new_sim(pooled_cfg(2, 12)).expect("sim engine");
            let _ = lazyeviction::server::serve_with_telemetry(engine, SERVE_ADDR, shutdown, Some(t));
        });
    }
    let mut up = false;
    for _ in 0..200 {
        if TcpStream::connect(SERVE_ADDR).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(up, "server did not come up within 4s");

    // 4 concurrent clients through 2 rows over 12 blocks: enough contention
    // to exercise the watermark while the scraper reads mid-flight
    let mut handles = Vec::new();
    for c in 0..4u32 {
        handles.push(std::thread::spawn(move || -> String {
            let stream = TcpStream::connect(SERVE_ADDR).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            writeln!(&stream, r#"{{"prompt":"#A={c};B=7;\n>","max_new":48}}"#).unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            line
        }));
    }

    // mid-serve scrapes: published counters may lag but must never regress
    // (the registry clamps monotone; absent-yet metrics read as zero)
    let mut last = (0.0f64, 0.0f64);
    for _ in 0..4 {
        std::thread::sleep(Duration::from_millis(30));
        let (head, body) = http_get(METRICS_ADDR, "/metrics");
        assert!(head.starts_with("HTTP/1.0 200"), "scrape head: {head}");
        let now = (
            metric(&body, "lazyeviction_tokens_out_total").unwrap_or(0.0),
            metric(&body, "lazyeviction_decode_steps_total").unwrap_or(0.0),
        );
        assert!(
            now.0 >= last.0 && now.1 >= last.1,
            "counters regressed mid-serve: {last:?} -> {now:?}"
        );
        last = now;
    }

    for h in handles {
        let line = h.join().unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(j.get("error").is_none(), "server returned an error: {line}");
        assert_eq!(j.usize_at("tokens").unwrap(), 48);
    }

    // the serve loop publishes on its next iteration — poll briefly for the
    // final snapshot instead of racing it
    let mut body = String::new();
    let mut settled = false;
    for _ in 0..100 {
        body = http_get(METRICS_ADDR, "/metrics").1;
        if metric(&body, "lazyeviction_requests_finished_total") == Some(4.0) {
            settled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(settled, "final publish never arrived; exposition:\n{body}");
    // 4 requests x 48 tokens; a resume-restart fallback could regenerate
    // some, so the decoded total is a floor, not an exact count
    assert!(metric(&body, "lazyeviction_tokens_out_total").unwrap() >= 192.0);
    assert_eq!(metric(&body, "lazyeviction_ttft_ms_count"), Some(4.0));
    assert!(metric(&body, "lazyeviction_ttft_ms_p50").unwrap() >= 0.0);
    assert!(metric(&body, "lazyeviction_queue_wait_ms_count").unwrap() >= 4.0);
    assert!(body.contains("# TYPE lazyeviction_step_latency_ms histogram"));
    assert_eq!(metric(&body, "lazyeviction_pool_total_blocks"), Some(12.0));
    // every PoolGauges field must be scrapable under the pool namespace —
    // the same single-source list the server JSON parity test pins
    for (name, _, _) in PoolGauges::default().fields() {
        assert!(
            metric(&body, &format!("lazyeviction_pool_{name}")).is_some(),
            "pool gauge '{name}' missing from the exposition"
        );
    }

    // HTTP trace endpoint: request 1's lifecycle as parseable JSONL,
    // starting at the server-recorded enqueue and ending at finish
    let (head, trace) = http_get(METRICS_ADDR, "/trace?req=1");
    assert!(head.starts_with("HTTP/1.0 200"), "trace head: {head}");
    let events: Vec<Json> = trace
        .lines()
        .map(|l| Json::parse(l).expect("trace line is JSON"))
        .collect();
    assert!(!events.is_empty(), "request 1 left no flight events");
    assert_eq!(events[0].str_at("event").unwrap(), event::QUEUED);
    assert_eq!(events.last().unwrap().str_at("event").unwrap(), event::FINISH);

    // line-protocol commands share the generation port
    let stream = TcpStream::connect(SERVE_ADDR).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(&stream, r#"{{"cmd":"stats"}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(&line).expect("stats reply");
    let counters = stats.req("stats").unwrap().req("counters").unwrap();
    assert_eq!(
        counters.f64_at("lazyeviction_requests_finished_total").unwrap(),
        4.0
    );

    writeln!(&stream, r#"{{"cmd":"trace","id":2}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let reply = Json::parse(&line).expect("trace reply");
    let evs = reply.get("trace").and_then(|v| v.as_arr()).expect("trace array");
    assert!(!evs.is_empty());
    assert_eq!(evs[0].str_at("event").unwrap(), event::QUEUED);

    writeln!(&stream, r#"{{"cmd":"bogus"}}"#).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert!(Json::parse(&line).unwrap().get("error").is_some());

    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn trace_spans_endpoint_serves_request_tree_and_trace_limit_pages() {
    // this binary owns 8960-8963; 8960/8961 belong to the scrape test above
    const SERVE2: &str = "127.0.0.1:8962";
    const METRICS2: &str = "127.0.0.1:8963";
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(METRICS2, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    {
        let shutdown = shutdown.clone();
        let t = telemetry.clone();
        std::thread::spawn(move || {
            let engine = Engine::new_sim(pooled_cfg(2, 12)).expect("sim engine");
            let _ = lazyeviction::server::serve_with_telemetry(engine, SERVE2, shutdown, Some(t));
        });
    }
    let mut up = false;
    for _ in 0..200 {
        if TcpStream::connect(SERVE2).is_ok() {
            up = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(up, "server did not come up within 4s");

    for c in 0..2u32 {
        let stream = TcpStream::connect(SERVE2).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        writeln!(&stream, r#"{{"prompt":"#A={c};B=7;\n>","max_new":48}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).expect("json response line");
        assert!(j.get("error").is_none(), "server returned an error: {line}");
    }

    // the root span closes (with flush) right after the reply line is
    // written — poll briefly instead of racing the server thread
    let mut tree = Json::obj();
    let mut rooted = false;
    for _ in 0..100 {
        let (head, body) = http_get(METRICS2, "/trace/spans?req=1");
        assert!(head.starts_with("HTTP/1.0 200"), "spans head: {head}");
        tree = Json::parse(&body).expect("span tree body is JSON");
        let roots = tree.get("spans").and_then(|v| v.as_arr()).expect("spans array");
        if roots
            .iter()
            .any(|r| r.str_at("name").ok() == Some("request"))
        {
            rooted = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(rooted, "request 1 never produced a closed root span: {tree:?}");
    let roots = tree.get("spans").and_then(|v| v.as_arr()).unwrap();
    let root = roots
        .iter()
        .find(|r| r.str_at("name").ok() == Some("request"))
        .unwrap();
    assert_eq!(root.f64_at("req").unwrap(), 1.0);
    assert_eq!(root.f64_at("parent").unwrap(), 0.0);
    assert!(root.f64_at("dur_ms").unwrap() >= 0.0);
    // the lifecycle stages nest under the root and start no earlier
    let t0 = root.f64_at("t_s").unwrap();
    let kids = root.get("children").and_then(|v| v.as_arr()).expect("children");
    let names: Vec<&str> = kids.iter().filter_map(|k| k.str_at("name").ok()).collect();
    for stage in ["route", "queue_wait", "prefill"] {
        assert!(names.contains(&stage), "missing {stage}: {names:?}");
    }
    for k in kids {
        assert!(k.f64_at("t_s").unwrap() >= t0, "child starts before root: {k:?}");
        assert_eq!(
            root.f64_at("span").unwrap(),
            k.f64_at("trace").unwrap(),
            "every child must carry the root's trace id"
        );
    }
    // a req filter returns nothing for an id that never ran
    let (_, other) = http_get(METRICS2, "/trace/spans?req=99");
    let none = Json::parse(&other).unwrap();
    assert!(none.get("spans").and_then(|v| v.as_arr()).unwrap().is_empty());

    // /trace pagination: limit=1 keeps only the newest event
    let (_, all) = http_get(METRICS2, "/trace");
    let total = all.lines().count();
    assert!(total > 1, "two served requests must leave multiple events");
    let (_, one) = http_get(METRICS2, "/trace?limit=1");
    assert_eq!(one.lines().count(), 1, "limit=1 must return one line");
    let newest = Json::parse(one.lines().next().unwrap()).unwrap();
    let last = Json::parse(all.lines().last().unwrap()).unwrap();
    assert_eq!(
        newest.usize_at("seq").unwrap(),
        last.usize_at("seq").unwrap(),
        "limit keeps the newest events, not the oldest"
    );
    // span durations feed the histogram registry on the next publish
    let mut seen = false;
    for _ in 0..100 {
        let (_, body) = http_get(METRICS2, "/metrics");
        if metric(&body, "lazyeviction_span_request_ms_count").map_or(false, |v| v >= 1.0) {
            seen = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(seen, "span duration histograms never reached /metrics");

    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn flight_recorder_orders_swap_preempt_resume() {
    // the quick-bench's contended swap scenario: 3 requests, 2 rows, 9
    // blocks, swap-mode preemption against a 1 MiB tier
    let telemetry = Telemetry::new();
    let mut e = Engine::new_sim(tier_cfg(PreemptMode::Swap, 2, 9)).expect("sim engine");
    e.attach_telemetry(telemetry.clone());
    let rs = e.run_all((0..3).map(|i| mk(i, 50)).collect()).expect("run");
    assert_eq!(rs.len(), 3);
    assert!(e.metrics.swap_preempts > 0, "the scenario must swap-preempt");

    let mut preempt_events = 0u64;
    let mut swap_cycles = 0usize;
    for id in 0..3u64 {
        let evs = telemetry.events_for(id);
        assert!(!evs.is_empty(), "request {id} left no flight events");
        assert!(
            evs.windows(2).all(|w| w[0].seq < w[1].seq),
            "request {id}: seq numbers must increase in emission order"
        );
        let names: Vec<&str> = evs.iter().map(|ev| ev.event).collect();
        // engine-level runs start at admission (`queued` is server-side)
        assert_eq!(names.first().copied(), Some(event::ADMITTED), "req {id}");
        assert_eq!(names.last().copied(), Some(event::FINISH), "req {id}");
        assert!(names.contains(&event::DECODE), "req {id} never decoded");
        preempt_events += names
            .iter()
            .filter(|n| **n == event::PREEMPT || **n == event::PREEMPT_SWAP)
            .count() as u64;
        // every swap-out must be paired with a later swap-in: by finish the
        // request's tier traffic is balanced, and the first cycle is ordered
        let outs = names.iter().filter(|n| **n == event::PREEMPT_SWAP).count();
        let ins = names.iter().filter(|n| **n == event::RESUME_SWAP).count();
        assert_eq!(outs, ins, "req {id}: unbalanced swap cycle");
        if outs > 0 {
            let p = names.iter().position(|n| *n == event::PREEMPT_SWAP).unwrap();
            let r = names.iter().position(|n| *n == event::RESUME_SWAP).unwrap();
            assert!(r > p, "req {id}: swap resume recorded before its preempt");
            swap_cycles += 1;
        }
    }
    assert_eq!(
        preempt_events, e.metrics.preemptions,
        "one preempt event per counted preemption"
    );
    assert!(swap_cycles > 0, "no request recorded a full swap cycle");
}

#[test]
fn trace_out_jsonl_replays_lifecycle_consistent_with_counters() {
    // the quick-bench's recurrence scenario: one lazy row over 16 blocks
    // with a host tier — guaranteed demotions and promotions
    let dir = std::env::temp_dir().join(format!("lazyeviction-tele-e2e-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.jsonl");
    let telemetry = Telemetry::with_trace(4096, Some(path.as_path())).expect("trace sink");
    let mut e = Engine::new_sim(tier_cfg(PreemptMode::Recompute, 1, 16)).expect("sim engine");
    e.attach_telemetry(telemetry.clone());
    let rs = e.run_all(vec![mk(0, 60)]).expect("run");
    assert_eq!(rs.len(), 1);
    telemetry.flush();

    let text = std::fs::read_to_string(&path).expect("read trace-out");
    let (mut finishes, mut promotes, mut demotes, mut evicts) = (0u64, 0u64, 0u64, 0u64);
    let mut last_seq = None;
    for line in text.lines() {
        let j = Json::parse(line).expect("every trace line is valid JSON");
        let seq = j.usize_at("seq").unwrap();
        if let Some(prev) = last_seq {
            assert!(seq > prev, "trace seq must be strictly increasing");
        }
        last_seq = Some(seq);
        assert!(j.f64_at("t_s").unwrap() >= 0.0);
        assert_eq!(j.f64_at("req").unwrap(), 0.0);
        let ev = j.str_at("event").unwrap();
        if ev == event::FINISH {
            finishes += 1;
        } else if ev == event::PROMOTE {
            promotes += 1;
        } else if ev == event::DEMOTE {
            demotes += 1;
        } else if ev == event::EVICT {
            evicts += 1;
        }
    }
    assert_eq!(finishes, 1, "exactly one finish for one request");
    assert_eq!(promotes, e.metrics.promotions, "one promote event per promotion");
    assert!(promotes > 0, "recurrence scenario must promote");
    assert!(demotes > 0, "evictions must park blocks");
    // batch-1: the per-row evict events are exactly the counted passes
    assert_eq!(evicts, e.metrics.eviction_count);
    // the ring (under capacity here) retained the same lifecycle the file got
    assert_eq!(telemetry.events_for(0).len(), text.lines().count());

    std::fs::remove_file(&path).ok();
    std::fs::remove_dir(&dir).ok();
}
