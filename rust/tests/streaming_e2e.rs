//! End-to-end per-token streaming over localhost TCP — the serving-core
//! acceptance tests. A streaming client must see token events incrementally
//! (first token line strictly before the terminal line), their concatenation
//! must be byte-identical to the non-streaming response for the same prompt
//! across every eviction policy, and a client that disconnects mid-stream
//! must have its row torn down promptly: pool blocks and host-tier state
//! back to idle, observed via the `/metrics` exposition. The last test pins
//! the abandoned swap-parked snapshot path (`release_discarded_state`) at
//! the engine level — the leak that motivated it is invisible over the wire
//! until the tier fills.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use lazyeviction::coordinator::{Engine, EngineConfig, PreemptMode, Request};
use lazyeviction::kvpool::PoolConfig;
use lazyeviction::kvtier::HostTierConfig;
use lazyeviction::telemetry::{spawn_metrics_listener, Telemetry};
use lazyeviction::util::json::Json;

// pool_e2e.rs owns 8953-8956, telemetry_e2e.rs 8960-8963; this binary
// uses 8970-8977 so the three can run in parallel
const POLICY_PORTS: [(&str, &str); 4] = [
    ("full", "127.0.0.1:8970"),
    ("h2o", "127.0.0.1:8971"),
    ("tova", "127.0.0.1:8972"),
    ("lazy", "127.0.0.1:8973"),
];
const DISCONNECT_ADDR: &str = "127.0.0.1:8976";
const DISCONNECT_METRICS: &str = "127.0.0.1:8977";

fn pooled_cfg(policy: &str, batch: usize, n_blocks: usize) -> EngineConfig {
    let mut cfg = EngineConfig {
        batch,
        cache: 64,
        budget: 40,
        policy: policy.into(),
        record_live: false,
        pool: Some(PoolConfig {
            block_size: 8,
            n_blocks,
            low_watermark: 2,
            high_watermark: 4,
        }),
        ..Default::default()
    };
    cfg.params.window = 8;
    cfg.params.recent = 8;
    cfg
}

fn mk(id: u64, max_new: usize) -> Request {
    Request {
        id,
        prompt: "#A=3;B=7;\n>".into(),
        template: String::new(),
        max_new,
        resume: None,
    }
}

/// Spawn a serve loop for `cfg` (optionally with telemetry) and wait for
/// its listener to come up.
fn serve_on(addr: &'static str, cfg: EngineConfig, shutdown: &Arc<AtomicBool>, telemetry: Option<Arc<Telemetry>>) {
    {
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            let engine = Engine::new_sim(cfg).expect("sim engine");
            let _ = lazyeviction::server::serve_with_telemetry(engine, addr, shutdown, telemetry);
        });
    }
    for _ in 0..200 {
        if let Ok(s) = TcpStream::connect(addr) {
            drop(s);
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    panic!("server did not come up within 4s");
}

/// One HTTP/1.0 exchange against the scrape listener → body.
fn http_get_body(addr: &str, path: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect scrape listener");
    write!(stream, "GET {path} HTTP/1.0\r\nHost: t\r\n\r\n").unwrap();
    let mut buf = String::new();
    stream.read_to_string(&mut buf).expect("read scrape response");
    buf.split_once("\r\n\r\n").expect("head/body").1.to_string()
}

/// Value of the `name value` sample line in a text exposition, if present.
fn metric(body: &str, name: &str) -> Option<f64> {
    body.lines().find_map(|l| {
        l.strip_prefix(name)?
            .strip_prefix(' ')?
            .trim()
            .parse::<f64>()
            .ok()
    })
}

#[test]
fn stream_concat_is_byte_identical_across_policies() {
    // For each policy: one streaming request, then the identical prompt
    // without streaming on the same server. The token lines must arrive
    // before the terminal line (incremental delivery), count one per token
    // with `n` increasing from 1, and concatenate to exactly the
    // non-streaming `text` — streaming changes delivery, never content.
    for (policy, addr) in POLICY_PORTS {
        let shutdown = Arc::new(AtomicBool::new(false));
        serve_on(addr, pooled_cfg(policy, 2, 16), &shutdown, None);

        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        writeln!(
            &stream,
            r#"{{"prompt":"#A=3;B=7;\n>","max_new":32,"stream":true,"class":"interactive"}}"#
        )
        .unwrap();

        let mut concat = String::new();
        let mut n_events = 0usize;
        let done = loop {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).expect("stream line is JSON");
            assert!(j.get("error").is_none(), "server errored: {line}");
            match j.str_at("event").expect("streaming lines carry 'event'") {
                "token" => {
                    n_events += 1;
                    // the very first line off the socket is a token event:
                    // the client holds the first token before the row is done
                    assert_eq!(
                        j.usize_at("n").unwrap(),
                        n_events,
                        "policy {policy}: token events out of order"
                    );
                    assert_eq!(
                        j.get("first").unwrap().as_bool().unwrap(),
                        n_events == 1,
                        "policy {policy}: 'first' must mark exactly event 1"
                    );
                    concat.push_str(j.str_at("text").unwrap());
                }
                "done" => break j,
                other => panic!("policy {policy}: unexpected event '{other}'"),
            }
        };
        assert!(n_events > 0, "policy {policy}: no token events before done");
        assert_eq!(
            done.usize_at("tokens").unwrap(),
            32,
            "policy {policy}: wrong token count"
        );
        assert_eq!(
            concat,
            done.str_at("text").unwrap(),
            "policy {policy}: streamed concat != terminal text"
        );

        // the same prompt, non-streaming, on the same connection: exactly
        // one line, no token events, byte-identical text
        writeln!(&stream, r#"{{"prompt":"#A=3;B=7;\n>","max_new":32}}"#).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(&line).expect("plain response line");
        assert!(j.get("error").is_none(), "server errored: {line}");
        assert!(
            j.get("event").is_none(),
            "policy {policy}: non-streaming reply must carry no event marker"
        );
        assert_eq!(
            j.str_at("text").unwrap(),
            concat,
            "policy {policy}: streaming changed the generated bytes"
        );
        shutdown.store(true, Ordering::Relaxed);
    }
}

#[test]
fn mid_stream_disconnect_frees_blocks_and_tier_state() {
    // A streaming client reads a handful of token events off a long
    // generation and hangs up. The reader thread lands the EOF in the
    // handler, the handler flags the cancel, and the engine loop's next
    // iteration tears the row down: cancelled_rows ticks, all pool blocks
    // return, and every parked host-tier entry the row had demoted is
    // released. The prefix cache is off so no pinned donor blocks mask a
    // leak in the free-block gauge.
    let shutdown = Arc::new(AtomicBool::new(false));
    let telemetry = Telemetry::new();
    spawn_metrics_listener(DISCONNECT_METRICS, telemetry.clone(), shutdown.clone())
        .expect("bind metrics listener");
    let mut cfg = pooled_cfg("lazy", 2, 9);
    cfg.prefix_cache = None;
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    cfg.preempt_mode = PreemptMode::Swap;
    serve_on(DISCONNECT_ADDR, cfg, &shutdown, Some(telemetry));

    {
        let stream = TcpStream::connect(DISCONNECT_ADDR).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        // 4096 tokens through a 40-token budget: the decode (and its tier
        // demotions) is nowhere near done when the client walks away, so
        // the abort deterministically lands mid-stream
        writeln!(
            &stream,
            r#"{{"prompt":"#A=3;B=7;\n>","max_new":4096,"stream":true}}"#
        )
        .unwrap();
        for i in 0..5 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            let j = Json::parse(&line).expect("token line");
            assert_eq!(j.str_at("event").unwrap(), "token", "line {i}: {line}");
        }
        // drop both halves: the reader thread sees EOF mid-decode
    }

    // the abort is asynchronous (next engine-loop iteration + a telemetry
    // publish); poll the exposition for the settled post-abort state
    let mut body = String::new();
    let mut settled = false;
    for _ in 0..250 {
        body = http_get_body(DISCONNECT_METRICS, "/metrics");
        if metric(&body, "lazyeviction_cancelled_rows_total") == Some(1.0)
            && metric(&body, "lazyeviction_pool_free_blocks") == Some(9.0)
            && metric(&body, "lazyeviction_pool_parked_bytes") == Some(0.0)
        {
            settled = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        settled,
        "abort did not reclaim blocks/tier state; exposition:\n{body}"
    );
    assert!(
        metric(&body, "lazyeviction_streamed_tokens_total").unwrap() >= 5.0,
        "the streamed events must be counted"
    );
    // no terminal was ever produced for the abandoned request
    assert_eq!(metric(&body, "lazyeviction_requests_finished_total"), Some(0.0));

    // the server stays healthy: a fresh client is served to completion
    let stream = TcpStream::connect(DISCONNECT_ADDR).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(&stream, r#"{{"prompt":"#A=1;\n>","max_new":8}}"#).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = Json::parse(&line).unwrap();
    assert!(j.get("error").is_none(), "post-abort request failed: {line}");
    assert_eq!(j.usize_at("tokens").unwrap(), 8);
    shutdown.store(true, Ordering::Relaxed);
}

#[test]
fn discarding_a_swap_parked_snapshot_drains_the_tier() {
    // The serve loop's queued-cancellation path, engine-level: two rows
    // contending for 9 blocks under swap-mode preemption park a victim's
    // whole block table in the host tier. If that victim's client is gone
    // when its turn comes, the serve loop calls `release_discarded_state`
    // instead of resubmitting — and the pinned tier bytes must come back,
    // or abandoned clients permanently shrink the tier budget.
    let mut cfg = pooled_cfg("lazy", 2, 9);
    {
        let p = cfg.pool.as_mut().unwrap();
        p.low_watermark = 0;
        p.high_watermark = 0;
    }
    cfg.prefix_cache = None;
    cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
    cfg.preempt_mode = PreemptMode::Swap;
    let mut e = Engine::new_sim(cfg).expect("sim engine");
    assert!(e.submit(mk(0, 50), 0.0).expect("submit 0"));
    assert!(e.submit(mk(1, 50), 0.0).expect("submit 1"));

    // step until the pool collision swap-preempts one of the rows
    let mut victims = Vec::new();
    for _ in 0..200 {
        e.step().expect("step");
        victims = e.take_preempted();
        if !victims.is_empty() {
            break;
        }
    }
    let victim = victims.pop().expect("9 blocks under 2 rows must preempt");
    // any same-step co-victims stay live: hand them straight back
    for r in victims {
        assert!(e.submit(r, 0.0).expect("resubmit co-victim"));
    }
    let st = victim.resume.clone().expect("preemption carries a snapshot");
    assert!(
        st.swapped.is_some(),
        "swap-mode preemption must park the table, not recompute"
    );
    let parked_before = e.pool_gauges().expect("paged mode").parked_bytes;
    assert!(parked_before > 0, "the victim's bytes must sit in the tier");

    // the client is gone: discard the snapshot the way the serve loop does
    let cancelled_before = e.metrics.cancelled_rows;
    e.release_discarded_state(&st, victim.id);
    assert_eq!(e.metrics.cancelled_rows, cancelled_before + 1);
    assert!(
        e.pool_gauges().unwrap().parked_bytes < parked_before,
        "discarding the snapshot must release its pinned tier bytes"
    );

    // drain the surviving row; at idle the tier must be byte-empty and the
    // pool whole again — nothing the dead client owned stays pinned
    for _ in 0..500 {
        if e.active() == 0 {
            break;
        }
        e.step().expect("drain step");
        for r in e.take_preempted() {
            // re-admit survivors so the drain terminates
            assert!(e.submit(r, 0.0).expect("resubmit"));
        }
    }
    assert_eq!(e.active(), 0, "survivor did not finish");
    let g = e.pool_gauges().unwrap();
    assert_eq!(g.parked_bytes, 0, "tier budget must return to zero");
    assert_eq!(g.parked_blocks, 0);
    assert_eq!(g.free_blocks, g.total_blocks, "pool blocks leaked");
}
