//! Paged KV block pool: a vLLM-style shared memory budget for the whole
//! engine instead of per-row worst-case provisioning.
//!
//! The seed gave every engine row an isolated `SeqKv` slot array sized for
//! the worst case. This module introduces the global alternative that makes
//! LazyEviction's 50–70% KV reduction pay off at serving scale: a fixed-size
//! [`BlockPool`] of refcounted blocks ([`pool`]), per-sequence
//! [`BlockTable`]s mapping compacted slots → (block, offset) ([`table`]),
//! and a [`PoolPressure`] signal the scheduler uses for admission control
//! and preemption:
//!
//! * **admission** — the server holds queued requests while
//!   `free < low_watermark` and resumes at `free >= high_watermark`
//!   (hysteresis lives in `scheduler::admission`);
//! * **preemption** — when the pool is exhausted mid-decode, the engine
//!   evicts the *youngest* row, returns its blocks, and re-queues its
//!   request for re-prefill (`coordinator::Engine::step`);
//! * **reclamation** — `SeqKv::apply_keep_pooled` returns whole blocks freed
//!   by an eviction pass, so lagged eviction directly becomes cross-sequence
//!   capacity (`sim::capacity` + `benches/pool.rs` measure it).
//!
//! Refcounts let identical prompt prefixes share whole blocks across a batch
//! ([`BlockTable::fork_prefix`]); copy-on-write (`ensure_private`) detaches a
//! table before its contents diverge under compaction. The [`prefix`] module
//! is the serving-path entry point: a prompt-hash → donor-table cache with
//! pressure-driven LRU invalidation that `Engine::submit` consults so
//! identical prompt headers across requests are admitted for free.
//!
//! Physical paging: the pool/table layer above is deliberately *logical*
//! (ids, refcounts, maps), so the capacity simulator and scheduler can drive
//! it without tensors. The physical half lives in [`arena`]: block-shaped
//! K/V storage (`[n_blocks, block_size, L·H·dh]`) that backends own — the
//! sim backend as a host [`KvArena`], the PJRT executor as device buffers of
//! the same layout — plus the [`BlockCopy`]/[`RowMove`] descriptors through
//! which table CoW and `SeqKv` compaction tell the storage which bytes to
//! duplicate or relocate. In paged mode every K/V byte is addressed through
//! a block table; there is no per-row worst-case buffer anywhere, a prefix
//! hit reuses the donor's bytes (prefill is skipped), and whole blocks freed
//! by eviction become cross-sequence physical capacity, not just accounting.
//!
//! Below this pool sits an optional second memory tier
//! ([`kvtier`](crate::kvtier)): eviction can *demote* dropped blocks into a
//! byte-budgeted host arena instead of destroying them (recurrence promotes
//! them back), and preemption can park a whole row's table there instead of
//! recomputing it. The pool stays the single source of truth for device
//! residency — tier entries hold byte copies, never block references.

pub mod arena;
pub mod audit;
pub mod pool;
pub mod prefix;
pub mod table;

pub use arena::{BlockCopy, KvArena, KvLayout, RowMove};
pub use pool::{BlockId, BlockPool, PoolConfig, PoolPressure};
pub use prefix::{
    boundary_hashes, prefix_hash, PrefillSeed, PrefixCache, PrefixCacheConfig, PrefixHit,
};
pub use table::BlockTable;
