//! Prompt-prefix cache: cross-sequence block sharing for identical prompt
//! headers (system prompts, few-shot preambles).
//!
//! Serving traffic that matters at scale repeats the same prompt prefix
//! across many requests. The block pool already supports refcounted sharing
//! ([`BlockTable::fork_prefix`]); this cache is the lookup structure that
//! turns it on in the serving path: `Engine::submit` hashes the incoming
//! prompt's token ids at block boundaries, and on a hit the new row's table
//! is forked from the cached donor — the shared whole blocks cost the pool
//! nothing, so admission only has to cover the row's *private* tail.
//!
//! ## Ownership
//!
//! Each entry owns a [`BlockTable`] fork of its donor (refcounts bumped at
//! insert time), so entries never dangle: the donor row can finish, be
//! preempted, or be evicted down to nothing and the cached blocks stay
//! alive under the cache's own references. The flip side is that cached
//! entries *pin* pool blocks (a block whose only holder is the cache is not
//! on the free list), which is why invalidation is pressure-driven.
//!
//! ## Invalidation rules
//!
//! 1. **Capacity (LRU)** — at most `max_entries` entries; inserting past
//!    the cap sheds the least-recently-used entry first
//!    ([`PrefixCache::shed_lru`] — unconditional, something must go).
//! 2. **Pool pressure (targeted LRU)** — when the engine cannot cover an
//!    admission or per-step block headroom, it sheds only entries whose
//!    release actually returns blocks to the free list
//!    ([`PrefixCache::shed_lru_reclaimable`]): destroying an entry whose
//!    blocks are still shared with live rows would free nothing while
//!    costing future admissions their sharing. Copy-on-write privatization
//!    additionally sheds entries holding the row's own shared blocks
//!    ([`PrefixCache::shed_lru_overlapping`]) — that lowers their refcount
//!    directly and often privatizes the row with no allocation at all.
//!    Blocks whose refcount drops to zero return to the free list
//!    immediately, so a cache-pinned pool can always be drained back to
//!    fully free.
//! 3. **Never by donor lifecycle** — entries hold their own references, so
//!    no invalidation is needed when donor blocks are "freed" by their row;
//!    the row merely drops its reference and the cache keeps the content.
//!
//! Lookups verify token ids (not just the 64-bit FNV hash), so a hash
//! collision can never splice the wrong prefix into a row.
//!
//! ## Prefill skipping (physical paging)
//!
//! With physical K/V in pool-owned block storage, a cached prefix's blocks
//! *are* the data — so an admission whose **entire prompt** matches a cached
//! entry does not need to run the prefill executable at all. The cached
//! whole blocks carry the prompt's leading K/V; everything else a prefill
//! would have produced is a small host-side [`PrefillSeed`] stored on the
//! entry at insert time: the partial-tail-block K/V rows (which cannot be
//! block-shared), the last-row attention (seeds TS/MRI tracking), and the
//! last-position logits (the first prediction). A seed is only served when
//! the probe's *full* token sequence equals the seed's — two prompts that
//! share a whole-block header but diverge in the tail get block sharing,
//! never each other's seed.

use super::pool::{BlockId, BlockPool};
use super::table::BlockTable;

/// Sizing/behavior knobs for the [`PrefixCache`].
#[derive(Clone, Debug)]
pub struct PrefixCacheConfig {
    /// Maximum cached prefixes; LRU-shed beyond this.
    pub max_entries: usize,
}

impl Default for PrefixCacheConfig {
    fn default() -> Self {
        PrefixCacheConfig { max_entries: 64 }
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;

/// One FNV-1a step: mix a token id into the running hash. `prefix_hash`
/// and `boundary_hashes` must stay bit-identical (entry keys come from the
/// former, probe keys from the latter), so both go through this.
#[inline]
fn fnv_mix(mut h: u64, id: u32) -> u64 {
    for byte in id.to_le_bytes() {
        h ^= byte as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// 64-bit FNV-1a over a token-id slice (the block-boundary prefix key).
pub fn prefix_hash(ids: &[u32]) -> u64 {
    ids.iter().fold(FNV_OFFSET, |h, &id| fnv_mix(h, id))
}

/// Rolling FNV-1a snapshots at every block boundary: `out[k]` is
/// `prefix_hash(&ids[..k * block_size])`. One O(len) pass, so a lookup
/// hashes the prompt once no matter how many entries it is checked against.
/// Public because the fleet router keys placement on the same hashes
/// ([`crate::scheduler::routing::header_hashes`]): a probe key computed here
/// is bit-identical to the entry keys `insert` stores, so a router match
/// means the target replica's cache would pre-filter the same entry.
pub fn boundary_hashes(ids: &[u32], block_size: usize) -> Vec<u64> {
    let n_bounds = ids.len() / block_size;
    let mut out = Vec::with_capacity(n_bounds + 1);
    let mut h = FNV_OFFSET;
    out.push(h);
    for (i, &id) in ids[..n_bounds * block_size].iter().enumerate() {
        h = fnv_mix(h, id);
        if (i + 1) % block_size == 0 {
            out.push(h);
        }
    }
    out
}

/// Host-side copy of everything a prefill produced that does NOT live in the
/// entry's shared whole blocks — enough, together with those blocks, to admit
/// an identical prompt with zero prefill compute (see module docs).
#[derive(Clone, Debug)]
pub struct PrefillSeed {
    /// The complete prompt these outputs belong to (exact-match key).
    pub prompt: Vec<u32>,
    /// Token-major `[prompt.len() - covered, L·H·dh]` K rows for the prompt
    /// remainder past the entry's whole-block coverage (may be empty).
    pub tail_k: Vec<f32>,
    pub tail_v: Vec<f32>,
    /// Last-prompt-row aggregated attention over all prompt tokens
    /// (`[prompt.len()]`) — initializes the recurrence tracker.
    pub attn_last: Vec<f32>,
    /// Logits at the last prompt position (`[vocab]`) — the first prediction.
    pub logits_last: Vec<f32>,
}

/// A successful [`PrefixCache::lookup`].
pub struct PrefixHit<'a> {
    /// Donor block table to [`BlockTable::fork_prefix`] from.
    pub table: &'a BlockTable,
    /// Present iff the probe's full prompt equals the entry's seed prompt —
    /// the admission may skip prefill entirely.
    pub seed: Option<&'a PrefillSeed>,
}

struct Entry {
    hash: u64,
    /// Exact token ids covered (always a whole number of blocks).
    tokens: Vec<u32>,
    /// Cache-owned fork pinning the blocks.
    table: BlockTable,
    /// Prefill outputs for one full prompt extending `tokens` (kept from the
    /// first admission that inserted/updated this entry).
    seed: Option<PrefillSeed>,
    last_used: u64,
}

/// Prompt-hash → donor block table map with LRU invalidation (module docs).
pub struct PrefixCache {
    cfg: PrefixCacheConfig,
    entries: Vec<Entry>,
    clock: u64,
    /// Admissions that reused a cached prefix (whole blocks actually
    /// forked into a row). Maintained by the engine at admission time, so
    /// a lookup whose admission is then declined inflates nothing.
    pub hits: u64,
    /// Admissions that found nothing to share.
    pub misses: u64,
    /// Entries ever inserted.
    pub insertions: u64,
    /// Entries shed (capacity or pool pressure).
    pub invalidations: u64,
}

impl PrefixCache {
    pub fn new(cfg: PrefixCacheConfig) -> PrefixCache {
        PrefixCache {
            cfg,
            entries: Vec::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            insertions: 0,
            invalidations: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Distinct blocks referenced by cache entries (overlapping entries —
    /// a shorter and a longer fork of the same header — share blocks, which
    /// must not be double-counted in the exported gauge).
    pub fn pinned_blocks(&self) -> usize {
        let mut ids: Vec<BlockId> = self
            .entries
            .iter()
            .flat_map(|e| e.table.blocks().iter().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Every block reference the cache holds, *with multiplicity*: a block
    /// referenced by two overlapping entries appears twice, because each
    /// entry's fork bumped its refcount independently. This is the cache's
    /// contribution to the pool refcount conservation check
    /// ([`crate::kvpool::audit`]) — unlike [`pinned_blocks`](Self::pinned_blocks),
    /// which dedups for the exported gauge.
    pub fn pinned_block_ids(&self) -> Vec<BlockId> {
        self.entries
            .iter()
            .flat_map(|e| e.table.blocks().iter().copied())
            .collect()
    }

    /// Blocks that shedding the whole cache would return to the free list
    /// right now (blocks the cache is the sole holder of — refcount 1, so
    /// each is referenced by exactly one entry and counting is exact). The
    /// engine uses this to decide whether shedding can cover a demand at
    /// all before destroying any entry.
    pub fn reclaimable_blocks(&self, pool: &BlockPool) -> usize {
        self.entries
            .iter()
            .flat_map(|e| e.table.blocks().iter())
            .filter(|&&b| pool.refcount(b) == 1)
            .count()
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Longest cached prefix of `ids`, by whole blocks of `block_size`.
    /// Bumps the matched entry's recency; hit/miss counters are the
    /// caller's to update once the admission outcome is known. The prompt
    /// is hashed once (rolling, at block boundaries); the hash pre-filters
    /// candidates and a token comparison confirms, so a collision can never
    /// serve the wrong prefix. The hit carries the donor table to
    /// [`BlockTable::fork_prefix`] from, plus the entry's [`PrefillSeed`]
    /// when (and only when) its full prompt equals `ids` exactly.
    pub fn lookup(&mut self, ids: &[u32], block_size: usize) -> Option<PrefixHit<'_>> {
        let now = self.tick();
        let bounds = boundary_hashes(ids, block_size);
        let mut best: Option<usize> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let k = e.tokens.len() / block_size;
            if k < bounds.len()
                && e.tokens.len() <= ids.len()
                && best.map_or(true, |b| e.tokens.len() > self.entries[b].tokens.len())
                && e.hash == bounds[k]
                && ids.starts_with(&e.tokens)
            {
                best = Some(i);
            }
        }
        let i = best?;
        self.entries[i].last_used = now;
        let e = &self.entries[i];
        Some(PrefixHit {
            table: &e.table,
            seed: e.seed.as_ref().filter(|s| s.prompt == ids),
        })
    }

    /// The seed a full-prompt hit on `ids` would serve (exact match only).
    /// Read-only companion to [`lookup`](Self::lookup) for callers that need
    /// the seed data after the hit's borrow has ended. Deliberately a
    /// rescan by prompt rather than an entry index: pressure shedding
    /// (`swap_remove`) can reorder entries between the lookup and this
    /// call, so an index would be unsound. Must stay consistent with
    /// `lookup`'s seed rule: the entry's tokens prefix `ids` and the seed's
    /// full prompt equals `ids`.
    pub fn seed_for(&self, ids: &[u32]) -> Option<&PrefillSeed> {
        self.entries
            .iter()
            .filter(|e| ids.starts_with(&e.tokens))
            .find_map(|e| e.seed.as_ref().filter(|s| s.prompt == ids))
    }

    /// Register the whole-block prefix of a freshly-admitted row. `ids` is
    /// the full prompt; `donor` the row's block table (its first
    /// `len/block_size` blocks hold exactly `ids`' leading tokens); `seed`
    /// the admission's prefill outputs when the caller runs physical paging
    /// (None keeps the entry share-only). An entry already covering the
    /// prefix is kept — but gains the seed if it had none. No-op when the
    /// prefix spans no whole block (entries are keyed by their whole-block
    /// header, so sub-block prompts are never cached — nor prefill-skipped).
    /// Sheds LRU entries past `max_entries`.
    pub fn insert(
        &mut self,
        ids: &[u32],
        donor: &BlockTable,
        seed: Option<PrefillSeed>,
        pool: &mut BlockPool,
    ) {
        let bs = donor.block_size();
        let covered = (ids.len().min(donor.len()) / bs) * bs;
        if covered == 0 {
            return;
        }
        let tokens = &ids[..covered];
        if let Some(e) = self
            .entries
            .iter_mut()
            .find(|e| e.tokens.len() == covered && e.tokens == tokens)
        {
            // first seed wins: later different-tail prompts sharing this
            // header must not thrash the stored outputs
            if e.seed.is_none() {
                e.seed = seed;
            }
            return;
        }
        let table = BlockTable::fork_prefix(donor, covered, pool);
        debug_assert_eq!(table.len(), covered);
        let now = self.tick();
        self.entries.push(Entry {
            hash: prefix_hash(tokens),
            tokens: tokens.to_vec(),
            table,
            seed,
            last_used: now,
        });
        self.insertions += 1;
        while self.entries.len() > self.cfg.max_entries {
            self.shed_lru(pool);
        }
    }

    /// Invalidate the least-recently-used entry, releasing its block
    /// references. Returns false when the cache is already empty.
    pub fn shed_lru(&mut self, pool: &mut BlockPool) -> bool {
        let idx = self.lru_where(|_| true);
        self.shed_entry(idx, pool)
    }

    /// Invalidate the LRU entry whose shedding would actually return at
    /// least one block to the free list (a block the cache is the sole
    /// holder of). Returns false when no entry frees anything — shedding
    /// further would destroy reusable entries without relieving pressure,
    /// so the engine's allocation-pressure loops stop here and move on to
    /// preemption.
    pub fn shed_lru_reclaimable(&mut self, pool: &mut BlockPool) -> bool {
        let idx = self.lru_where(|e| e.table.blocks().iter().any(|&b| pool.refcount(b) == 1));
        self.shed_entry(idx, pool)
    }

    /// Invalidate the LRU entry referencing any of `blocks` — used by
    /// copy-on-write privatization to drop the cache's share of exactly the
    /// row's shared blocks (which frees nothing but lowers their refcount,
    /// often privatizing the row with no allocation at all). Returns false
    /// when no entry overlaps.
    pub fn shed_lru_overlapping(&mut self, blocks: &[BlockId], pool: &mut BlockPool) -> bool {
        let idx = self.lru_where(|e| e.table.blocks().iter().any(|b| blocks.contains(b)));
        self.shed_entry(idx, pool)
    }

    fn lru_where(&self, keep: impl Fn(&Entry) -> bool) -> Option<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| keep(e))
            .min_by_key(|(_, e)| e.last_used)
            .map(|(i, _)| i)
    }

    fn shed_entry(&mut self, idx: Option<usize>, pool: &mut BlockPool) -> bool {
        let Some(i) = idx else { return false };
        let mut e = self.entries.swap_remove(i);
        e.table.release_all(pool);
        self.invalidations += 1;
        true
    }

    /// Drop every entry (shutdown / tests / admin reset).
    pub fn clear(&mut self, pool: &mut BlockPool) {
        while self.shed_lru(pool) {}
    }

    /// The entry keys (whole-block header hashes), sorted — the replica's
    /// routing digest. The fleet router compares a prompt's block-boundary
    /// hashes against each replica's digest to place the request where the
    /// donor blocks live. Hashes are a *placement hint* only: a collision
    /// can at worst route a request to a replica whose cache then
    /// token-verifies and rejects the match ([`PrefixCache::lookup`]), so
    /// mis-routing never shares wrong bytes — it just forfeits one hit.
    pub fn digest(&self) -> Vec<u64> {
        let mut out: Vec<u64> = self.entries.iter().map(|e| e.hash).collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::PoolConfig;

    fn pool(n: usize) -> BlockPool {
        BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: n,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap()
    }

    fn table_for(ids_len: usize, pool: &mut BlockPool) -> BlockTable {
        let mut t = BlockTable::new(pool.block_size());
        for _ in 0..ids_len {
            assert!(t.push_token(pool));
        }
        t
    }

    #[test]
    fn miss_then_insert_then_hit() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..10).collect(); // 2 whole blocks + partial
        assert!(c.lookup(&ids, 4).is_none());

        let donor = table_for(10, &mut p);
        c.insert(&ids, &donor, None, &mut p);
        assert_eq!(c.len(), 1);
        assert_eq!(c.pinned_blocks(), 2); // whole blocks only
        assert_eq!(p.used_blocks(), 3); // sharing allocated nothing

        let hit = c.lookup(&ids, 4).expect("hit");
        assert_eq!(hit.table.len(), 8);
        // a prompt sharing only the first block's worth of tokens misses
        // (entries are keyed on their full whole-block prefix)
        let other: Vec<u32> = (0..4).chain([99, 98, 97, 96]).collect();
        assert!(c.lookup(&other, 4).is_none());
    }

    #[test]
    fn longest_matching_prefix_wins() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let long: Vec<u32> = (0..12).collect();
        let donor_short = table_for(4, &mut p);
        let donor_long = table_for(12, &mut p);
        c.insert(&long[..4], &donor_short, None, &mut p);
        c.insert(&long, &donor_long, None, &mut p);
        assert_eq!(c.len(), 2);
        let hit = c.lookup(&long, 4).unwrap();
        assert_eq!(hit.table.len(), 12);
        // a prompt extending only the short entry matches the short one
        let mid: Vec<u32> = (0..4).chain([50, 51]).collect();
        assert_eq!(c.lookup(&mid, 4).unwrap().table.len(), 4);
    }

    #[test]
    fn overlapping_entries_pin_distinct_blocks_once() {
        // A short and a long fork of the same header share their leading
        // blocks; the pinned-blocks gauge must count each block once.
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let long: Vec<u32> = (0..12).collect();
        let donor = table_for(12, &mut p);
        c.insert(&long[..4], &donor, None, &mut p); // pins block 0
        c.insert(&long, &donor, None, &mut p); // pins blocks 0, 1, 2
        assert_eq!(c.len(), 2);
        assert_eq!(c.pinned_blocks(), 3, "block 0 must not be double-counted");
    }

    fn seed_for_prompt(ids: &[u32]) -> PrefillSeed {
        PrefillSeed {
            prompt: ids.to_vec(),
            tail_k: vec![1.0; (ids.len() % 4) * 3],
            tail_v: vec![2.0; (ids.len() % 4) * 3],
            attn_last: vec![0.5; ids.len()],
            logits_last: vec![0.0; 8],
        }
    }

    #[test]
    fn seed_served_only_on_exact_full_prompt() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..10).collect(); // 2 whole blocks + 2-token tail
        let donor = table_for(10, &mut p);
        c.insert(&ids, &donor, Some(seed_for_prompt(&ids)), &mut p);
        // exact prompt: the hit carries the seed (prefill skippable)
        let hit = c.lookup(&ids, 4).unwrap();
        assert!(hit.seed.is_some());
        assert_eq!(hit.seed.unwrap().attn_last.len(), 10);
        // same whole-block header, divergent tail: sharing only, never the seed
        let mut other = ids.clone();
        other[9] = 99;
        let hit = c.lookup(&other, 4).unwrap();
        assert_eq!(hit.table.len(), 8);
        assert!(hit.seed.is_none(), "a divergent tail must not get the seed");
        assert!(c.seed_for(&ids).is_some());
        assert!(c.seed_for(&other).is_none());
        c.clear(&mut p);
    }

    #[test]
    fn first_seed_wins_and_seedless_entries_upgrade() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let a: Vec<u32> = (0..10).collect();
        let mut b = a.clone();
        b[9] = 99; // same 8-token header, different tail
        let donor = table_for(10, &mut p);
        // share-only insert first (e.g. a non-paged engine), then seeded
        c.insert(&a, &donor, None, &mut p);
        assert_eq!(c.len(), 1);
        c.insert(&a, &donor, Some(seed_for_prompt(&a)), &mut p);
        assert_eq!(c.len(), 1, "same header re-insert must not duplicate");
        assert!(c.seed_for(&a).is_some(), "seedless entry gains the seed");
        // a different-tail prompt maps to the same entry: seed is kept as-is
        c.insert(&b, &donor, Some(seed_for_prompt(&b)), &mut p);
        assert_eq!(c.len(), 1);
        assert!(c.seed_for(&a).is_some(), "first seed survives");
        assert!(c.seed_for(&b).is_none());
        c.clear(&mut p);
    }

    #[test]
    fn hash_collision_cannot_serve_wrong_tokens() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..4).collect();
        let donor = table_for(4, &mut p);
        c.insert(&ids, &donor, None, &mut p);
        // force the stored hash to collide with a different prompt
        c.entries[0].hash = prefix_hash(&[9, 9, 9, 9]);
        assert!(
            c.lookup(&[9, 9, 9, 9], 4).is_none(),
            "token check must reject"
        );
    }

    #[test]
    fn digest_lists_entry_hashes_sorted_deduped() {
        let mut p = pool(16);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        assert!(c.digest().is_empty(), "empty cache exports an empty digest");
        let a: Vec<u32> = (0..8).collect();
        let b: Vec<u32> = (20..24).collect();
        let ta = table_for(8, &mut p);
        let tb = table_for(4, &mut p);
        c.insert(&a, &ta, None, &mut p);
        c.insert(&b, &tb, None, &mut p);
        let d = c.digest();
        assert_eq!(d.len(), 2);
        assert!(d.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
        assert!(d.contains(&prefix_hash(&a)));
        assert!(d.contains(&prefix_hash(&b)));
        // digest keys are exactly the probe keys boundary_hashes computes,
        // so a router match implies the cache's own hash pre-filter matches
        assert_eq!(boundary_hashes(&a, 4)[2], prefix_hash(&a));
        c.clear(&mut p);
        assert!(c.digest().is_empty());
    }

    /// Fleet-routing companion to `hash_collision_cannot_serve_wrong_tokens`:
    /// two prompts with equal hashes but different tokens may be *routed*
    /// to the same replica (the digest is hash-only), but they can never
    /// *share* — the cache's token verification rejects the colliding
    /// probe, so the worst outcome of a collision is one lost hit.
    #[test]
    fn digest_collision_is_a_hint_never_a_share() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..4).collect();
        let donor = table_for(4, &mut p);
        c.insert(&ids, &donor, None, &mut p);
        let colliding: Vec<u32> = vec![9, 9, 9, 9];
        c.entries[0].hash = prefix_hash(&colliding);
        // the routing digest now matches the colliding prompt's header hash
        assert!(c.digest().contains(&boundary_hashes(&colliding, 4)[1]));
        // ...but a lookup on that replica still refuses to splice blocks
        assert!(c.lookup(&colliding, 4).is_none());
    }

    #[test]
    fn boundary_hashes_match_prefix_hash() {
        let ids: Vec<u32> = (0..11).collect();
        let bh = boundary_hashes(&ids, 4);
        assert_eq!(bh.len(), 3); // k = 0, 1, 2 (partial third block excluded)
        assert_eq!(bh[0], prefix_hash(&[]));
        assert_eq!(bh[1], prefix_hash(&ids[..4]));
        assert_eq!(bh[2], prefix_hash(&ids[..8]));
    }

    #[test]
    fn entries_pin_blocks_past_donor_release() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..8).collect();
        let mut donor = table_for(8, &mut p);
        c.insert(&ids, &donor, None, &mut p);
        donor.release_all(&mut p); // donor row finishes
        assert_eq!(p.used_blocks(), 2, "cache keeps the blocks alive");
        assert!(c.lookup(&ids, 4).is_some(), "entry survives its donor");
        c.clear(&mut p);
        assert_eq!(p.free_blocks(), 8, "clearing drains the pins");
    }

    #[test]
    fn capacity_sheds_lru_first() {
        let mut p = pool(32);
        let mut c = PrefixCache::new(PrefixCacheConfig { max_entries: 2 });
        let a: Vec<u32> = (0..4).collect();
        let b: Vec<u32> = (10..14).collect();
        let d: Vec<u32> = (20..24).collect();
        let ta = table_for(4, &mut p);
        let tb = table_for(4, &mut p);
        let td = table_for(4, &mut p);
        c.insert(&a, &ta, None, &mut p);
        c.insert(&b, &tb, None, &mut p);
        assert!(c.lookup(&a, 4).is_some()); // refresh a: b is now LRU
        c.insert(&d, &td, None, &mut p);
        assert_eq!(c.len(), 2);
        assert_eq!(c.invalidations, 1);
        assert!(c.lookup(&b, 4).is_none(), "LRU entry b was shed");
        assert!(c.lookup(&a, 4).is_some());
        assert!(c.lookup(&d, 4).is_some());
    }

    #[test]
    fn shed_frees_unshared_blocks() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..8).collect();
        let mut donor = table_for(8, &mut p);
        c.insert(&ids, &donor, None, &mut p);
        donor.release_all(&mut p);
        assert_eq!(p.free_blocks(), 6);
        assert!(c.shed_lru(&mut p));
        assert_eq!(p.free_blocks(), 8, "sole-owner pins return to the pool");
        assert!(!c.shed_lru(&mut p), "empty cache has nothing to shed");
    }

    #[test]
    fn reclaimable_shed_skips_entries_that_free_nothing() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        // entry A: blocks shared with a live "row" (donor kept) — frees 0
        let ids_a: Vec<u32> = (0..4).collect();
        let donor_a = table_for(4, &mut p); // stays alive: rc 2 after insert
        c.insert(&ids_a, &donor_a, None, &mut p);
        // entry B: donor released — the cache is sole holder, frees 1
        let ids_b: Vec<u32> = (10..14).collect();
        let mut donor_b = table_for(4, &mut p);
        c.insert(&ids_b, &donor_b, None, &mut p);
        donor_b.release_all(&mut p);
        // make A the LRU so a naive shed would pick it
        assert!(c.lookup(&ids_b, 4).is_some());
        let free_before = p.free_blocks();
        assert_eq!(c.reclaimable_blocks(&p), 1, "only B's block is sole-held");
        assert!(c.shed_lru_reclaimable(&mut p));
        assert_eq!(p.free_blocks(), free_before + 1, "must shed B, not A");
        assert!(c.lookup(&ids_a, 4).is_some(), "useless-to-shed A survives");
        // A is still pinned by its donor: nothing reclaimable remains
        assert!(!c.shed_lru_reclaimable(&mut p));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn overlapping_shed_targets_the_shared_blocks() {
        let mut p = pool(8);
        let mut c = PrefixCache::new(PrefixCacheConfig::default());
        let ids_a: Vec<u32> = (0..4).collect();
        let ids_b: Vec<u32> = (10..14).collect();
        let donor_a = table_for(4, &mut p);
        let donor_b = table_for(4, &mut p);
        c.insert(&ids_a, &donor_a, None, &mut p);
        c.insert(&ids_b, &donor_b, None, &mut p);
        let target = donor_b.blocks().to_vec();
        assert!(c.shed_lru_overlapping(&target, &mut p));
        assert!(c.lookup(&ids_b, 4).is_none(), "overlapping entry shed");
        assert!(c.lookup(&ids_a, 4).is_some(), "unrelated entry survives");
        assert!(
            !c.shed_lru_overlapping(&target, &mut p),
            "no entry references those blocks any more"
        );
        assert_eq!(p.refcount(target[0]), 1, "donor is sole holder again");
    }
}
