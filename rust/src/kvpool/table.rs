//! Per-sequence block table: compacted slot index → (block, offset).
//!
//! `SeqKv` keeps live tokens compacted in slots `[0, len)`, so the mapping
//! is dense: slot `s` lives at `(blocks[s / block_size], s % block_size)`.
//! Growth allocates a block only when crossing a block boundary; shrinking
//! (after an eviction pass) releases whole trailing blocks back to the pool
//! — that reclamation is what turns lagged eviction into cross-sequence
//! serving capacity.
//!
//! ## Invariants
//!
//! * **Dense mapping** — `len` tokens always occupy the leading `len` slots
//!   of the held blocks, in order; only the tail block may be partial.
//! * **Shared blocks are immutable** — a block with refcount > 1 (prefix
//!   fork / cache pin) is never written through this table. Any operation
//!   that would (a push into a shared partial tail, an eviction compaction
//!   over shared blocks) swaps in a fresh private block first
//!   (copy-on-write). The *logical* swap happens here; when physical K/V
//!   storage is attached, the byte duplication it implies is reported as a
//!   [`BlockCopy`] through the `_cow` method variants, and the caller must
//!   apply it to the storage **before the next write** or the new private
//!   block reads as garbage. Callers with no physical storage (capacity
//!   simulation, logical-only tests) use the plain variants, which drop the
//!   descriptors.
//! * **Exhaustion is non-destructive** — every allocating operation returns
//!   `false` with the table unchanged when the pool is dry; callers shed
//!   cache pins or preempt and retry. A partially-completed
//!   [`ensure_private`](BlockTable::ensure_private) keeps its progress
//!   (already-privatized blocks stay private) and is safe to retry.
//! * **Release accounting is physical** — `truncate`/`release_all` count
//!   only blocks that actually returned to the free list; dropping a shared
//!   reference frees nothing and must not be reported as reclaimed capacity.

use super::arena::BlockCopy;
use super::pool::{BlockId, BlockPool};

#[derive(Clone, Debug)]
pub struct BlockTable {
    block_size: usize,
    blocks: Vec<BlockId>,
    /// Tokens currently mapped (== owning SeqKv's live count).
    len: usize,
}

impl BlockTable {
    pub fn new(block_size: usize) -> BlockTable {
        assert!(block_size >= 1, "block_size must be >= 1");
        BlockTable {
            block_size,
            blocks: Vec::new(),
            len: 0,
        }
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn n_blocks(&self) -> usize {
        self.blocks.len()
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    /// Tokens the currently-held blocks can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.blocks.len() * self.block_size
    }

    /// Will the next `push_token` need a fresh block?
    pub fn at_block_boundary(&self) -> bool {
        self.len == self.capacity_tokens()
    }

    /// Physical location of a mapped slot.
    pub fn locate(&self, slot: usize) -> Option<(BlockId, usize)> {
        if slot >= self.len {
            return None;
        }
        Some((self.blocks[slot / self.block_size], slot % self.block_size))
    }

    /// Is the partially-filled tail block (the one the next in-block push
    /// would write into) shared with other holders?
    pub fn tail_is_shared(&self, pool: &BlockPool) -> bool {
        if self.at_block_boundary() {
            return false;
        }
        self.blocks
            .last()
            .map_or(false, |&b| pool.refcount(b) > 1)
    }

    /// Map one more token, allocating a block at boundaries. A push that
    /// would land inside a *shared* tail block (possible after truncating
    /// into a forked prefix) copies-on-write first: the shared block is
    /// swapped for a fresh private one, so the donor's mapping is never
    /// mutated. Returns false (state unchanged) when the pool is exhausted.
    ///
    /// Logical-only variant: any CoW byte duplication the swap implies is
    /// dropped. Callers with attached physical storage must use
    /// [`push_token_cow`](Self::push_token_cow).
    pub fn push_token(&mut self, pool: &mut BlockPool) -> bool {
        self.push_inner(pool, None)
    }

    /// [`push_token`](Self::push_token) that reports the [`BlockCopy`] a
    /// shared-tail copy-on-write implies, so the caller can duplicate the
    /// occupied K/V rows into the fresh block before anything reads it.
    pub fn push_token_cow(&mut self, pool: &mut BlockPool, copies: &mut Vec<BlockCopy>) -> bool {
        self.push_inner(pool, Some(copies))
    }

    fn push_inner(&mut self, pool: &mut BlockPool, copies: Option<&mut Vec<BlockCopy>>) -> bool {
        debug_assert_eq!(self.block_size, pool.block_size(), "table/pool block size");
        if self.at_block_boundary() {
            match pool.alloc() {
                Some(b) => self.blocks.push(b),
                None => return false,
            }
        } else if self.tail_is_shared(pool) {
            match pool.alloc() {
                Some(fresh) => {
                    // rows already occupied in the (partial) shared tail
                    let rows = self.len - (self.blocks.len() - 1) * self.block_size;
                    let tail = self.blocks.last_mut().expect("non-boundary ⇒ tail");
                    if let Some(c) = copies {
                        c.push(BlockCopy { src: *tail, dst: fresh, rows });
                    }
                    pool.release(*tail);
                    *tail = fresh;
                }
                None => return false,
            }
        }
        self.len += 1;
        true
    }

    /// Shrink to `new_len` tokens, dropping references to whole trailing
    /// blocks. A shared trailing block (refcount > 1) only loses this
    /// table's reference — it stays allocated for its other holders and is
    /// NOT handed back to the free list. Returns how many blocks actually
    /// returned to the free list (the capacity an eviction pass reclaimed).
    pub fn truncate(&mut self, new_len: usize, pool: &mut BlockPool) -> usize {
        assert!(new_len <= self.len, "truncate {} > len {}", new_len, self.len);
        self.len = new_len;
        let needed = (new_len + self.block_size - 1) / self.block_size;
        let mut released = 0;
        while self.blocks.len() > needed {
            if pool.release(self.blocks.pop().expect("blocks non-empty")) {
                released += 1;
            }
        }
        released
    }

    /// Release every block (sequence finished or preempted).
    pub fn release_all(&mut self, pool: &mut BlockPool) -> usize {
        self.truncate(0, pool)
    }

    /// New table sharing the longest whole-block prefix of `other` that
    /// covers at most `n_tokens` tokens (refcounts bumped). The shared
    /// region maps `n_full_blocks * block_size` tokens; the caller allocates
    /// privately from there.
    pub fn fork_prefix(other: &BlockTable, n_tokens: usize, pool: &mut BlockPool) -> BlockTable {
        let n_full = (n_tokens.min(other.len) / other.block_size).min(other.blocks.len());
        let blocks: Vec<BlockId> = other.blocks[..n_full].to_vec();
        for &b in &blocks {
            pool.retain(b);
        }
        BlockTable {
            block_size: other.block_size,
            len: n_full * other.block_size,
            blocks,
        }
    }

    /// Count of blocks this table shares with other holders.
    pub fn n_shared_blocks(&self, pool: &BlockPool) -> usize {
        self.blocks
            .iter()
            .filter(|&&b| pool.refcount(b) > 1)
            .count()
    }

    /// Ids of the blocks this table shares with other holders — the
    /// targets a copy-on-write pass wants other holders (e.g. the prefix
    /// cache) to release first.
    pub fn shared_block_ids(&self, pool: &BlockPool) -> Vec<BlockId> {
        self.blocks
            .iter()
            .copied()
            .filter(|&b| pool.refcount(b) > 1)
            .collect()
    }

    /// Copy-on-write: replace every shared block with a freshly-allocated
    /// private one. Returns false if the pool ran out mid-way (the table
    /// stays consistent — already-privatized blocks keep their new ids,
    /// remaining shared blocks are untouched; safe to retry after blocks
    /// free up).
    ///
    /// Logical-only variant; see [`ensure_private_cow`](Self::ensure_private_cow)
    /// when physical K/V storage is attached.
    pub fn ensure_private(&mut self, pool: &mut BlockPool) -> bool {
        self.ensure_private_inner(pool, None)
    }

    /// [`ensure_private`](Self::ensure_private) that reports one
    /// [`BlockCopy`] per replaced block (occupied rows only), so the caller
    /// can duplicate the K/V bytes into each fresh private block. On a
    /// `false` return the copies already pushed are still valid — they
    /// describe the blocks that *were* privatized — and must be applied.
    pub fn ensure_private_cow(
        &mut self,
        pool: &mut BlockPool,
        copies: &mut Vec<BlockCopy>,
    ) -> bool {
        self.ensure_private_inner(pool, Some(copies))
    }

    fn ensure_private_inner(
        &mut self,
        pool: &mut BlockPool,
        mut copies: Option<&mut Vec<BlockCopy>>,
    ) -> bool {
        for i in 0..self.blocks.len() {
            let b = self.blocks[i];
            if pool.refcount(b) > 1 {
                match pool.alloc() {
                    Some(fresh) => {
                        let rows = (self.len - i * self.block_size).min(self.block_size);
                        if let Some(c) = copies.as_mut() {
                            c.push(BlockCopy { src: b, dst: fresh, rows });
                        }
                        pool.release(b);
                        self.blocks[i] = fresh;
                    }
                    None => return false,
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::PoolConfig;

    fn pool(n_blocks: usize) -> BlockPool {
        BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap()
    }

    fn grow(t: &mut BlockTable, n: usize, pool: &mut BlockPool) {
        for _ in 0..n {
            assert!(t.push_token(pool));
        }
    }

    #[test]
    fn growth_allocates_at_boundaries() {
        let mut p = pool(8);
        let mut t = BlockTable::new(4);
        assert!(t.at_block_boundary()); // empty: first push allocates
        grow(&mut t, 4, &mut p);
        assert_eq!(t.n_blocks(), 1);
        assert!(t.at_block_boundary());
        grow(&mut t, 1, &mut p);
        assert_eq!(t.n_blocks(), 2);
        assert_eq!(t.len(), 5);
        assert_eq!(p.used_blocks(), 2);
    }

    #[test]
    fn locate_maps_slots_densely() {
        let mut p = pool(8);
        let mut t = BlockTable::new(4);
        grow(&mut t, 9, &mut p);
        let (b0, o0) = t.locate(0).unwrap();
        let (b5, o5) = t.locate(5).unwrap();
        let (b8, o8) = t.locate(8).unwrap();
        assert_eq!((b0, o0), (t.blocks()[0], 0));
        assert_eq!((b5, o5), (t.blocks()[1], 1));
        assert_eq!((b8, o8), (t.blocks()[2], 0));
        assert!(t.locate(9).is_none());
    }

    #[test]
    fn truncate_releases_whole_blocks_only() {
        let mut p = pool(8);
        let mut t = BlockTable::new(4);
        grow(&mut t, 16, &mut p); // 4 blocks
        let released = t.truncate(5, &mut p); // needs 2 blocks
        assert_eq!(released, 2);
        assert_eq!(t.n_blocks(), 2);
        assert_eq!(p.free_blocks(), 6);
        // partial block at the tail is retained
        assert_eq!(t.truncate(5, &mut p), 0);
        assert_eq!(t.release_all(&mut p), 2);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn exhaustion_leaves_state_consistent() {
        let mut p = pool(2);
        let mut t = BlockTable::new(4);
        grow(&mut t, 8, &mut p);
        assert!(!t.push_token(&mut p)); // pool empty at the boundary
        assert_eq!(t.len(), 8);
        assert_eq!(t.n_blocks(), 2);
        assert_eq!(p.failed_allocs, 1);
    }

    #[test]
    fn fork_prefix_shares_whole_blocks() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 10, &mut p); // 3 blocks, last partial
        let b = BlockTable::fork_prefix(&a, 10, &mut p);
        assert_eq!(b.n_blocks(), 2); // only full blocks shared
        assert_eq!(b.len(), 8);
        assert_eq!(p.refcount(a.blocks()[0]), 2);
        assert_eq!(p.refcount(a.blocks()[2]), 1);
        assert_eq!(a.n_shared_blocks(&p), 2);
        // sharing consumed no new blocks
        assert_eq!(p.used_blocks(), 3);
        // releasing the fork leaves the original intact
        let mut b = b;
        b.release_all(&mut p);
        assert_eq!(p.refcount(a.blocks()[0]), 1);
        assert_eq!(p.used_blocks(), 3);
        a.release_all(&mut p);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn ensure_private_copies_on_write() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p);
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        assert_eq!(b.n_shared_blocks(&p), 2);
        assert!(b.ensure_private(&mut p));
        assert_eq!(b.n_shared_blocks(&p), 0);
        assert_eq!(a.n_shared_blocks(&p), 0);
        // two tables, four blocks total now
        assert_eq!(p.used_blocks(), 4);
        a.release_all(&mut p);
        b.release_all(&mut p);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn truncate_on_shared_blocks_drops_refs_not_capacity() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 12, &mut p); // 3 blocks
        let mut b = BlockTable::fork_prefix(&a, 12, &mut p); // shares all 3
        assert_eq!(p.used_blocks(), 3);
        let free_before = p.free_blocks();
        // truncating the fork through two shared blocks must not free them —
        // the donor still holds both — and must not count them as released
        let released = b.truncate(2, &mut p);
        assert_eq!(released, 0, "shared blocks are not reclaimed capacity");
        assert_eq!(p.free_blocks(), free_before);
        assert_eq!(b.n_blocks(), 1);
        // the donor's mapping is fully intact
        assert_eq!(a.n_blocks(), 3);
        assert_eq!(a.len(), 12);
        assert_eq!(p.refcount(a.blocks()[1]), 1);
        assert_eq!(p.refcount(a.blocks()[2]), 1);
        assert_eq!(p.refcount(a.blocks()[0]), 2); // still shared with b
        a.release_all(&mut p);
        b.release_all(&mut p);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn push_into_shared_tail_copies_on_write() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p); // 2 full blocks
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        // truncate into the middle of the shared prefix: tail now shared+partial
        b.truncate(2, &mut p);
        assert!(b.tail_is_shared(&p));
        let donor_block = a.blocks()[0];
        assert_eq!(b.blocks()[0], donor_block);
        // the next push would write slot 2 of the shared block → must CoW
        assert!(b.push_token(&mut p));
        assert_ne!(b.blocks()[0], donor_block, "shared tail must be copied");
        assert!(!b.tail_is_shared(&p));
        assert_eq!(p.refcount(donor_block), 1); // donor sole owner again
        assert_eq!(b.len(), 3);
        // donor untouched throughout, and nothing of it is shared any more
        // (the truncate dropped b's ref on block 1, the CoW on block 0)
        assert_eq!(a.len(), 8);
        assert_eq!(a.n_shared_blocks(&p), 0);
        a.release_all(&mut p);
        b.release_all(&mut p);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn cow_push_reports_the_block_copy() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p);
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        b.truncate(2, &mut p); // shared partial tail: 2 occupied rows
        let donor_block = a.blocks()[0];
        let mut copies = Vec::new();
        assert!(b.push_token_cow(&mut p, &mut copies));
        assert_eq!(copies.len(), 1, "one shared tail ⇒ one copy");
        assert_eq!(copies[0].src, donor_block);
        assert_eq!(copies[0].dst, b.blocks()[0]);
        assert_eq!(copies[0].rows, 2, "only pre-push occupied rows copy");
        // an ordinary boundary push reports nothing
        copies.clear();
        grow(&mut b, 1, &mut p);
        assert!(b.push_token_cow(&mut p, &mut copies));
        assert!(copies.is_empty());
        a.release_all(&mut p);
        b.release_all(&mut p);
    }

    #[test]
    fn ensure_private_cow_reports_occupied_rows_per_block() {
        let mut p = pool(8);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p); // 2 full blocks
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        let mut copies = Vec::new();
        assert!(b.ensure_private_cow(&mut p, &mut copies));
        assert_eq!(copies.len(), 2);
        assert_eq!(copies[0].src, a.blocks()[0]);
        assert_eq!(copies[0].dst, b.blocks()[0]);
        assert_eq!(copies[0].rows, 4, "full block copies block_size rows");
        assert_eq!(copies[1].rows, 4);
        a.release_all(&mut p);
        b.release_all(&mut p);
        assert_eq!(p.free_blocks(), 8);
    }

    #[test]
    fn cow_push_fails_cleanly_when_pool_dry() {
        let mut p = pool(2);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p); // both blocks
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        b.truncate(1, &mut p); // shared partial tail, pool has no spare
        assert!(!b.push_token(&mut p), "CoW with a dry pool must fail");
        assert_eq!(b.len(), 1, "failed push leaves state unchanged");
        assert_eq!(b.blocks()[0], a.blocks()[0]);
        b.release_all(&mut p);
        a.release_all(&mut p);
        assert_eq!(p.free_blocks(), 2);
    }

    #[test]
    fn ensure_private_reports_exhaustion() {
        let mut p = pool(2);
        let mut a = BlockTable::new(4);
        grow(&mut a, 8, &mut p); // uses both blocks
        let mut b = BlockTable::fork_prefix(&a, 8, &mut p);
        assert!(!b.ensure_private(&mut p)); // no spare block for the copy
        // still consistent: can be released safely
        b.release_all(&mut p);
        a.release_all(&mut p);
        assert_eq!(p.free_blocks(), 2);
    }
}
