//! The fixed-size block allocator: free list + per-block refcounts.
//!
//! ## Invariants
//!
//! * **Conservation** — every block is either on the free list (refcount 0)
//!   or held by at least one reference; `free_blocks() + used_blocks() ==
//!   n_blocks` at all times. Double-free and retain-of-free are programming
//!   errors and panic (they would silently corrupt another holder's data
//!   once physical storage is attached).
//! * **Release reports physical reclamation** — [`BlockPool::release`]
//!   returns `true` only when the last reference dropped and the block
//!   actually rejoined the free list. Dropping a *shared* reference changes
//!   nothing about pool pressure; callers accounting freed capacity
//!   (`BlockTable::truncate`, eviction passes) must count only `true`
//!   returns, or forked rows inflate the reclaimed-capacity numbers.
//! * **Single-owner mutation** — the pool is `&mut`-threaded through one
//!   engine's decode loop; there is no interior locking. Cloning the pool
//!   clones *bookkeeping only* (simulators do this); physical K/V storage
//!   lives with the backend, never here, so a clone can never alias tensors.
//!
//! ## Failure modes
//!
//! Exhaustion is a normal state, not an error: [`BlockPool::alloc`] returns
//! `None` (and counts `failed_allocs`), and the engine responds by shedding
//! prefix-cache pins, then preempting the youngest row. The [`PoolPressure`]
//! snapshot carries the configured watermarks so the scheduler's admission
//! latch (`scheduler::admission`) can hold the queue *before* exhaustion
//! turns into preemption thrash.

/// Index of a block inside one [`BlockPool`].
pub type BlockId = u32;

/// Pool sizing and the admission watermarks read by the scheduler.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Tokens per block (vLLM uses 16; same default here).
    pub block_size: usize,
    /// Total blocks in the pool — the global KV budget.
    pub n_blocks: usize,
    /// Hold new admissions while `free < low_watermark` (blocks).
    pub low_watermark: usize,
    /// Resume admissions once `free >= high_watermark` (blocks).
    pub high_watermark: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            block_size: 16,
            n_blocks: 64,
            low_watermark: 4,
            high_watermark: 8,
        }
    }
}

impl PoolConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.block_size >= 1, "block_size must be >= 1");
        anyhow::ensure!(self.n_blocks >= 1, "pool needs at least one block");
        anyhow::ensure!(
            self.low_watermark <= self.high_watermark,
            "low watermark {} > high watermark {}",
            self.low_watermark,
            self.high_watermark
        );
        anyhow::ensure!(
            self.high_watermark <= self.n_blocks,
            "high watermark {} > pool size {}",
            self.high_watermark,
            self.n_blocks
        );
        Ok(())
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        (tokens + self.block_size - 1) / self.block_size
    }
}

/// Instantaneous pool state + the configured watermarks — everything the
/// admission controller needs in one copyable value.
#[derive(Clone, Copy, Debug)]
pub struct PoolPressure {
    pub free: usize,
    pub total: usize,
    pub low_watermark: usize,
    pub high_watermark: usize,
}

impl PoolPressure {
    /// Below the hold threshold: stop admitting.
    pub fn below_low(&self) -> bool {
        self.free < self.low_watermark
    }

    /// Recovered past the resume threshold.
    pub fn at_or_above_high(&self) -> bool {
        self.free >= self.high_watermark
    }

    pub fn utilization(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            (self.total - self.free) as f64 / self.total as f64
        }
    }
}

/// Fixed-size refcounted block allocator. Single-owner (`&mut`) by design:
/// it lives inside one engine's decode loop, which is single-threaded.
#[derive(Clone, Debug)]
pub struct BlockPool {
    cfg: PoolConfig,
    /// Per-block reference count; 0 = free.
    refcount: Vec<u32>,
    /// Free-list stack of block ids.
    free: Vec<BlockId>,
    /// Lifetime counters (metrics).
    pub alloc_count: u64,
    pub failed_allocs: u64,
}

impl BlockPool {
    pub fn new(cfg: PoolConfig) -> anyhow::Result<BlockPool> {
        cfg.validate()?;
        let n = cfg.n_blocks;
        Ok(BlockPool {
            cfg,
            refcount: vec![0; n],
            // pop() hands out low ids first
            free: (0..n as BlockId).rev().collect(),
            alloc_count: 0,
            failed_allocs: 0,
        })
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    pub fn total_blocks(&self) -> usize {
        self.cfg.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.cfg.n_blocks - self.free.len()
    }

    /// Fraction of the pool currently allocated.
    pub fn utilization(&self) -> f64 {
        if self.cfg.n_blocks == 0 {
            0.0
        } else {
            self.used_blocks() as f64 / self.cfg.n_blocks as f64
        }
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for(&self, tokens: usize) -> usize {
        self.cfg.blocks_for(tokens)
    }

    /// Take one free block (refcount 1), or `None` when exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        match self.free.pop() {
            Some(b) => {
                debug_assert_eq!(self.refcount[b as usize], 0);
                self.refcount[b as usize] = 1;
                self.alloc_count += 1;
                Some(b)
            }
            None => {
                self.failed_allocs += 1;
                None
            }
        }
    }

    /// Add a reference to an already-allocated block (prefix sharing).
    pub fn retain(&mut self, b: BlockId) {
        let rc = &mut self.refcount[b as usize];
        assert!(*rc > 0, "retain of free block {b}");
        *rc += 1;
    }

    /// Drop one reference. Returns true when this was the last reference and
    /// the block actually went back to the free list — callers accounting
    /// freed capacity (e.g. `BlockTable::truncate`) must count only those,
    /// since releasing a shared block changes nothing about pool pressure.
    pub fn release(&mut self, b: BlockId) -> bool {
        let rc = &mut self.refcount[b as usize];
        assert!(*rc > 0, "double free of block {b}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(b);
            true
        } else {
            false
        }
    }

    /// Number of blocks currently shared (refcount > 1) — prefix-cache /
    /// CoW visibility for gauges and tests.
    pub fn shared_blocks(&self) -> usize {
        self.refcount.iter().filter(|&&rc| rc > 1).count()
    }

    pub fn refcount(&self, b: BlockId) -> u32 {
        self.refcount[b as usize]
    }

    pub fn pressure(&self) -> PoolPressure {
        PoolPressure {
            free: self.free.len(),
            total: self.cfg.n_blocks,
            low_watermark: self.cfg.low_watermark,
            high_watermark: self.cfg.high_watermark,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(n: usize) -> BlockPool {
        BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: n,
            low_watermark: 1,
            high_watermark: 2,
        })
        .unwrap()
    }

    #[test]
    fn alloc_until_exhausted_then_free_restores() {
        let mut p = pool(3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        assert_eq!(p.free_blocks(), 0);
        assert!(p.alloc().is_none());
        assert_eq!(p.failed_allocs, 1);
        p.release(b);
        assert_eq!(p.free_blocks(), 1);
        let d = p.alloc().unwrap();
        assert_eq!(d, b); // the freed block is reused
        assert_eq!(p.used_blocks(), 3);
        p.release(a);
        p.release(c);
        p.release(d);
        assert_eq!(p.free_blocks(), 3);
        assert!((p.utilization() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn refcount_shares_until_last_release() {
        let mut p = pool(2);
        let b = p.alloc().unwrap();
        p.retain(b);
        p.retain(b);
        assert_eq!(p.refcount(b), 3);
        assert_eq!(p.shared_blocks(), 1);
        // dropping a shared reference frees nothing
        assert!(!p.release(b));
        assert!(!p.release(b));
        assert_eq!(p.free_blocks(), 1); // still held once
        assert_eq!(p.refcount(b), 1);
        assert_eq!(p.shared_blocks(), 0);
        // the last reference actually returns the block
        assert!(p.release(b));
        assert_eq!(p.free_blocks(), 2);
        assert_eq!(p.refcount(b), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = pool(1);
        let b = p.alloc().unwrap();
        p.release(b);
        p.release(b);
    }

    #[test]
    #[should_panic(expected = "retain of free")]
    fn retain_free_block_panics() {
        let mut p = pool(1);
        p.retain(0);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(4);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(4), 1);
        assert_eq!(p.blocks_for(5), 2);
    }

    #[test]
    fn pressure_watermarks() {
        let mut p = pool(3); // low 1, high 2
        assert!(!p.pressure().below_low());
        assert!(p.pressure().at_or_above_high());
        let _a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        let c = p.alloc().unwrap();
        let pr = p.pressure();
        assert!(pr.below_low());
        assert!(!pr.at_or_above_high());
        assert!((pr.utilization() - 1.0).abs() < 1e-12);
        p.release(c);
        assert!(!p.pressure().below_low()); // free 1 == low 1: not below
        assert!(!p.pressure().at_or_above_high());
    }

    #[test]
    fn config_validation() {
        assert!(PoolConfig::default().validate().is_ok());
        assert!(PoolConfig {
            block_size: 0,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(PoolConfig {
            low_watermark: 9,
            high_watermark: 3,
            ..Default::default()
        }
        .validate()
        .is_err());
        assert!(PoolConfig {
            n_blocks: 4,
            low_watermark: 2,
            high_watermark: 8,
            ..Default::default()
        }
        .validate()
        .is_err());
    }
}
