//! Runtime invariant auditor — the dynamic counterpart of the `lazylint`
//! static pass ([`crate::analysis`]).
//!
//! The pool/tier stack rests on conservation laws that no single module can
//! check alone: block refcounts are distributed across row tables and
//! prefix-cache forks, tier bytes across parked entries, pin ownership
//! across preemption snapshots that ride a queue the engine does not own.
//! The [`Auditor`] takes one consistent view of all of it — assembled by
//! `Engine::audit_invariants` at a step boundary — and checks:
//!
//! 1. **Refcount conservation** — for every block, the pool's refcount
//!    equals the number of references actually held: row block tables plus
//!    prefix-cache entry forks (with multiplicity,
//!    [`PrefixCache::pinned_block_ids`](crate::kvpool::PrefixCache::pinned_block_ids)).
//!    A leak (refcount > holders) silently shrinks serving capacity; the
//!    reverse (holders > refcount) means a future release will free a block
//!    someone still reads.
//! 2. **Free-list / live-set disjointness** — zero-refcount blocks match
//!    the free list's size exactly, and `free + used == total`.
//! 3. **Slot identity** — every table maps its `len` slots densely
//!    (`locate` resolves each one) into in-bounds, live blocks; the tail
//!    block is the only partial one.
//! 4. **Tier byte-budget conservation** — parked entry bytes sum to
//!    `bytes_in_use`, never exceed `max_bytes`, and the entry count matches
//!    `parked_blocks`.
//! 5. **Pinned entries never shed** — every swap-preemption pin reference
//!    resolves to a live, pinned tier entry with the expected row count
//!    (the tier's "a resume can never lose its bytes" promise). In
//!    *strict* mode the reverse also holds: every pinned entry is owned by
//!    a known pin reference. Strict only makes sense when the caller can
//!    enumerate *all* outstanding preemption snapshots (tests and benches
//!    after a full drain); at step boundaries snapshots live in queues
//!    outside the engine, so the step hook audits non-strict.
//! 6. **Ledger references** — a row's demotion ledger entry that still
//!    resolves must be unpinned with a matching record count; a missing
//!    entry is legal (shed under byte pressure — the demotion became a
//!    plain eviction).
//!
//! Violations panic (via [`Auditor::assert_clean`]) with a full owner dump,
//! so the failing test names the row/request/cache holder of every block
//! involved. The automatic step-boundary hook is compiled only under
//! `debug_assertions`; release callers (the quick-bench gate in CI) invoke
//! `Engine::audit_invariants` explicitly at drain points.

use super::pool::{BlockId, BlockPool};
use super::table::BlockTable;
use crate::kvtier::TierBlockId;

/// One table holding block references, tagged with who owns it.
pub struct TableRef<'a> {
    /// Human-readable owner (`"row 3 (req 17)"`, `"prefix-cache entry"`).
    pub owner: String,
    pub table: &'a BlockTable,
}

/// Snapshot of one parked tier entry (from `HostTier::entries_for_audit`).
#[derive(Clone, Debug)]
pub struct TierEntryInfo {
    pub id: TierBlockId,
    pub rows: usize,
    pub pinned: bool,
    pub bytes: usize,
}

/// Snapshot of the host tier's accounting.
#[derive(Clone, Debug, Default)]
pub struct TierView {
    pub max_bytes: usize,
    pub bytes_in_use: usize,
    pub parked_blocks: usize,
    pub entries: Vec<TierEntryInfo>,
}

impl TierView {
    /// Assemble from a live tier.
    pub fn of(t: &crate::kvtier::HostTier) -> TierView {
        TierView {
            max_bytes: t.max_bytes(),
            bytes_in_use: t.bytes_in_use(),
            parked_blocks: t.parked_blocks(),
            entries: t
                .entries_for_audit()
                .into_iter()
                .map(|(id, rows, pinned, bytes)| TierEntryInfo {
                    id,
                    rows,
                    pinned,
                    bytes,
                })
                .collect(),
        }
    }
}

/// A swap-preemption snapshot's claim on one pinned tier entry.
#[derive(Clone, Debug)]
pub struct PinRef {
    pub owner: String,
    pub tier_id: TierBlockId,
    pub rows: usize,
}

/// A row's demotion-ledger claim on one unpinned tier entry.
#[derive(Clone, Debug)]
pub struct LedgerRef {
    pub owner: String,
    pub tier_id: TierBlockId,
    pub records: usize,
}

/// One detected inconsistency: which law broke, and the evidence.
#[derive(Clone, Debug)]
pub struct AuditViolation {
    /// Short law name (`"refcount-conservation"`, `"tier-budget"`, …).
    pub law: &'static str,
    pub detail: String,
}

impl std::fmt::Display for AuditViolation {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(w, "[{}] {}", self.law, self.detail)
    }
}

/// One consistent view of the pool/tier ownership graph, ready to check.
/// Plain data by design: tests hand-build views with injected violations
/// to prove each law actually trips.
pub struct Auditor<'a> {
    pub pool: &'a BlockPool,
    /// Row block tables (and any other table-shaped holders).
    pub tables: Vec<TableRef<'a>>,
    /// Prefix-cache block references, with multiplicity.
    pub cache_blocks: Vec<BlockId>,
    pub tier: Option<TierView>,
    /// Swap-preemption pins from every snapshot the caller can see.
    pub pins: Vec<PinRef>,
    /// Demotion-ledger references from live rows and queued snapshots.
    pub ledgers: Vec<LedgerRef>,
    /// Require every pinned tier entry to be owned by a known [`PinRef`].
    /// Only sound when `pins` covers *all* outstanding snapshots (post-drain
    /// tests/benches) — at step boundaries snapshots live outside the engine.
    pub strict_pins: bool,
}

impl<'a> Auditor<'a> {
    /// Run every law; first violation wins.
    pub fn check(&self) -> Result<(), AuditViolation> {
        self.check_refcounts()?;
        self.check_free_list()?;
        self.check_slot_identity()?;
        self.check_tier()?;
        Ok(())
    }

    /// [`check`](Self::check), panicking with a full owner dump on failure.
    /// `context` names the call site (`"step end"`, `"bench drain"`).
    pub fn assert_clean(&self, context: &str) {
        if let Err(v) = self.check() {
            panic!(
                "kvpool audit failed at {context}: {v}\n{}",
                self.owner_dump()
            );
        }
    }

    /// Expected refcount per block from the holders the caller enumerated.
    fn expected_refcounts(&self) -> Vec<u32> {
        let mut exp = vec![0u32; self.pool.total_blocks()];
        for tr in &self.tables {
            for &b in tr.table.blocks() {
                if let Some(slot) = exp.get_mut(b as usize) {
                    *slot += 1;
                }
            }
        }
        for &b in &self.cache_blocks {
            if let Some(slot) = exp.get_mut(b as usize) {
                *slot += 1;
            }
        }
        exp
    }

    fn check_refcounts(&self) -> Result<(), AuditViolation> {
        for (b, &expected) in self.expected_refcounts().iter().enumerate() {
            let actual = self.pool.refcount(b as BlockId);
            if actual != expected {
                return Err(AuditViolation {
                    law: "refcount-conservation",
                    detail: format!(
                        "block {b}: pool refcount {actual}, but {expected} reference(s) held \
                         ({} leaked)",
                        actual as i64 - expected as i64
                    ),
                });
            }
        }
        Ok(())
    }

    fn check_free_list(&self) -> Result<(), AuditViolation> {
        let total = self.pool.total_blocks();
        let zero_rc = (0..total)
            .filter(|&b| self.pool.refcount(b as BlockId) == 0)
            .count();
        if zero_rc != self.pool.free_blocks() {
            return Err(AuditViolation {
                law: "free-list-disjointness",
                detail: format!(
                    "{zero_rc} block(s) have refcount 0 but the free list holds {}",
                    self.pool.free_blocks()
                ),
            });
        }
        if self.pool.free_blocks() + self.pool.used_blocks() != total {
            return Err(AuditViolation {
                law: "free-list-disjointness",
                detail: format!(
                    "free {} + used {} != total {total}",
                    self.pool.free_blocks(),
                    self.pool.used_blocks()
                ),
            });
        }
        Ok(())
    }

    fn check_slot_identity(&self) -> Result<(), AuditViolation> {
        let total = self.pool.total_blocks();
        for tr in &self.tables {
            let t = tr.table;
            if t.len() > t.capacity_tokens() {
                return Err(AuditViolation {
                    law: "slot-identity",
                    detail: format!(
                        "{}: len {} exceeds capacity {} of {} block(s)",
                        tr.owner,
                        t.len(),
                        t.capacity_tokens(),
                        t.n_blocks()
                    ),
                });
            }
            for slot in 0..t.len() {
                let Some((b, _off)) = t.locate(slot) else {
                    return Err(AuditViolation {
                        law: "slot-identity",
                        detail: format!("{}: slot {slot} < len does not locate", tr.owner),
                    });
                };
                if (b as usize) >= total {
                    return Err(AuditViolation {
                        law: "slot-identity",
                        detail: format!("{}: slot {slot} maps to out-of-range block {b}", tr.owner),
                    });
                }
                if self.pool.refcount(b) == 0 {
                    return Err(AuditViolation {
                        law: "slot-identity",
                        detail: format!("{}: slot {slot} maps to freed block {b}", tr.owner),
                    });
                }
            }
        }
        Ok(())
    }

    fn check_tier(&self) -> Result<(), AuditViolation> {
        let Some(tier) = &self.tier else {
            return Ok(());
        };
        let sum: usize = tier.entries.iter().map(|e| e.bytes).sum();
        if sum != tier.bytes_in_use {
            return Err(AuditViolation {
                law: "tier-budget",
                detail: format!(
                    "entry bytes sum to {sum} but bytes_in_use reports {}",
                    tier.bytes_in_use
                ),
            });
        }
        if tier.bytes_in_use > tier.max_bytes {
            return Err(AuditViolation {
                law: "tier-budget",
                detail: format!(
                    "bytes_in_use {} exceeds the {}-byte budget",
                    tier.bytes_in_use, tier.max_bytes
                ),
            });
        }
        if tier.entries.len() != tier.parked_blocks {
            return Err(AuditViolation {
                law: "tier-budget",
                detail: format!(
                    "{} entries but parked_blocks reports {}",
                    tier.entries.len(),
                    tier.parked_blocks
                ),
            });
        }
        // every pin must resolve to a live, pinned, size-matching entry
        for p in &self.pins {
            let Some(e) = tier.entries.iter().find(|e| e.id == p.tier_id) else {
                return Err(AuditViolation {
                    law: "pinned-never-shed",
                    detail: format!(
                        "{} pins tier entry {} but it is gone — a resume would lose its bytes",
                        p.owner, p.tier_id
                    ),
                });
            };
            if !e.pinned {
                return Err(AuditViolation {
                    law: "pinned-never-shed",
                    detail: format!(
                        "{} pins tier entry {} but the entry is unpinned (LRU-sheddable)",
                        p.owner, p.tier_id
                    ),
                });
            }
            if e.rows != p.rows {
                return Err(AuditViolation {
                    law: "pinned-never-shed",
                    detail: format!(
                        "{}: tier entry {} holds {} row(s), snapshot expects {}",
                        p.owner, p.tier_id, e.rows, p.rows
                    ),
                });
            }
        }
        if self.strict_pins {
            for e in tier.entries.iter().filter(|e| e.pinned) {
                if !self.pins.iter().any(|p| p.tier_id == e.id) {
                    return Err(AuditViolation {
                        law: "pinned-never-shed",
                        detail: format!(
                            "pinned tier entry {} ({} rows) has no owning snapshot — pinned \
                             bytes leaked",
                            e.id, e.rows
                        ),
                    });
                }
            }
        }
        // a resolvable ledger entry must be unpinned and size-matching;
        // unresolvable is legal (shed under pressure)
        for l in &self.ledgers {
            if let Some(e) = tier.entries.iter().find(|e| e.id == l.tier_id) {
                if e.pinned {
                    return Err(AuditViolation {
                        law: "ledger-identity",
                        detail: format!(
                            "{}: demotion ledger references *pinned* tier entry {}",
                            l.owner, l.tier_id
                        ),
                    });
                }
                if e.rows != l.records {
                    return Err(AuditViolation {
                        law: "ledger-identity",
                        detail: format!(
                            "{}: tier entry {} holds {} row(s) but the ledger carries {} record(s)",
                            l.owner, l.tier_id, e.rows, l.records
                        ),
                    });
                }
            }
        }
        Ok(())
    }

    /// Everything a human needs to attribute a violation: who holds what.
    fn owner_dump(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "pool: {} total, {} free, {} used, {} shared\n",
            self.pool.total_blocks(),
            self.pool.free_blocks(),
            self.pool.used_blocks(),
            self.pool.shared_blocks()
        ));
        for tr in &self.tables {
            s.push_str(&format!(
                "  table {}: len {} blocks {:?}\n",
                tr.owner,
                tr.table.len(),
                tr.table.blocks()
            ));
        }
        if !self.cache_blocks.is_empty() {
            s.push_str(&format!("  prefix-cache refs: {:?}\n", self.cache_blocks));
        }
        if let Some(t) = &self.tier {
            s.push_str(&format!(
                "tier: {}/{} bytes, {} parked\n",
                t.bytes_in_use, t.max_bytes, t.parked_blocks
            ));
            for e in &t.entries {
                s.push_str(&format!(
                    "  entry {}: rows {}, {} bytes{}\n",
                    e.id,
                    e.rows,
                    e.bytes,
                    if e.pinned { ", pinned" } else { "" }
                ));
            }
        }
        for p in &self.pins {
            s.push_str(&format!(
                "  pin {} -> tier {} ({} rows)\n",
                p.owner, p.tier_id, p.rows
            ));
        }
        for l in &self.ledgers {
            s.push_str(&format!(
                "  ledger {} -> tier {} ({} records)\n",
                l.owner, l.tier_id, l.records
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvpool::{PoolConfig, PrefixCache, PrefixCacheConfig};
    use crate::kvtier::HostTier;

    fn pool(n: usize) -> BlockPool {
        BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: n,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap()
    }

    fn table_of(tokens: usize, p: &mut BlockPool) -> BlockTable {
        let mut t = BlockTable::new(p.block_size());
        for _ in 0..tokens {
            assert!(t.push_token(p));
        }
        t
    }

    fn auditor<'a>(p: &'a BlockPool, tables: Vec<TableRef<'a>>) -> Auditor<'a> {
        Auditor {
            pool: p,
            tables,
            cache_blocks: Vec::new(),
            tier: None,
            pins: Vec::new(),
            ledgers: Vec::new(),
            strict_pins: false,
        }
    }

    #[test]
    fn consistent_state_passes_all_laws() {
        let mut p = pool(8);
        let t1 = table_of(6, &mut p);
        let t2 = table_of(4, &mut p);
        let mut cache = PrefixCache::new(PrefixCacheConfig::default());
        let ids: Vec<u32> = (0..4).collect();
        cache.insert(&ids, &t2, None, &mut p);
        let a = Auditor {
            cache_blocks: cache.pinned_block_ids(),
            ..auditor(
                &p,
                vec![
                    TableRef {
                        owner: "row 0".into(),
                        table: &t1,
                    },
                    TableRef {
                        owner: "row 1".into(),
                        table: &t2,
                    },
                ],
            )
        };
        assert!(a.check().is_ok(), "{:?}", a.check());
    }

    #[test]
    fn leaked_refcount_trips_conservation() {
        let mut p = pool(4);
        let t = table_of(4, &mut p);
        // the auditor is told about no holders: the table's block is a leak
        let a = auditor(&p, Vec::new());
        let v = a.check().unwrap_err();
        assert_eq!(v.law, "refcount-conservation", "{v}");
        // and the symmetric direction: a holder the pool forgot
        let mut p2 = pool(4);
        let t2 = table_of(4, &mut p2);
        let a2 = auditor(
            &p2,
            vec![
                TableRef {
                    owner: "row 0".into(),
                    table: &t2,
                },
                TableRef {
                    owner: "phantom".into(),
                    table: &t2,
                },
            ],
        );
        assert_eq!(a2.check().unwrap_err().law, "refcount-conservation");
        drop(t);
    }

    #[test]
    fn tier_budget_overshoot_trips() {
        let p = pool(1);
        let mut a = auditor(&p, Vec::new());
        a.tier = Some(TierView {
            max_bytes: 64,
            bytes_in_use: 128,
            parked_blocks: 1,
            entries: vec![TierEntryInfo {
                id: 0,
                rows: 2,
                pinned: false,
                bytes: 128,
            }],
        });
        let v = a.check().unwrap_err();
        assert_eq!(v.law, "tier-budget");
        assert!(v.detail.contains("exceeds"), "{v}");
    }

    #[test]
    fn tier_byte_accounting_drift_trips() {
        let p = pool(1);
        let mut a = auditor(&p, Vec::new());
        a.tier = Some(TierView {
            max_bytes: 256,
            bytes_in_use: 96, // entries actually sum to 64
            parked_blocks: 1,
            entries: vec![TierEntryInfo {
                id: 0,
                rows: 1,
                pinned: false,
                bytes: 64,
            }],
        });
        assert_eq!(a.check().unwrap_err().law, "tier-budget");
    }

    #[test]
    fn shed_pinned_entry_trips_pin_law() {
        let p = pool(1);
        let mut a = auditor(&p, Vec::new());
        a.tier = Some(TierView::default());
        a.pins.push(PinRef {
            owner: "req 9".into(),
            tier_id: 42,
            rows: 3,
        });
        let v = a.check().unwrap_err();
        assert_eq!(v.law, "pinned-never-shed");
        assert!(v.detail.contains("req 9"), "{v}");
    }

    #[test]
    fn strict_mode_catches_orphaned_pinned_entries() {
        let mut tier = HostTier::new(1 << 16);
        let id = tier.park(vec![0.0; 8], vec![0.0; 8], 2, true).unwrap();
        let p = pool(1);
        let mut a = auditor(&p, Vec::new());
        a.tier = Some(TierView::of(&tier));
        // non-strict: an unowned pinned entry is tolerated (its snapshot
        // may live in a queue outside the caller's view)
        assert!(a.check().is_ok());
        // strict (post-drain): it is a leak
        a.strict_pins = true;
        let v = a.check().unwrap_err();
        assert_eq!(v.law, "pinned-never-shed");
        assert!(v.detail.contains(&id.to_string()), "{v}");
    }

    #[test]
    fn ledger_mismatches_trip_and_shed_entries_are_tolerated() {
        let mut tier = HostTier::new(1 << 16);
        let id = tier.park(vec![0.0; 8], vec![0.0; 8], 2, false).unwrap();
        let p = pool(1);
        let mut a = auditor(&p, Vec::new());
        a.tier = Some(TierView::of(&tier));
        // a shed (absent) ledger target is legal
        a.ledgers.push(LedgerRef {
            owner: "row 0".into(),
            tier_id: 999,
            records: 4,
        });
        assert!(a.check().is_ok());
        // a resolvable one must match the entry's row count
        a.ledgers.push(LedgerRef {
            owner: "row 0".into(),
            tier_id: id,
            records: 3,
        });
        assert_eq!(a.check().unwrap_err().law, "ledger-identity");
    }

    #[test]
    fn assert_clean_panics_with_owner_dump() {
        let mut p = pool(4);
        let t = table_of(4, &mut p);
        let a = auditor(&p, Vec::new());
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            a.assert_clean("unit test");
        }))
        .unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("refcount-conservation"), "{msg}");
        assert!(msg.contains("pool: 4 total"), "dump must name the holders: {msg}");
        drop(t);
    }
}
