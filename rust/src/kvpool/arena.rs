//! Host-side physical K/V block storage: the byte-level half of paging.
//!
//! [`BlockPool`](super::BlockPool) and [`BlockTable`](super::BlockTable) are
//! purely *logical* — ids, refcounts, slot→(block, offset) maps. This module
//! holds the actual numbers: a [`KvArena`] is a `[n_blocks, block_size,
//! row_elems]` slab (one for K, one for V) where `row_elems = L · H · dh` is
//! one token's per-layer/head K or V footprint. Every physical byte of paged
//! KV lives in exactly one arena row, addressed only through a block table —
//! there is no per-sequence worst-case buffer anywhere.
//!
//! Ownership: the arena belongs to the *backend* (`SimBackend` holds one on
//! the host; `ModelExecutor` holds the same layout as device buffers), not to
//! the pool — the pool must stay a cheap, copyable bookkeeping structure the
//! scheduler and simulators can drive without touching tensors.
//!
//! The copy/move descriptor types here ([`BlockCopy`], [`RowMove`]) are how
//! the logical layer tells the physical layer what bytes to touch:
//!
//! * a [`BlockCopy`] is emitted by `BlockTable` copy-on-write (a shared
//!   block's occupied rows must be duplicated into the fresh private block
//!   *before* the next write lands, or the fork would read garbage and the
//!   donor could be clobbered);
//! * a [`RowMove`] list is emitted by `SeqKv::apply_keep_pooled` compaction
//!   (eviction reorders live slots, so surviving rows relocate between
//!   blocks). Moves are applied **two-phase** (gather all sources, then
//!   write) because a kept row's destination may overlap another kept row's
//!   source — see [`KvArena::gather_rows`].
//!
//! Failure modes worth knowing: rows in freed blocks are *not* zeroed — the
//! logical layer guarantees a block is re-written before it is re-read, so
//! stale bytes are unreachable through any live table (asserted end-to-end
//! by the divergent-tail engine tests and `tests/paged_kv.rs`).

use super::pool::BlockId;

/// One token's K (or V) element count: `n_layers * n_heads * d_head`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvLayout {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
}

impl KvLayout {
    pub fn row_elems(&self) -> usize {
        self.n_layers * self.n_heads * self.d_head
    }
}

/// Copy-on-write descriptor: duplicate the first `rows` occupied rows of
/// block `src` into block `dst` (both K and V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockCopy {
    pub src: BlockId,
    pub dst: BlockId,
    pub rows: usize,
}

/// Compaction descriptor: the row at `(src_block, src_off)` survives an
/// eviction pass and now lives at `(dst_block, dst_off)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowMove {
    pub src_block: BlockId,
    pub src_off: usize,
    pub dst_block: BlockId,
    pub dst_off: usize,
}

/// Pool-shaped physical K/V storage (see module docs).
#[derive(Clone, Debug)]
pub struct KvArena {
    n_blocks: usize,
    block_size: usize,
    row_elems: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvArena {
    pub fn new(n_blocks: usize, block_size: usize, layout: KvLayout) -> KvArena {
        let row_elems = layout.row_elems();
        let n = n_blocks * block_size * row_elems;
        KvArena {
            n_blocks,
            block_size,
            row_elems,
            k: vec![0.0; n],
            v: vec![0.0; n],
        }
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    pub fn row_elems(&self) -> usize {
        self.row_elems
    }

    /// Total bytes the arena occupies (K + V) — the *whole* physical KV
    /// footprint of a paged engine, independent of batch or max length.
    pub fn bytes(&self) -> usize {
        2 * self.k.len() * std::mem::size_of::<f32>()
    }

    /// Bytes held by `used_blocks` live blocks — the in-use share of
    /// [`bytes`](Self::bytes).
    pub fn bytes_for_blocks(&self, used_blocks: usize) -> usize {
        2 * used_blocks * self.block_size * self.row_elems * std::mem::size_of::<f32>()
    }

    #[inline]
    fn at(&self, block: BlockId, off: usize) -> usize {
        debug_assert!((block as usize) < self.n_blocks, "block {block} out of range");
        debug_assert!(off < self.block_size, "offset {off} out of range");
        (block as usize * self.block_size + off) * self.row_elems
    }

    pub fn k_row(&self, block: BlockId, off: usize) -> &[f32] {
        let i = self.at(block, off);
        &self.k[i..i + self.row_elems]
    }

    pub fn v_row(&self, block: BlockId, off: usize) -> &[f32] {
        let i = self.at(block, off);
        &self.v[i..i + self.row_elems]
    }

    /// Write `n` consecutive rows starting at `(block, off)`; `k_rows` and
    /// `v_rows` are token-major `[n, row_elems]`. The span must not cross
    /// the block boundary — callers write block by block, exactly as the
    /// block table maps tokens.
    pub fn write_rows(&mut self, block: BlockId, off: usize, k_rows: &[f32], v_rows: &[f32]) {
        let n = k_rows.len() / self.row_elems;
        assert_eq!(k_rows.len(), n * self.row_elems, "ragged k rows");
        assert_eq!(v_rows.len(), k_rows.len(), "k/v row count mismatch");
        assert!(off + n <= self.block_size, "write crosses block boundary");
        let i = self.at(block, off);
        self.k[i..i + k_rows.len()].copy_from_slice(k_rows);
        self.v[i..i + v_rows.len()].copy_from_slice(v_rows);
    }

    /// Apply a copy-on-write: duplicate `copy.rows` leading rows of the
    /// shared source block into the fresh private destination.
    pub fn copy_block(&mut self, copy: BlockCopy) {
        assert!(copy.rows <= self.block_size, "copy rows exceed block");
        let n = copy.rows * self.row_elems;
        let s = self.at(copy.src, 0);
        let d = self.at(copy.dst, 0);
        self.k.copy_within(s..s + n, d);
        self.v.copy_within(s..s + n, d);
    }

    /// Apply a compaction: every surviving row moves from its old to its new
    /// location. Two-phase (read everything, then write) so overlapping
    /// source/destination rows — keep-lists reorder slots arbitrarily — can
    /// never read a half-updated arena.
    pub fn gather_rows(&mut self, moves: &[RowMove]) {
        let re = self.row_elems;
        let mut k_tmp = vec![0.0f32; moves.len() * re];
        let mut v_tmp = vec![0.0f32; moves.len() * re];
        for (j, m) in moves.iter().enumerate() {
            let s = self.at(m.src_block, m.src_off);
            k_tmp[j * re..(j + 1) * re].copy_from_slice(&self.k[s..s + re]);
            v_tmp[j * re..(j + 1) * re].copy_from_slice(&self.v[s..s + re]);
        }
        for (j, m) in moves.iter().enumerate() {
            let d = self.at(m.dst_block, m.dst_off);
            self.k[d..d + re].copy_from_slice(&k_tmp[j * re..(j + 1) * re]);
            self.v[d..d + re].copy_from_slice(&v_tmp[j * re..(j + 1) * re]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> KvArena {
        // 4 blocks x 2 tokens, 3 elems per row
        KvArena::new(
            4,
            2,
            KvLayout {
                n_layers: 1,
                n_heads: 1,
                d_head: 3,
            },
        )
    }

    fn row(x: f32) -> Vec<f32> {
        vec![x, x + 0.1, x + 0.2]
    }

    #[test]
    fn write_and_read_rows() {
        let mut a = arena();
        let k: Vec<f32> = [row(1.0), row(2.0)].concat();
        let v: Vec<f32> = [row(-1.0), row(-2.0)].concat();
        a.write_rows(3, 0, &k, &v);
        assert_eq!(a.k_row(3, 0), &row(1.0)[..]);
        assert_eq!(a.k_row(3, 1), &row(2.0)[..]);
        assert_eq!(a.v_row(3, 1), &row(-2.0)[..]);
        assert_eq!(a.k_row(0, 0), &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "crosses block boundary")]
    fn write_cannot_cross_blocks() {
        let mut a = arena();
        let k: Vec<f32> = [row(1.0), row(2.0)].concat();
        a.write_rows(0, 1, &k, &k);
    }

    #[test]
    fn copy_block_duplicates_occupied_prefix() {
        let mut a = arena();
        a.write_rows(1, 0, &row(5.0), &row(6.0));
        a.write_rows(1, 1, &row(7.0), &row(8.0));
        a.copy_block(BlockCopy { src: 1, dst: 2, rows: 1 });
        assert_eq!(a.k_row(2, 0), &row(5.0)[..]);
        assert_eq!(a.v_row(2, 0), &row(6.0)[..]);
        // only the occupied prefix was copied
        assert_eq!(a.k_row(2, 1), &[0.0, 0.0, 0.0]);
        // source untouched
        assert_eq!(a.k_row(1, 1), &row(7.0)[..]);
    }

    #[test]
    fn gather_rows_is_two_phase() {
        let mut a = arena();
        a.write_rows(0, 0, &row(1.0), &row(1.5));
        a.write_rows(0, 1, &row(2.0), &row(2.5));
        // swap the two rows: naive in-order copy would clobber a source
        a.gather_rows(&[
            RowMove { src_block: 0, src_off: 0, dst_block: 0, dst_off: 1 },
            RowMove { src_block: 0, src_off: 1, dst_block: 0, dst_off: 0 },
        ]);
        assert_eq!(a.k_row(0, 0), &row(2.0)[..]);
        assert_eq!(a.k_row(0, 1), &row(1.0)[..]);
        assert_eq!(a.v_row(0, 0), &row(2.5)[..]);
    }

    #[test]
    fn byte_accounting_scales_with_blocks_not_rows() {
        let a = arena();
        assert_eq!(a.bytes(), 2 * 4 * 2 * 3 * 4);
        assert_eq!(a.bytes_for_blocks(1), 2 * 2 * 3 * 4);
        assert_eq!(a.bytes_for_blocks(4), a.bytes());
    }
}
