//! TCP JSON-lines serving front-end (std::net + threads; the offline crate
//! set has no tokio — at our batch sizes the engine is compute-bound, so
//! thread-per-connection I/O costs nothing measurable).
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "template": "...", "max_new": 256,
//!      "class": "interactive" | "standard" | "batch",   // SLO class, opt.
//!      "stream": true}                                  // opt-in streaming
//!   ← {"event": "token", "id": 1, "n": 3, "first": false, "text": "…"}
//!                                  // streaming only: one line per decode
//!                                  // step, written as it is produced
//!   ← {"id": 1, "text": "...", "holes": "…", "finish": "max_tokens",
//!      "ttft_ms": 12.3, "total_ms": 456.7, "tokens": 256, "evictions": 3,
//!      "pool": {"free_blocks": 9, "total_blocks": 64,        // paged mode
//!               "utilization": 0.86, "preemptions": 2,       // only
//!               "resumes": 2, "recomputed_tokens": 120,
//!               "shared_blocks": 3, "prefix_hits": 5, "prefix_misses": 2,
//!               "prefix_entries": 1, "prefix_pinned_blocks": 3,
//!               "parked_blocks": 2, "promotions": 4,      // host tier
//!               "swap_out_bytes": 9216, "swap_in_bytes": 6144, ...}}
//!                                  // terminal summary line (both modes;
//!                                  // carries "event":"done" when streaming)
//!   ← {"error": "..."}                                    // on any failure
//!
//! Concatenating the `text` of one request's token events yields exactly the
//! summary line's `text` — streaming changes delivery, never content. The
//! full wire protocol (including cancellation semantics) is specified in
//! docs/serving.md.
//!
//! `max_new` is clamped: 0 is rejected, values above [`MAX_MAX_NEW`] are
//! capped before they reach the scheduler.
//!
//! With telemetry attached (`serve_with_telemetry`), two more line-protocol
//! commands are available on the same port:
//!   → {"cmd": "stats"}            ← {"stats": {"counters": …, "gauges": …,
//!                                              "histograms": …}}
//!   → {"cmd": "trace", "id": 7}   ← {"id": 7, "trace": [flight events…]}
//! and the Prometheus exposition is served by the dedicated `--metrics-addr`
//! listener (see `telemetry::http`), kept off this port so scrapers never
//! head-of-line-block a generation client.
//!
//! ## Event-driven serve loop
//!
//! Three thread roles share three pieces of state — the [`RequestQueue`],
//! the `routes` map (request id → per-connection reply channel), and the
//! `cancels` list:
//!
//! * The **acceptor** blocks in `accept` (no poll loop; shutdown wakes it
//!   with a dummy connect) and spawns one handler per connection.
//! * A **connection handler** owns the socket's write half; a paired reader
//!   thread pumps incoming lines and the EOF into the same channel the
//!   engine's replies arrive on, so the handler observes a client disconnect
//!   *while a request is in flight* and flags it in `cancels`. Token events
//!   are serialized with the reusable `util::wire::EventWriter` — the per
//!   token path does no allocation and no tree building.
//! * The **engine loop** (the calling thread) runs one iteration per decode
//!   step: sweep cancellations, admit from the queue (deadline-ordered —
//!   see `scheduler::queue`), step the engine, forward drained token events
//!   to streaming routes, deliver terminal replies, re-queue preemption
//!   victims. When fully idle it parks on the queue's condvar
//!   ([`RequestQueue::wait_nonempty`]) instead of sleep-polling.
//!
//! ## Cancellation
//!
//! A disconnect (EOF or failed write) lands the request id in `cancels`;
//! the next loop iteration routes it to whichever place owns state for it:
//! a queued fresh request is simply dropped, a queued *preempted* request
//! releases the tier state riding in its snapshot
//! (`Engine::release_discarded_state` — pinned swap blocks and parked
//! ledger), and an active row is torn down (`Engine::abort_request`,
//! blocks + parked entries released). All three count into
//! `cancelled_rows`; nothing is decoded for a client that is gone.
//!
//! ## Pressure / preemption protocol (paged-KV mode)
//!
//! When the engine runs on a shared block pool, the serve loop consults an
//! `AdmissionController` each iteration: while free blocks sit below the
//! pool's low watermark the queue is held (requests wait, connections stay
//! blocked on their reply channel) until the pool recovers past the high
//! watermark. A request the engine declines (`submit -> Ok(false)`) goes
//! back to the *front* of the queue untouched. A request preempted
//! mid-decode comes back from `Engine::take_preempted` carrying its full
//! decode-state snapshot (`Request::resume`); the serve loop re-queues the
//! whole batch at the front **in the order the engine returned it — oldest
//! victim first, via `RequestQueue::push_front_all`** (a per-request
//! `push_front` loop would reverse same-step victims), and its re-admission
//! *resumes* generation (recompute mode: one batched re-prefill, tracker
//! state restored) instead of restarting it. Re-queues keep the request's
//! SLO class (front lane outranks the deadline lane, and the class rides
//! along for any later re-push). Clients never see a preemption, only
//! latency; the wait accumulated across the round trip is reported in the
//! response's queue-wait metric (the snapshot carries the pre-preemption
//! wait, so nothing is lost to the re-queue). Completed responses carry the
//! pool gauges above — including `resumes` and `recomputed_tokens` — so
//! clients/scrapers observe global pressure.
//!
//! ## Failure delivery
//!
//! Every queued request owns a reply channel in `routes`. All terminal
//! outcomes deliver exactly one reply: a response, or an `{"error": ...}`
//! line when its submit fails or the engine's step errors. On a step error
//! the engine's active rows are aborted (blocks released, rows cleared) and
//! exactly those requests get the error line — no connection thread is left
//! blocked on a channel that can no longer be served, queued-but-unsubmitted
//! requests are unaffected, and the loop cannot busy-spin on zombie rows.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{Engine, Request, Response, TokenEvent};
use crate::metrics::PoolGauges;
use crate::scheduler::{AdmissionController, QueuedRequest, RequestQueue, SloClass};
use crate::telemetry::{event, Telemetry};
use crate::util::json::Json;
use crate::util::wire;

/// Upper bound on a request's `max_new`; larger asks are capped, not erred,
/// so misconfigured clients degrade gracefully.
pub const MAX_MAX_NEW: usize = 4096;

pub fn response_to_json(r: &Response) -> Json {
    Json::obj()
        .set("id", r.id as f64)
        .set("text", r.text.as_str())
        .set(
            "holes",
            r.hole_predictions.iter().collect::<String>(),
        )
        .set("finish", r.finish.as_str())
        .set("ttft_ms", r.metrics.ttft_s * 1e3)
        .set("total_ms", r.metrics.total_s * 1e3)
        .set("tokens", r.metrics.tokens_out)
        .set("evictions", r.metrics.evictions)
}

/// Block-pool gauges as attached to responses in paged-KV mode. Driven by
/// `PoolGauges::fields()` — the same enumeration that feeds the `/metrics`
/// exposition — so the two surfaces cannot drift apart.
pub fn pool_gauges_to_json(g: &PoolGauges) -> Json {
    let mut j = Json::obj();
    for (name, value, _kind) in g.fields() {
        j = j.set(name, value);
    }
    j
}

/// Parse one request line via the zero-copy visitor (`util::wire`): no tree
/// is built, and an escape-free prompt is borrowed from the line until the
/// final `to_string`. Returns the queued request plus its streaming flag.
pub fn parse_request(line: &str, id: u64) -> Result<(QueuedRequest, bool)> {
    let w = wire::parse_request(line.as_bytes())
        .map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    let prompt = w
        .prompt
        .ok_or_else(|| anyhow::anyhow!("missing key 'prompt'"))?;
    let max_new = w.max_new.map(|x| x as usize).unwrap_or(256);
    anyhow::ensure!(max_new > 0, "max_new must be >= 1");
    let class = match &w.class {
        Some(c) => SloClass::parse(c)
            .ok_or_else(|| anyhow::anyhow!("unknown class '{c}' (interactive|standard|batch)"))?,
        None => SloClass::Standard,
    };
    Ok((
        QueuedRequest {
            id,
            prompt: prompt.into_owned(),
            template: w.template.map(|t| t.into_owned()).unwrap_or_default(),
            max_new: max_new.min(MAX_MAX_NEW),
            class,
            queued_at: Instant::now(),
            resume: None,
        },
        w.stream,
    ))
}

/// Replies the engine loop sends to a connection. Terminal variants
/// (`Done`/`Failed`) arrive exactly once per request; `Token` any number of
/// times before that, streaming mode only.
enum ServeReply {
    Token(TokenEvent),
    Done(Response, Option<PoolGauges>),
    Failed(String),
}

/// Everything a connection handler can observe, merged into one channel so
/// a blocked request still sees the client hang up.
enum ConnEvent {
    Line(String),
    Eof,
    Reply(ServeReply),
}

struct Route {
    tx: mpsc::Sender<ConnEvent>,
    stream: bool,
}

type Routes = Arc<Mutex<HashMap<u64, Route>>>;
/// Request ids whose client disconnected; swept by the engine loop.
type Cancels = Arc<Mutex<Vec<u64>>>;

fn send_reply(routes: &Routes, id: u64, reply: ServeReply) {
    if let Some(rt) = routes.lock().unwrap().remove(&id) {
        let _ = rt.tx.send(ConnEvent::Reply(reply));
    }
}

/// Forward one token event to its (streaming) route without consuming the
/// route — the terminal reply is still to come. Returns whether the event
/// was actually handed to a streaming client (routes for non-streaming
/// requests and already-cancelled rows swallow their events).
fn send_token(routes: &Routes, ev: TokenEvent) -> bool {
    let g = routes.lock().unwrap();
    if let Some(rt) = g.get(&ev.req) {
        if rt.stream {
            let _ = rt.tx.send(ConnEvent::Reply(ServeReply::Token(ev)));
            return true;
        }
    }
    false
}

/// Flag `id` for cancellation and wake an idle engine so the sweep happens
/// now, not at the next wait timeout.
fn cancel(cancels: &Cancels, queue: &RequestQueue, id: u64) {
    cancels.lock().unwrap().push(id);
    queue.nudge();
}

/// Serve an engine on `addr` until `shutdown` flips. The engine loop runs on
/// the calling thread; connections are handled by spawned threads.
pub fn serve(engine: Engine, addr: &str, shutdown: Arc<AtomicBool>) -> Result<()> {
    serve_with_telemetry(engine, addr, shutdown, None)
}

/// [`serve`] with a shared telemetry handle: the engine publishes registry
/// snapshots every loop iteration, connection threads record `queued`
/// flight events and answer `stats`/`trace` commands. The caller usually
/// also hands the same handle to `telemetry::spawn_metrics_listener`.
pub fn serve_with_telemetry(
    mut engine: Engine,
    addr: &str,
    shutdown: Arc<AtomicBool>,
    telemetry: Option<Arc<Telemetry>>,
) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    eprintln!(
        "lazyevictiond: serving on {addr} (policy={}, budget={}, batch={}{})",
        engine.policy_name(),
        engine.cfg.budget,
        engine.cfg.batch,
        match &engine.cfg.pool {
            Some(p) => format!(", pool={}x{}", p.n_blocks, p.block_size),
            None => String::new(),
        }
    );

    if let Some(t) = &telemetry {
        engine.attach_telemetry(t.clone());
    }

    let queue = Arc::new(RequestQueue::new());
    let routes: Routes = Arc::new(Mutex::new(HashMap::new()));
    let cancels: Cancels = Arc::new(Mutex::new(Vec::new()));
    let next_id = Arc::new(AtomicU64::new(1));

    // acceptor thread: blocking accept (no retry poll); the engine loop
    // wakes it at shutdown with a dummy connect to our own address
    {
        let queue = queue.clone();
        let routes = routes.clone();
        let cancels = cancels.clone();
        let next_id = next_id.clone();
        let shutdown = shutdown.clone();
        let telemetry = telemetry.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(s) = stream else { break };
                let queue = queue.clone();
                let routes = routes.clone();
                let cancels = cancels.clone();
                let next_id = next_id.clone();
                let telemetry = telemetry.clone();
                std::thread::spawn(move || {
                    handle_conn(s, queue, routes, cancels, next_id, telemetry)
                });
            }
        });
    }

    // engine loop (this thread). `classes` remembers each in-flight
    // request's SLO class so preemption re-queues keep it (Request does not
    // carry the class — it is a scheduling concern, not an engine one).
    let mut admission = AdmissionController::new();
    let mut classes: HashMap<u64, SloClass> = HashMap::new();
    while !shutdown.load(Ordering::Relaxed) {
        let mut idle = true;

        // cancellation sweep: route each disconnected id to whatever owns
        // state for it (see "Cancellation" above)
        let cancelled: Vec<u64> = std::mem::take(&mut *cancels.lock().unwrap());
        for id in cancelled {
            routes.lock().unwrap().remove(&id);
            classes.remove(&id);
            if let Some(q) = queue.remove(id) {
                match &q.resume {
                    Some(st) => engine.release_discarded_state(st, id),
                    None => {
                        // fresh queued request: nothing admitted, nothing to
                        // release — just count the cancellation
                        engine.metrics.cancelled_rows += 1;
                        if let Some(t) = &telemetry {
                            t.record(id, event::ABORT, 0, 0, 0.0, "unadmitted");
                        }
                    }
                }
            } else {
                engine.abort_request(id);
            }
        }

        let mut admit_open = match engine.pool_pressure() {
            Some(p) => admission.allow(&p),
            None => true,
        };
        if !admit_open && engine.active() == 0 && !queue.is_empty() {
            // Nothing is decoding, so nothing will ever free blocks on its
            // own — stale prefix-cache pins are all that holds the latch
            // closed. Release them and re-evaluate, or the queue hangs.
            engine.shed_prefix_to_high_watermark();
            if let Some(p) = engine.pool_pressure() {
                admit_open = admission.allow(&p);
            }
        }
        while admit_open && engine.has_free_row() {
            let Some(q) = queue.try_pop() else { break };
            let queued_s = q.queued_at.elapsed().as_secs_f64();
            classes.insert(q.id, q.class);
            let req = Request {
                id: q.id,
                prompt: q.prompt.clone(),
                template: q.template.clone(),
                max_new: q.max_new,
                resume: q.resume.clone(),
            };
            match engine.submit(req, queued_s) {
                Ok(true) => {
                    idle = false;
                }
                Ok(false) => {
                    // declined under pool pressure: hold it at the front
                    queue.push_front(q);
                    break;
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    eprintln!("submit error (request {}): {msg}", q.id);
                    classes.remove(&q.id);
                    send_reply(&routes, q.id, ServeReply::Failed(msg));
                }
            }
        }
        if engine.active() > 0 {
            idle = false;
            match engine.step() {
                Ok(done) => {
                    // tokens first, then terminals: a finishing row's last
                    // token event precedes its summary on the channel
                    for ev in engine.drain_token_events() {
                        if send_token(&routes, ev) {
                            engine.metrics.streamed_tokens += 1;
                        }
                    }
                    let gauges = engine.pool_gauges();
                    for resp in done {
                        let id = resp.id;
                        classes.remove(&id);
                        send_reply(&routes, id, ServeReply::Done(resp, gauges));
                    }
                }
                Err(e) => {
                    let msg = format!("engine step error: {e:#}");
                    eprintln!("{msg}");
                    // Partial token events from the failed step must not
                    // reach clients their summary will never follow.
                    engine.drain_token_events();
                    // Fail exactly the requests whose rows were inside the
                    // erroring engine — their decode state is gone — and
                    // clear those rows (blocks released) so the loop cannot
                    // busy-spin on zombie rows or run out of free rows.
                    // Requests still waiting in the queue keep their routes
                    // and are served normally once the engine recovers.
                    for id in engine.abort_rows() {
                        classes.remove(&id);
                        send_reply(&routes, id, ServeReply::Failed(msg.clone()));
                    }
                }
            }
            // preempted rows: decode state preserved in `resume`, first in
            // line for recompute re-admission. The batch keeps the engine's
            // oldest-victim-first order (push_front_all; a per-request
            // push_front here would reverse same-step victims). `queued_at`
            // marks the re-queue time only — the wait accumulated before
            // the preemption travels inside the snapshot, so the final
            // queue-wait metric covers the request's full queued time. The
            // SLO class survives the round trip via `classes`.
            let now = Instant::now();
            queue.push_front_all(
                engine
                    .take_preempted()
                    .into_iter()
                    .map(|r| QueuedRequest {
                        class: classes.get(&r.id).copied().unwrap_or_default(),
                        id: r.id,
                        prompt: r.prompt,
                        template: r.template,
                        max_new: r.max_new,
                        queued_at: now,
                        resume: r.resume,
                    })
                    .collect(),
            );
        }
        // push this iteration's counters/gauges/histograms to the shared
        // registry so scrapers read fresh values without touching the engine
        engine.publish_telemetry();
        if idle {
            if queue.is_empty() {
                // park on the queue condvar: a push (or a cancel nudge)
                // wakes us immediately; the timeout only bounds how stale
                // the published telemetry can go while fully idle
                queue.wait_nonempty(Duration::from_millis(25));
            } else {
                // queue non-empty but nothing admissible (pressure latch):
                // yield briefly, re-evaluate
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }
    queue.close();
    // wake the acceptor out of its blocking accept so it observes shutdown
    let _ = TcpStream::connect(local_addr);
    if let Some(t) = &telemetry {
        t.flush();
    }
    Ok(())
}

/// Handle a `{"cmd": ...}` line; returns the reply, or `None` if the line
/// is not a command (i.e. a generation request).
fn handle_command(line: &str, telemetry: &Option<Arc<Telemetry>>) -> Option<Json> {
    let j = Json::parse(line).ok()?;
    let cmd = j.get("cmd")?.as_str()?.to_string();
    let Some(t) = telemetry else {
        return Some(Json::obj().set("error", "telemetry not enabled on this server"));
    };
    Some(match cmd.as_str() {
        "stats" => Json::obj().set("stats", t.registry.to_json()),
        "trace" => match j.get("id").and_then(|v| v.as_f64()) {
            Some(id) => {
                let events: Vec<Json> = t
                    .events_for(id as u64)
                    .iter()
                    .map(|e| e.to_json())
                    .collect();
                Json::obj().set("id", id).set("trace", events)
            }
            None => Json::obj().set("error", "trace requires a numeric 'id'"),
        },
        other => Json::obj().set("error", format!("unknown cmd '{other}'")),
    })
}

fn handle_conn(
    stream: TcpStream,
    queue: Arc<RequestQueue>,
    routes: Routes,
    cancels: Cancels,
    next_id: Arc<AtomicU64>,
    telemetry: Option<Arc<Telemetry>>,
) {
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let (tx, rx) = mpsc::channel::<ConnEvent>();

    // reader thread: pump lines and the EOF into the merged channel, so the
    // handler observes a disconnect even while a request is in flight
    {
        let tx = tx.clone();
        std::thread::spawn(move || {
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if tx.send(ConnEvent::Line(line)).is_err() {
                    return;
                }
            }
            let _ = tx.send(ConnEvent::Eof);
        });
    }

    // lines that arrived while a request was in flight (pipelining)
    let mut pending: VecDeque<String> = VecDeque::new();
    let mut events = wire::EventWriter::new();
    'conn: loop {
        let line = match pending.pop_front() {
            Some(l) => l,
            None => match rx.recv() {
                Ok(ConnEvent::Line(l)) => l,
                Ok(ConnEvent::Eof) | Err(_) => break 'conn,
                // replies for a request this handler already gave up on
                Ok(ConnEvent::Reply(_)) => continue,
            },
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Some(reply) = handle_command(&line, &telemetry) {
            if writeln!(writer, "{}", reply.to_string()).is_err() {
                break 'conn;
            }
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let (q, stream_mode) = match parse_request(&line, id) {
            Ok(v) => v,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    Json::obj().set("error", format!("{e:#}")).to_string()
                );
                continue;
            }
        };
        routes.lock().unwrap().insert(
            id,
            Route {
                tx: tx.clone(),
                stream: stream_mode,
            },
        );
        if let Some(t) = &telemetry {
            t.record(id, event::QUEUED, 0, 0, 0.0, q.class.as_str());
        }
        queue.push(q);
        // in flight: forward token events as they arrive, finish on the
        // terminal reply, cancel on any sign the client is gone
        loop {
            match rx.recv() {
                Ok(ConnEvent::Reply(ServeReply::Token(ev))) => {
                    let line = events.token(ev.req, &ev.text, ev.produced, ev.first);
                    if writer.write_all(line).is_err() {
                        cancel(&cancels, &queue, id);
                        break 'conn;
                    }
                }
                Ok(ConnEvent::Reply(ServeReply::Done(resp, gauges))) => {
                    let mut j = response_to_json(&resp);
                    if stream_mode {
                        j = j.set("event", "done");
                    }
                    if let Some(g) = gauges {
                        j = j.set("pool", pool_gauges_to_json(&g));
                    }
                    if writeln!(writer, "{}", j.to_string()).is_err() {
                        break 'conn;
                    }
                    break;
                }
                Ok(ConnEvent::Reply(ServeReply::Failed(msg))) => {
                    // deterministic failure line; connection stays usable
                    if writeln!(
                        writer,
                        "{}",
                        Json::obj().set("error", msg.as_str()).to_string()
                    )
                    .is_err()
                    {
                        break 'conn;
                    }
                    break;
                }
                // client sent the next request before this one finished
                Ok(ConnEvent::Line(l)) => pending.push_back(l),
                // client hung up mid-request: flag the abort and leave —
                // the engine loop releases blocks/tier state on its next
                // iteration
                Ok(ConnEvent::Eof) => {
                    cancel(&cancels, &queue, id);
                    break 'conn;
                }
                // server shut down with the request still in flight
                Err(_) => {
                    let _ = writeln!(
                        writer,
                        "{}",
                        Json::obj().set("error", "server shut down").to_string()
                    );
                    break 'conn;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let (q, stream) =
            parse_request(r##"{"prompt":"#A=1;\n>","template":"A=?;","max_new":32}"##, 7)
                .unwrap();
        assert_eq!(q.id, 7);
        assert_eq!(q.prompt, "#A=1;\n>");
        assert_eq!(q.template, "A=?;");
        assert_eq!(q.max_new, 32);
        assert_eq!(q.class, SloClass::Standard);
        assert!(!stream);
    }

    #[test]
    fn parse_request_defaults() {
        let (q, stream) = parse_request(r#"{"prompt":"x"}"#, 1).unwrap();
        assert_eq!(q.template, "");
        assert_eq!(q.max_new, 256);
        assert_eq!(q.class, SloClass::Standard);
        assert!(!stream);
    }

    #[test]
    fn parse_request_class_and_stream() {
        let (q, stream) =
            parse_request(r#"{"prompt":"x","class":"interactive","stream":true}"#, 1).unwrap();
        assert_eq!(q.class, SloClass::Interactive);
        assert!(stream);
        let (q, _) = parse_request(r#"{"prompt":"x","class":"batch"}"#, 1).unwrap();
        assert_eq!(q.class, SloClass::Batch);
        // unknown class is a hard error, not a silent default
        assert!(parse_request(r#"{"prompt":"x","class":"platinum"}"#, 1).is_err());
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"template":"x"}"#, 1).is_err());
    }

    #[test]
    fn parse_request_clamps_max_new() {
        // zero is rejected outright
        assert!(parse_request(r#"{"prompt":"x","max_new":0}"#, 1).is_err());
        // negative numbers land on 0 via the f64→usize cast: also rejected
        assert!(parse_request(r#"{"prompt":"x","max_new":-5}"#, 1).is_err());
        // absurd values are capped, not erred
        let (q, _) = parse_request(r#"{"prompt":"x","max_new":999999999}"#, 1).unwrap();
        assert_eq!(q.max_new, MAX_MAX_NEW);
        let (q, _) = parse_request(&format!(r#"{{"prompt":"x","max_new":{MAX_MAX_NEW}}}"#), 1)
            .unwrap();
        assert_eq!(q.max_new, MAX_MAX_NEW);
    }

    #[test]
    fn parse_request_ignores_unknown_fields() {
        let (q, _) = parse_request(
            r#"{"prompt":"x","future":{"nested":[1,2,3]},"n":null}"#,
            1,
        )
        .unwrap();
        assert_eq!(q.prompt, "x");
    }

    #[test]
    fn response_json_shape() {
        use crate::coordinator::FinishReason;
        use crate::metrics::RequestMetrics;
        let r = Response {
            id: 3,
            text: "A+B=4;".into(),
            hole_predictions: vec!['4'],
            finish: FinishReason::TemplateDone,
            metrics: RequestMetrics::default(),
            live_curve: vec![],
        };
        let j = response_to_json(&r);
        assert_eq!(j.str_at("holes").unwrap(), "4");
        assert_eq!(j.str_at("finish").unwrap(), "template_done");
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_at("id").unwrap(), 3);
    }

    #[test]
    fn pool_gauges_json_shape() {
        let g = PoolGauges {
            free_blocks: 9,
            total_blocks: 64,
            utilization: 0.859,
            preemptions: 2,
            resumes: 2,
            recomputed_tokens: 120,
            shared_blocks: 3,
            prefix_hits: 5,
            prefix_misses: 2,
            prefix_entries: 1,
            prefix_pinned_blocks: 3,
            prefix_prefill_skips: 4,
            kv_arena_bytes: 131072,
            kv_bytes_in_use: 112640,
            parked_blocks: 3,
            parked_bytes: 3072,
            demoted_blocks: 7,
            promotions: 5,
            false_evictions_avoided: 11,
            swap_out_bytes: 9216,
            swap_in_bytes: 6144,
            swap_preempts: 1,
            tier_shed_blocks: 2,
            tier_rejects: 6,
        };
        let j = pool_gauges_to_json(&g);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_at("free_blocks").unwrap(), 9);
        assert_eq!(parsed.usize_at("total_blocks").unwrap(), 64);
        assert_eq!(parsed.usize_at("preemptions").unwrap(), 2);
        assert_eq!(parsed.usize_at("resumes").unwrap(), 2);
        assert_eq!(parsed.usize_at("recomputed_tokens").unwrap(), 120);
        assert!((parsed.f64_at("utilization").unwrap() - 0.859).abs() < 1e-9);
        assert_eq!(parsed.usize_at("shared_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("prefix_hits").unwrap(), 5);
        assert_eq!(parsed.usize_at("prefix_misses").unwrap(), 2);
        assert_eq!(parsed.usize_at("prefix_entries").unwrap(), 1);
        assert_eq!(parsed.usize_at("prefix_pinned_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("prefix_prefill_skips").unwrap(), 4);
        assert_eq!(parsed.usize_at("kv_arena_bytes").unwrap(), 131072);
        assert_eq!(parsed.usize_at("kv_bytes_in_use").unwrap(), 112640);
        assert_eq!(parsed.usize_at("parked_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("parked_bytes").unwrap(), 3072);
        assert_eq!(parsed.usize_at("demoted_blocks").unwrap(), 7);
        assert_eq!(parsed.usize_at("promotions").unwrap(), 5);
        assert_eq!(parsed.usize_at("false_evictions_avoided").unwrap(), 11);
        assert_eq!(parsed.usize_at("swap_out_bytes").unwrap(), 9216);
        assert_eq!(parsed.usize_at("swap_in_bytes").unwrap(), 6144);
        assert_eq!(parsed.usize_at("swap_preempts").unwrap(), 1);
        assert_eq!(parsed.usize_at("tier_shed_blocks").unwrap(), 2);
        assert_eq!(parsed.usize_at("tier_rejects").unwrap(), 6);
    }

    /// Every `PoolGauges` field must appear in both export surfaces: the
    /// server `pool` JSON and the Prometheus exposition. `fields()` is the
    /// single enumeration (exhaustive destructuring makes omissions a
    /// compile error); this pins that both paths actually consume it.
    #[test]
    fn pool_gauge_field_parity_json_and_exposition() {
        let g = PoolGauges {
            free_blocks: 1,
            total_blocks: 2,
            utilization: 0.5,
            preemptions: 3,
            resumes: 4,
            recomputed_tokens: 5,
            shared_blocks: 6,
            prefix_hits: 7,
            prefix_misses: 8,
            prefix_entries: 9,
            prefix_pinned_blocks: 10,
            prefix_prefill_skips: 11,
            kv_arena_bytes: 12,
            kv_bytes_in_use: 13,
            parked_blocks: 14,
            parked_bytes: 15,
            demoted_blocks: 16,
            promotions: 17,
            false_evictions_avoided: 18,
            swap_out_bytes: 19,
            swap_in_bytes: 20,
            swap_preempts: 21,
            tier_shed_blocks: 22,
            tier_rejects: 23,
        };
        let json = pool_gauges_to_json(&g);
        let obj = json.as_obj().expect("pool json is an object");

        let reg = crate::telemetry::Registry::new();
        g.publish(&reg);
        let exposition = reg.render_prometheus();

        let fields = g.fields();
        assert_eq!(obj.len(), fields.len(), "json has exactly the fields");
        for (name, value, _kind) in &fields {
            assert_eq!(
                json.f64_at(name).unwrap(),
                *value,
                "json missing or wrong for {name}"
            );
            let metric = format!("{}{name}", crate::telemetry::names::POOL_PREFIX);
            let line = format!("{metric} ");
            assert!(
                exposition.lines().any(|l| l.starts_with(&line)),
                "exposition missing {metric}"
            );
        }
        // distinct values survive the round trip (no copy-paste aliasing)
        assert_eq!(json.f64_at("tier_rejects").unwrap(), 23.0);
        assert!(exposition.contains("lazyeviction_pool_tier_rejects 23"));
    }
}
