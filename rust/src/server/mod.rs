//! TCP JSON-lines serving front-end (std::net + threads; the offline crate
//! set has no tokio — at our batch sizes the engine is PJRT-compute-bound,
//! so thread-per-connection I/O costs nothing measurable).
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "template": "...", "max_new": 256}
//!   ← {"id": 1, "text": "...", "holes": "…", "finish": "max_tokens",
//!      "ttft_ms": 12.3, "total_ms": 456.7, "tokens": 256, "evictions": 3}

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::{Engine, Request, Response};
use crate::scheduler::{QueuedRequest, RequestQueue};
use crate::util::json::Json;

pub fn response_to_json(r: &Response) -> Json {
    Json::obj()
        .set("id", r.id as f64)
        .set("text", r.text.as_str())
        .set(
            "holes",
            r.hole_predictions.iter().collect::<String>(),
        )
        .set("finish", r.finish.as_str())
        .set("ttft_ms", r.metrics.ttft_s * 1e3)
        .set("total_ms", r.metrics.total_s * 1e3)
        .set("tokens", r.metrics.tokens_out)
        .set("evictions", r.metrics.evictions)
}

pub fn parse_request(line: &str, id: u64) -> Result<QueuedRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    Ok(QueuedRequest {
        id,
        prompt: j.str_at("prompt")?.to_string(),
        template: j
            .get("template")
            .and_then(|t| t.as_str())
            .unwrap_or("")
            .to_string(),
        max_new: j
            .get("max_new")
            .and_then(|m| m.as_usize())
            .unwrap_or(256),
        queued_at: Instant::now(),
    })
}

type Routes = Arc<Mutex<HashMap<u64, mpsc::Sender<Response>>>>;

/// Serve an engine on `addr` until `shutdown` flips. The engine loop runs on
/// the calling thread; connections are handled by spawned threads.
pub fn serve(mut engine: Engine, addr: &str, shutdown: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    eprintln!(
        "lazyevictiond: serving on {addr} (policy={}, budget={}, batch={})",
        engine.policy_name(),
        engine.cfg.budget,
        engine.cfg.batch
    );

    let queue = Arc::new(RequestQueue::new());
    let routes: Routes = Arc::new(Mutex::new(HashMap::new()));
    let next_id = Arc::new(AtomicU64::new(1));

    // acceptor thread
    {
        let queue = queue.clone();
        let routes = routes.clone();
        let next_id = next_id.clone();
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                match stream {
                    Ok(s) => {
                        let queue = queue.clone();
                        let routes = routes.clone();
                        let next_id = next_id.clone();
                        std::thread::spawn(move || handle_conn(s, queue, routes, next_id));
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        });
    }

    // engine loop (this thread)
    while !shutdown.load(Ordering::Relaxed) {
        let mut idle = true;
        while engine.has_free_row() {
            let Some(q) = queue.try_pop() else { break };
            let queued_s = q.queued_at.elapsed().as_secs_f64();
            let req = Request {
                id: q.id,
                prompt: q.prompt,
                template: q.template,
                max_new: q.max_new,
            };
            if let Err(e) = engine.submit(req, queued_s) {
                eprintln!("submit error: {e:#}");
            }
            idle = false;
        }
        if engine.active() > 0 {
            idle = false;
            match engine.step() {
                Ok(done) => {
                    let mut routes = routes.lock().unwrap();
                    for resp in done {
                        if let Some(tx) = routes.remove(&resp.id) {
                            let _ = tx.send(resp);
                        }
                    }
                }
                Err(e) => eprintln!("engine step error: {e:#}"),
            }
        }
        if idle {
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, queue: Arc<RequestQueue>, routes: Routes, next_id: Arc<AtomicU64>) {
    let peer = stream.peer_addr().ok();
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let q = match parse_request(&line, id) {
            Ok(q) => q,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    Json::obj().set("error", format!("{e:#}")).to_string()
                );
                continue;
            }
        };
        let (tx, rx) = mpsc::channel();
        routes.lock().unwrap().insert(id, tx);
        queue.push(q);
        match rx.recv() {
            Ok(resp) => {
                if writeln!(writer, "{}", response_to_json(&resp).to_string()).is_err() {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let _ = peer;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let q = parse_request(r##"{"prompt":"#A=1;\n>","template":"A=?;","max_new":32}"##, 7)
            .unwrap();
        assert_eq!(q.id, 7);
        assert_eq!(q.prompt, "#A=1;\n>");
        assert_eq!(q.template, "A=?;");
        assert_eq!(q.max_new, 32);
    }

    #[test]
    fn parse_request_defaults() {
        let q = parse_request(r#"{"prompt":"x"}"#, 1).unwrap();
        assert_eq!(q.template, "");
        assert_eq!(q.max_new, 256);
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"template":"x"}"#, 1).is_err());
    }

    #[test]
    fn response_json_shape() {
        use crate::coordinator::FinishReason;
        use crate::metrics::RequestMetrics;
        let r = Response {
            id: 3,
            text: "A+B=4;".into(),
            hole_predictions: vec!['4'],
            finish: FinishReason::TemplateDone,
            metrics: RequestMetrics::default(),
            live_curve: vec![],
        };
        let j = response_to_json(&r);
        assert_eq!(j.str_at("holes").unwrap(), "4");
        assert_eq!(j.str_at("finish").unwrap(), "template_done");
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_at("id").unwrap(), 3);
    }
}
