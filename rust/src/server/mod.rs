//! TCP JSON-lines serving front-end (std::net + threads; the offline crate
//! set has no tokio — at our batch sizes the engine is compute-bound, so
//! thread-per-connection I/O costs nothing measurable).
//!
//! Protocol (one JSON object per line):
//!   → {"prompt": "...", "template": "...", "max_new": 256,
//!      "class": "interactive" | "standard" | "batch",   // SLO class, opt.
//!      "stream": true}                                  // opt-in streaming
//!   ← {"event": "token", "id": 1, "n": 3, "first": false, "text": "…"}
//!                                  // streaming only: one line per decode
//!                                  // step, written as it is produced
//!   ← {"id": 1, "text": "...", "holes": "…", "finish": "max_tokens",
//!      "ttft_ms": 12.3, "total_ms": 456.7, "tokens": 256, "evictions": 3,
//!      "pool": {"free_blocks": 9, "total_blocks": 64,        // paged mode
//!               "utilization": 0.86, "preemptions": 2,       // only
//!               "resumes": 2, "recomputed_tokens": 120,
//!               "shared_blocks": 3, "prefix_hits": 5, "prefix_misses": 2,
//!               "prefix_entries": 1, "prefix_pinned_blocks": 3,
//!               "parked_blocks": 2, "promotions": 4,      // host tier
//!               "swap_out_bytes": 9216, "swap_in_bytes": 6144, ...}}
//!                                  // terminal summary line (both modes;
//!                                  // carries "event":"done" when streaming)
//!   ← {"error": "..."}                                    // on any failure
//!
//! Concatenating the `text` of one request's token events yields exactly the
//! summary line's `text` — streaming changes delivery, never content. The
//! full wire protocol (including cancellation semantics) is specified in
//! docs/serving.md; fleet semantics in docs/fleet.md.
//!
//! `max_new` is clamped: 0 is rejected, values above [`MAX_MAX_NEW`] are
//! capped before they reach the scheduler.
//!
//! With telemetry attached (`serve_with_telemetry`), more line-protocol
//! commands are available on the same port:
//!   → {"cmd": "stats"}            ← {"stats": {"counters": …, "gauges": …,
//!                                              "histograms": …}}
//!   → {"cmd": "trace", "id": 7}   ← {"id": 7, "trace": [flight events…]}
//!   → {"cmd": "fleet"}            ← {"fleet": [per-replica status…]}
//!   → {"cmd": "kill_replica", "replica": 1}
//!                                 ← {"killed": 1}   // fault injection only
//! and the Prometheus exposition is served by the dedicated `--metrics-addr`
//! listener (see `telemetry::http`), kept off this port so scrapers never
//! head-of-line-block a generation client.
//!
//! ## Fleet architecture (listener → router → replica fan-out)
//!
//! Since PR 8 the serve loop is gone: every engine — including the N = 1
//! single-engine case — runs as a library-owned **actor**
//! ([`coordinator::actor`]) on its own thread, executing the same
//! cancel-sweep → admit → step → re-queue iteration the old in-loop engine
//! did, driven entirely by messages. The server side is three thread roles
//! around shared routing state:
//!
//! * The **acceptor** blocks in `accept` (no poll loop; shutdown wakes it
//!   with a dummy connect) and spawns one handler per connection.
//! * A **connection handler** parses requests and *places* each one
//!   through the [`Fleet`]: prompt → block-boundary header hashes
//!   ([`scheduler::routing::header_hashes`]) → [`Router::choose`] over the
//!   replicas' lock-free status views (prefix-affinity first, pool
//!   pressure as fallback, round-robin as the bench baseline) → one
//!   `EngineMsg::Submit` to the chosen replica. A paired reader thread
//!   pumps incoming lines and the EOF into the same channel replies arrive
//!   on, so the handler observes a client disconnect *while a request is
//!   in flight* and cancels straight to the home replica.
//! * The **event pump** (the calling thread) drains the fleet-wide
//!   [`ActorEvent`] channel: token events forward to streaming routes,
//!   terminal `Done`/`Failed` replies resolve their routes, `Orphaned`
//!   requests from a killed replica are *re-routed* to survivors, and
//!   router/streaming counters are published to the registry.
//!
//! Requests never migrate once placed: a preempted row's resume snapshot
//! references blocks in its home replica's pool, so the actor re-queues it
//! on its own front lane (oldest-victim-first), exactly as single-engine
//! PR 4 established.
//!
//! ## Cancellation
//!
//! A disconnect (EOF or failed write) routes the id to its home replica
//! (`Fleet::cancel`); the actor's next iteration disposes of whatever it
//! owns for that id — a queued fresh request is dropped, a queued
//! *preempted* request releases the tier state riding in its snapshot
//! (`Engine::release_discarded_state`), an active row is torn down
//! (`Engine::abort_request`). All three count into `cancelled_rows` on
//! *that replica's* metrics; other replicas are untouched.
//!
//! ## Failure delivery
//!
//! Every in-flight request owns a reply channel in `routes` and delivers
//! exactly one terminal line. Submit errors and step errors produce
//! deterministic `{"error": ...}` replies (the actor fails exactly the
//! rows inside the erroring engine). A **killed replica** (fault injection
//! or shutdown) fails its active and preempted-queued requests
//! deterministically and orphans its fresh-queued ones back to the router,
//! which re-places them on surviving replicas — no connection ever hangs
//! on a dead replica (see docs/fleet.md for the full contract).

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{spawn_engine_actor, ActorEvent, ActorHandle, Engine, Response, TokenEvent};
use crate::metrics::PoolGauges;
use crate::scheduler::{header_hashes, QueuedRequest, ReplicaView, Router, Routing, SloClass};
use crate::telemetry::{event, labeled, names, span, SpanContext, Telemetry};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;
use crate::util::wire;

/// Upper bound on a request's `max_new`; larger asks are capped, not erred,
/// so misconfigured clients degrade gracefully.
pub const MAX_MAX_NEW: usize = 4096;

pub fn response_to_json(r: &Response) -> Json {
    Json::obj()
        .set("id", r.id as f64)
        .set("text", r.text.as_str())
        .set(
            "holes",
            r.hole_predictions.iter().collect::<String>(),
        )
        .set("finish", r.finish.as_str())
        .set("ttft_ms", r.metrics.ttft_s * 1e3)
        .set("total_ms", r.metrics.total_s * 1e3)
        .set("tokens", r.metrics.tokens_out)
        .set("evictions", r.metrics.evictions)
}

/// Block-pool gauges as attached to responses in paged-KV mode. Driven by
/// `PoolGauges::fields()` — the same enumeration that feeds the `/metrics`
/// exposition — so the two surfaces cannot drift apart.
pub fn pool_gauges_to_json(g: &PoolGauges) -> Json {
    let mut j = Json::obj();
    for (name, value, _kind) in g.fields() {
        j = j.set(name, value);
    }
    j
}

/// Parse one request line via the zero-copy visitor (`util::wire`): no tree
/// is built, and an escape-free prompt is borrowed from the line until the
/// final `to_string`. Returns the queued request plus its streaming flag.
pub fn parse_request(line: &str, id: u64) -> Result<(QueuedRequest, bool)> {
    let w = wire::parse_request(line.as_bytes())
        .map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
    let prompt = w
        .prompt
        .ok_or_else(|| anyhow::anyhow!("missing key 'prompt'"))?;
    let max_new = w.max_new.map(|x| x as usize).unwrap_or(256);
    anyhow::ensure!(max_new > 0, "max_new must be >= 1");
    let class = match &w.class {
        Some(c) => SloClass::parse(c)
            .ok_or_else(|| anyhow::anyhow!("unknown class '{c}' (interactive|standard|batch)"))?,
        None => SloClass::Standard,
    };
    Ok((
        QueuedRequest {
            id,
            prompt: prompt.into_owned(),
            template: w.template.map(|t| t.into_owned()).unwrap_or_default(),
            max_new: max_new.min(MAX_MAX_NEW),
            class,
            queued_at: Instant::now(),
            resume: None,
            span: SpanContext::default(),
        },
        w.stream,
    ))
}

/// Replies the event pump sends to a connection. Terminal variants
/// (`Done`/`Failed`) arrive exactly once per request; `Token` any number of
/// times before that, streaming mode only.
enum ServeReply {
    Token(TokenEvent),
    Done(Response, Option<PoolGauges>),
    Failed(String),
}

/// Everything a connection handler can observe, merged into one channel so
/// a blocked request still sees the client hang up.
enum ConnEvent {
    Line(String),
    Eof,
    Reply(ServeReply),
}

struct Route {
    tx: mpsc::Sender<ConnEvent>,
    stream: bool,
    /// The request's root `request` span id (0 = tracing off), closed when
    /// the terminal reply resolves this route (or on cancellation).
    root: u64,
}

type Routes = Arc<Mutex<HashMap<u64, Route>>>;

fn send_reply(routes: &Routes, id: u64, reply: ServeReply) {
    if let Some(rt) = lock_unpoisoned(routes).remove(&id) {
        let _ = rt.tx.send(ConnEvent::Reply(reply));
    }
}

/// Close the request's root span (looked up from its still-live route)
/// with the terminal outcome. Flushes: the root close is the last line of
/// a request's trace, and crash-truncated JSONL must still carry it.
fn close_root_span(fleet: &Fleet, id: u64, detail: Option<f64>, note: Option<&'static str>) {
    let Some(t) = &fleet.telemetry else { return };
    let root = lock_unpoisoned(&fleet.routes)
        .get(&id)
        .map(|r| r.root)
        .unwrap_or(0);
    t.span_close_full(root, detail, note, true);
}

/// Forward one token event to its (streaming) route without consuming the
/// route — the terminal reply is still to come. Returns whether the event
/// was actually handed to a streaming client (routes for non-streaming
/// requests and already-cancelled rows swallow their events).
fn send_token(routes: &Routes, ev: TokenEvent) -> bool {
    let g = lock_unpoisoned(routes);
    if let Some(rt) = g.get(&ev.req) {
        if rt.stream {
            let _ = rt.tx.send(ConnEvent::Reply(ServeReply::Token(ev)));
            return true;
        }
    }
    false
}

/// Fleet-level serve options (`--replicas` / `--routing` on the CLI).
#[derive(Clone, Copy, Debug)]
pub struct FleetOptions {
    /// Placement policy for incoming requests.
    pub routing: Routing,
    /// Seed for the router's deterministic equal-pressure tie-break.
    pub seed: u64,
    /// Enable the `kill_replica` line-protocol command. Off by default:
    /// killing replicas is a chaos/testing tool, not a production verb.
    pub fault_injection: bool,
}

impl Default for FleetOptions {
    fn default() -> FleetOptions {
        FleetOptions {
            routing: Routing::Affinity,
            seed: 0x5eed,
            fault_injection: false,
        }
    }
}

/// Shared fleet state: the replica handles, the router, and the maps that
/// tie request ids to connections (`routes`) and home replicas
/// (`placements`).
struct Fleet {
    handles: Vec<ActorHandle>,
    router: Mutex<Router>,
    /// request id → home replica (for cancellation routing).
    placements: Mutex<HashMap<u64, usize>>,
    routes: Routes,
    tokenizer: Tokenizer,
    /// Block size the prefix hashes are keyed on (pool block size; 16 when
    /// the engines run poolless and affinity can never hit anyway).
    block_size: usize,
    telemetry: Option<Arc<Telemetry>>,
    fault_injection: bool,
    /// N > 1: per-replica metric labels are active.
    labeled: bool,
}

impl Fleet {
    fn views(&self) -> Vec<ReplicaView> {
        self.handles.iter().map(|h| h.status.view()).collect()
    }

    /// Route and deliver one request. Retries routing if the chosen
    /// replica dies in the submit race (each failure marks it dead, so the
    /// loop strictly shrinks the candidate set). `Err` carries the id and
    /// a deterministic error message for the reply line.
    fn submit(&self, q: QueuedRequest) -> std::result::Result<(), (u64, String)> {
        let ids = self.tokenizer.encode_lossy(&q.prompt);
        let hashes = header_hashes(&ids, self.block_size);
        let mut q = q;
        loop {
            let views = self.views();
            // one `route` span per placement attempt: its note records the
            // router's verdict (affinity/pressure/rr/rebalanced — or why
            // the attempt failed), its detail the chosen replica
            let route_span = match &self.telemetry {
                Some(t) if !q.span.is_off() => {
                    t.span_open(q.id, span::name::ROUTE, q.span, None, 0.0, "")
                }
                _ => 0,
            };
            let close_route = |detail: Option<f64>, note: &'static str| {
                if let Some(t) = &self.telemetry {
                    t.span_close_full(route_span, detail, Some(note), false);
                }
            };
            let decision = lock_unpoisoned(&self.router).choose(&hashes, q.id, &views);
            let Some(d) = decision else {
                close_route(None, "no_live_replicas");
                lock_unpoisoned(&self.placements).remove(&q.id);
                return Err((q.id, "no live replicas".to_string()));
            };
            let Some(h) = self.handles.get(d.replica) else {
                // the router only hands out indices < views.len(), but a
                // defective decision must fail the request, not the thread
                close_route(Some(d.replica as f64), "unknown_replica");
                lock_unpoisoned(&self.placements).remove(&q.id);
                return Err((q.id, format!("router chose unknown replica {}", d.replica)));
            };
            lock_unpoisoned(&self.placements).insert(q.id, d.replica);
            match h.submit(q) {
                Ok(()) => {
                    close_route(Some(d.replica as f64), d.reason.as_str());
                    return Ok(());
                }
                Err(back) => {
                    // raced a dying replica: flag it so choose() skips it
                    close_route(Some(d.replica as f64), "dead_replica");
                    h.status.alive.store(false, Ordering::Release);
                    q = back;
                }
            }
        }
    }

    /// Client gone: drop the route and tell the home replica to release
    /// whatever it owns for this id.
    fn cancel(&self, id: u64) {
        let root = lock_unpoisoned(&self.routes)
            .remove(&id)
            .map(|rt| rt.root)
            .unwrap_or(0);
        if let Some(t) = &self.telemetry {
            t.span_close_full(root, None, Some("cancelled"), true);
        }
        if let Some(r) = lock_unpoisoned(&self.placements).remove(&id) {
            if let Some(h) = self.handles.get(r) {
                h.cancel(id);
            }
        }
    }

    /// Publish router counters + fleet gauges into the registry.
    fn publish_metrics(&self, streamed: &[u64]) {
        let Some(t) = &self.telemetry else { return };
        let reg = &t.registry;
        let c = lock_unpoisoned(&self.router).counters;
        reg.set_counter(names::ROUTED_AFFINITY, c.routed_affinity);
        reg.set_counter(names::ROUTED_PRESSURE, c.routed_pressure);
        reg.set_counter(names::ROUTED_RR, c.routed_rr);
        reg.set_counter(names::ROUTER_REBALANCES, c.rebalances);
        let alive = self.handles.iter().filter(|h| h.is_alive()).count();
        reg.set_gauge(names::REPLICAS_ALIVE, alive as f64);
        for (i, &s) in streamed.iter().enumerate() {
            let key = if self.labeled {
                labeled(names::STREAMED_TOKENS, "replica", i)
            } else {
                names::STREAMED_TOKENS.to_string()
            };
            reg.set_counter(&key, s);
        }
        t.publish_span_metrics();
    }
}

/// Serve an engine on `addr` until `shutdown` flips (single-replica fleet).
pub fn serve(engine: Engine, addr: &str, shutdown: Arc<AtomicBool>) -> Result<()> {
    serve_with_telemetry(engine, addr, shutdown, None)
}

/// [`serve`] with a shared telemetry handle: the engine publishes registry
/// snapshots every actor iteration, connection threads record `queued`
/// flight events and answer `stats`/`trace` commands. The caller usually
/// also hands the same handle to `telemetry::spawn_metrics_listener`.
pub fn serve_with_telemetry(
    engine: Engine,
    addr: &str,
    shutdown: Arc<AtomicBool>,
    telemetry: Option<Arc<Telemetry>>,
) -> Result<()> {
    serve_fleet(vec![engine], addr, shutdown, telemetry, FleetOptions::default())
}

/// Serve N engine replicas behind the prefix-affinity router. With one
/// engine this is exactly the old single-engine server (unlabeled metrics,
/// every request routed to replica 0); with more it is the fleet. The
/// event pump runs on the calling thread; replicas and connections run on
/// spawned threads.
pub fn serve_fleet(
    engines: Vec<Engine>,
    addr: &str,
    shutdown: Arc<AtomicBool>,
    telemetry: Option<Arc<Telemetry>>,
    opts: FleetOptions,
) -> Result<()> {
    let Some(head) = engines.first() else {
        anyhow::bail!("fleet needs at least one engine");
    };
    let listener = TcpListener::bind(addr)?;
    let local_addr = listener.local_addr()?;
    let n = engines.len();
    eprintln!(
        "lazyevictiond: serving on {addr} (policy={}, budget={}, batch={}{}, replicas={n}, routing={})",
        head.policy_name(),
        head.cfg.budget,
        head.cfg.batch,
        match &head.cfg.pool {
            Some(p) => format!(", pool={}x{}", p.n_blocks, p.block_size),
            None => String::new(),
        },
        opts.routing.as_str(),
    );

    let block_size = head.cfg.pool.as_ref().map(|p| p.block_size).unwrap_or(16);
    let tokenizer = head.tokenizer.clone();
    let (etx, erx) = mpsc::channel::<ActorEvent>();
    let mut handles = Vec::with_capacity(n);
    for (i, mut e) in engines.into_iter().enumerate() {
        if n > 1 {
            e.set_replica_label(i);
        }
        if let Some(t) = &telemetry {
            e.attach_telemetry(t.clone());
        }
        handles.push(spawn_engine_actor(e, i, etx.clone()));
    }
    drop(etx); // pump's receiver outlives exactly the actors

    let fleet = Arc::new(Fleet {
        handles,
        router: Mutex::new(Router::new(opts.routing, opts.seed)),
        placements: Mutex::new(HashMap::new()),
        routes: Arc::new(Mutex::new(HashMap::new())),
        tokenizer,
        block_size,
        telemetry: telemetry.clone(),
        fault_injection: opts.fault_injection,
        labeled: n > 1,
    });
    let next_id = Arc::new(AtomicU64::new(1));

    // acceptor thread: blocking accept (no retry poll); the pump wakes it
    // at shutdown with a dummy connect to our own address
    {
        let fleet = fleet.clone();
        let next_id = next_id.clone();
        let shutdown = shutdown.clone();
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(s) = stream else { break };
                let fleet = fleet.clone();
                let next_id = next_id.clone();
                std::thread::spawn(move || handle_conn(s, fleet, next_id));
            }
        });
    }

    // event pump (this thread): actor events → connection replies
    let mut streamed: Vec<u64> = vec![0; n];
    while !shutdown.load(Ordering::Relaxed) {
        match erx.recv_timeout(Duration::from_millis(25)) {
            Ok(ev) => {
                let publish = !matches!(ev, ActorEvent::Token { .. });
                pump_event(&fleet, ev, &mut streamed);
                if publish {
                    fleet.publish_metrics(&streamed);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => fleet.publish_metrics(&streamed),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // every replica exited; submits now fail deterministically
                // ("no live replicas") — idle until shutdown
                fleet.publish_metrics(&streamed);
                // lazylint: allow(determinism): every replica already exited — there is no event source left to wake on, only the shutdown flag to poll
                std::thread::sleep(Duration::from_millis(25));
            }
        }
    }

    // shutdown: kill all replicas (their teardown fails/orphans what they
    // own), then drain the final events so every in-flight connection gets
    // its terminal line instead of hanging
    for h in &fleet.handles {
        h.kill();
    }
    for h in &fleet.handles {
        h.join();
    }
    while let Ok(ev) = erx.try_recv() {
        pump_event(&fleet, ev, &mut streamed);
    }
    fleet.publish_metrics(&streamed);
    // wake the acceptor out of its blocking accept so it observes shutdown
    let _ = TcpStream::connect(local_addr);
    if let Some(t) = &telemetry {
        t.flush();
    }
    Ok(())
}

/// Translate one actor event into connection replies / routing updates.
fn pump_event(fleet: &Arc<Fleet>, ev: ActorEvent, streamed: &mut [u64]) {
    match ev {
        ActorEvent::Token { replica, ev } => {
            if send_token(&fleet.routes, ev) {
                if let Some(s) = streamed.get_mut(replica) {
                    *s += 1;
                }
            }
        }
        ActorEvent::Done { resp, gauges, .. } => {
            lock_unpoisoned(&fleet.placements).remove(&resp.id);
            let id = resp.id;
            close_root_span(
                fleet,
                id,
                Some(resp.metrics.tokens_out as f64),
                Some(resp.finish.as_str()),
            );
            send_reply(&fleet.routes, id, ServeReply::Done(resp, gauges));
        }
        ActorEvent::Failed { req, error, .. } => {
            lock_unpoisoned(&fleet.placements).remove(&req);
            close_root_span(fleet, req, None, Some("failed"));
            send_reply(&fleet.routes, req, ServeReply::Failed(error));
        }
        ActorEvent::Orphaned { replica, req } => {
            // a killed replica never admitted this request: place it again
            // on the survivors; only give up when the whole fleet is gone.
            // The `reroute` hop span (detail = the dead replica) is what
            // stitches the two replicas' span trees under one trace.
            if let Some(t) = &fleet.telemetry {
                if !req.span.is_off() {
                    let sid =
                        t.span_open(req.id, span::name::REROUTE, req.span, None, replica as f64, "");
                    t.span_close_full(sid, None, None, false);
                }
            }
            if let Err((id, msg)) = fleet.submit(req) {
                close_root_span(fleet, id, None, Some("failed"));
                send_reply(&fleet.routes, id, ServeReply::Failed(msg));
            }
        }
        ActorEvent::Exited { replica, clean } => {
            if !clean {
                eprintln!("lazyevictiond: replica {replica} exited (killed)");
            }
        }
    }
}

/// Handle a `{"cmd": ...}` line; returns the reply, or `None` if the line
/// is not a command (i.e. a generation request).
fn handle_command(line: &str, fleet: &Arc<Fleet>) -> Option<Json> {
    let j = Json::parse(line).ok()?;
    let cmd = j.get("cmd")?.as_str()?.to_string();
    match cmd.as_str() {
        "fleet" => {
            let replicas: Vec<Json> = fleet
                .handles
                .iter()
                .map(|h| {
                    let v = h.status.view();
                    Json::obj()
                        .set("replica", h.replica)
                        .set("alive", if v.alive { 1.0 } else { 0.0 })
                        .set("free_blocks", v.free_blocks)
                        .set("total_blocks", v.total_blocks)
                        .set("parked_bytes", v.parked_bytes)
                        .set("queue_len", v.queue_len)
                        .set("active", v.active)
                        .set("digest_len", v.digest.len())
                })
                .collect();
            return Some(Json::obj().set("fleet", replicas));
        }
        "kill_replica" => {
            if !fleet.fault_injection {
                return Some(Json::obj().set(
                    "error",
                    "kill_replica requires --fault-injection",
                ));
            }
            let Some(r) = j.get("replica").and_then(|v| v.as_f64()) else {
                return Some(Json::obj().set("error", "kill_replica requires a numeric 'replica'"));
            };
            let r = r as usize;
            let Some(h) = fleet.handles.get(r) else {
                return Some(Json::obj().set("error", format!("no replica {r}")));
            };
            h.kill();
            return Some(Json::obj().set("killed", r));
        }
        _ => {}
    }
    let Some(t) = &fleet.telemetry else {
        return Some(Json::obj().set("error", "telemetry not enabled on this server"));
    };
    Some(match cmd.as_str() {
        "stats" => Json::obj().set("stats", t.registry.to_json()),
        "trace" => match j.get("id").and_then(|v| v.as_f64()) {
            Some(id) => {
                let events: Vec<Json> = t
                    .events_for(id as u64)
                    .iter()
                    .map(|e| e.to_json())
                    .collect();
                Json::obj().set("id", id).set("trace", events)
            }
            None => Json::obj().set("error", "trace requires a numeric 'id'"),
        },
        other => Json::obj().set("error", format!("unknown cmd '{other}'")),
    })
}

fn handle_conn(stream: TcpStream, fleet: Arc<Fleet>, next_id: Arc<AtomicU64>) {
    let reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut writer = stream;
    let (tx, rx) = mpsc::channel::<ConnEvent>();

    // reader thread: pump lines and the EOF into the merged channel, so the
    // handler observes a disconnect even while a request is in flight
    {
        let tx = tx.clone();
        std::thread::spawn(move || {
            for line in reader.lines() {
                let Ok(line) = line else { break };
                if tx.send(ConnEvent::Line(line)).is_err() {
                    return;
                }
            }
            let _ = tx.send(ConnEvent::Eof);
        });
    }

    // lines that arrived while a request was in flight (pipelining)
    let mut pending: VecDeque<String> = VecDeque::new();
    let mut events = wire::EventWriter::new();
    'conn: loop {
        let line = match pending.pop_front() {
            Some(l) => l,
            None => match rx.recv() {
                Ok(ConnEvent::Line(l)) => l,
                Ok(ConnEvent::Eof) | Err(_) => break 'conn,
                // replies for a request this handler already gave up on
                Ok(ConnEvent::Reply(_)) => continue,
            },
        };
        if line.trim().is_empty() {
            continue;
        }
        if let Some(reply) = handle_command(&line, &fleet) {
            if writeln!(writer, "{}", reply.to_string()).is_err() {
                break 'conn;
            }
            continue;
        }
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let (mut q, stream_mode) = match parse_request(&line, id) {
            Ok(v) => v,
            Err(e) => {
                let _ = writeln!(
                    writer,
                    "{}",
                    Json::obj().set("error", format!("{e:#}")).to_string()
                );
                continue;
            }
        };
        // trace root: every downstream span (route, queue wait, prefill,
        // decode windows, eviction, preempt/re-route hops — on whichever
        // replica ends up serving it) links under this id
        let root = match &fleet.telemetry {
            Some(t) => t.span_open(
                id,
                span::name::REQUEST,
                SpanContext::default(),
                None,
                0.0,
                q.class.as_str(),
            ),
            None => 0,
        };
        if root != 0 {
            q.span = SpanContext::child_of(root, root);
        }
        lock_unpoisoned(&fleet.routes).insert(
            id,
            Route {
                tx: tx.clone(),
                stream: stream_mode,
                root,
            },
        );
        if let Some(t) = &fleet.telemetry {
            t.record(id, event::QUEUED, 0, 0, 0.0, q.class.as_str());
        }
        if let Err((fid, msg)) = fleet.submit(q) {
            // deterministic routing failure: the reply arrives on our own
            // channel like any other terminal, handled by the loop below
            send_reply(&fleet.routes, fid, ServeReply::Failed(msg));
        }
        // in flight: forward token events as they arrive, finish on the
        // terminal reply, cancel on any sign the client is gone
        loop {
            match rx.recv() {
                Ok(ConnEvent::Reply(ServeReply::Token(ev))) => {
                    let line = events.token(ev.req, &ev.text, ev.produced, ev.first);
                    if writer.write_all(line).is_err() {
                        fleet.cancel(id);
                        break 'conn;
                    }
                }
                Ok(ConnEvent::Reply(ServeReply::Done(resp, gauges))) => {
                    let mut j = response_to_json(&resp);
                    if stream_mode {
                        j = j.set("event", "done");
                    }
                    if let Some(g) = gauges {
                        j = j.set("pool", pool_gauges_to_json(&g));
                    }
                    if writeln!(writer, "{}", j.to_string()).is_err() {
                        break 'conn;
                    }
                    break;
                }
                Ok(ConnEvent::Reply(ServeReply::Failed(msg))) => {
                    // deterministic failure line; connection stays usable
                    if writeln!(
                        writer,
                        "{}",
                        Json::obj().set("error", msg.as_str()).to_string()
                    )
                    .is_err()
                    {
                        break 'conn;
                    }
                    break;
                }
                // client sent the next request before this one finished
                Ok(ConnEvent::Line(l)) => pending.push_back(l),
                // client hung up mid-request: cancel straight to the home
                // replica and leave — its actor releases blocks/tier state
                // on its next iteration
                Ok(ConnEvent::Eof) => {
                    fleet.cancel(id);
                    break 'conn;
                }
                // server shut down with the request still in flight
                Err(_) => {
                    let _ = writeln!(
                        writer,
                        "{}",
                        Json::obj().set("error", "server shut down").to_string()
                    );
                    break 'conn;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_request_full() {
        let (q, stream) =
            parse_request(r##"{"prompt":"#A=1;\n>","template":"A=?;","max_new":32}"##, 7)
                .unwrap();
        assert_eq!(q.id, 7);
        assert_eq!(q.prompt, "#A=1;\n>");
        assert_eq!(q.template, "A=?;");
        assert_eq!(q.max_new, 32);
        assert_eq!(q.class, SloClass::Standard);
        assert!(!stream);
    }

    #[test]
    fn parse_request_defaults() {
        let (q, stream) = parse_request(r#"{"prompt":"x"}"#, 1).unwrap();
        assert_eq!(q.template, "");
        assert_eq!(q.max_new, 256);
        assert_eq!(q.class, SloClass::Standard);
        assert!(!stream);
    }

    #[test]
    fn parse_request_class_and_stream() {
        let (q, stream) =
            parse_request(r#"{"prompt":"x","class":"interactive","stream":true}"#, 1).unwrap();
        assert_eq!(q.class, SloClass::Interactive);
        assert!(stream);
        let (q, _) = parse_request(r#"{"prompt":"x","class":"batch"}"#, 1).unwrap();
        assert_eq!(q.class, SloClass::Batch);
        // unknown class is a hard error, not a silent default
        assert!(parse_request(r#"{"prompt":"x","class":"platinum"}"#, 1).is_err());
    }

    #[test]
    fn parse_request_rejects_garbage() {
        assert!(parse_request("not json", 1).is_err());
        assert!(parse_request(r#"{"template":"x"}"#, 1).is_err());
    }

    #[test]
    fn parse_request_clamps_max_new() {
        // zero is rejected outright
        assert!(parse_request(r#"{"prompt":"x","max_new":0}"#, 1).is_err());
        // negative numbers land on 0 via the f64→usize cast: also rejected
        assert!(parse_request(r#"{"prompt":"x","max_new":-5}"#, 1).is_err());
        // absurd values are capped, not erred
        let (q, _) = parse_request(r#"{"prompt":"x","max_new":999999999}"#, 1).unwrap();
        assert_eq!(q.max_new, MAX_MAX_NEW);
        let (q, _) = parse_request(&format!(r#"{{"prompt":"x","max_new":{MAX_MAX_NEW}}}"#), 1)
            .unwrap();
        assert_eq!(q.max_new, MAX_MAX_NEW);
    }

    #[test]
    fn parse_request_ignores_unknown_fields() {
        let (q, _) = parse_request(
            r#"{"prompt":"x","future":{"nested":[1,2,3]},"n":null}"#,
            1,
        )
        .unwrap();
        assert_eq!(q.prompt, "x");
    }

    #[test]
    fn response_json_shape() {
        use crate::coordinator::FinishReason;
        use crate::metrics::RequestMetrics;
        let r = Response {
            id: 3,
            text: "A+B=4;".into(),
            hole_predictions: vec!['4'],
            finish: FinishReason::TemplateDone,
            metrics: RequestMetrics::default(),
            live_curve: vec![],
        };
        let j = response_to_json(&r);
        assert_eq!(j.str_at("holes").unwrap(), "4");
        assert_eq!(j.str_at("finish").unwrap(), "template_done");
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_at("id").unwrap(), 3);
    }

    #[test]
    fn pool_gauges_json_shape() {
        let g = PoolGauges {
            free_blocks: 9,
            total_blocks: 64,
            utilization: 0.859,
            preemptions: 2,
            resumes: 2,
            recomputed_tokens: 120,
            shared_blocks: 3,
            prefix_hits: 5,
            prefix_misses: 2,
            prefix_entries: 1,
            prefix_pinned_blocks: 3,
            prefix_prefill_skips: 4,
            kv_arena_bytes: 131072,
            kv_bytes_in_use: 112640,
            parked_blocks: 3,
            parked_bytes: 3072,
            demoted_blocks: 7,
            promotions: 5,
            false_evictions_avoided: 11,
            swap_out_bytes: 9216,
            swap_in_bytes: 6144,
            swap_preempts: 1,
            tier_shed_blocks: 2,
            tier_rejects: 6,
        };
        let j = pool_gauges_to_json(&g);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.usize_at("free_blocks").unwrap(), 9);
        assert_eq!(parsed.usize_at("total_blocks").unwrap(), 64);
        assert_eq!(parsed.usize_at("preemptions").unwrap(), 2);
        assert_eq!(parsed.usize_at("resumes").unwrap(), 2);
        assert_eq!(parsed.usize_at("recomputed_tokens").unwrap(), 120);
        assert!((parsed.f64_at("utilization").unwrap() - 0.859).abs() < 1e-9);
        assert_eq!(parsed.usize_at("shared_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("prefix_hits").unwrap(), 5);
        assert_eq!(parsed.usize_at("prefix_misses").unwrap(), 2);
        assert_eq!(parsed.usize_at("prefix_entries").unwrap(), 1);
        assert_eq!(parsed.usize_at("prefix_pinned_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("prefix_prefill_skips").unwrap(), 4);
        assert_eq!(parsed.usize_at("kv_arena_bytes").unwrap(), 131072);
        assert_eq!(parsed.usize_at("kv_bytes_in_use").unwrap(), 112640);
        assert_eq!(parsed.usize_at("parked_blocks").unwrap(), 3);
        assert_eq!(parsed.usize_at("parked_bytes").unwrap(), 3072);
        assert_eq!(parsed.usize_at("demoted_blocks").unwrap(), 7);
        assert_eq!(parsed.usize_at("promotions").unwrap(), 5);
        assert_eq!(parsed.usize_at("false_evictions_avoided").unwrap(), 11);
        assert_eq!(parsed.usize_at("swap_out_bytes").unwrap(), 9216);
        assert_eq!(parsed.usize_at("swap_in_bytes").unwrap(), 6144);
        assert_eq!(parsed.usize_at("swap_preempts").unwrap(), 1);
        assert_eq!(parsed.usize_at("tier_shed_blocks").unwrap(), 2);
        assert_eq!(parsed.usize_at("tier_rejects").unwrap(), 6);
    }

    /// Every `PoolGauges` field must appear in both export surfaces: the
    /// server `pool` JSON and the Prometheus exposition. `fields()` is the
    /// single enumeration (exhaustive destructuring makes omissions a
    /// compile error); this pins that both paths actually consume it.
    #[test]
    fn pool_gauge_field_parity_json_and_exposition() {
        let g = PoolGauges {
            free_blocks: 1,
            total_blocks: 2,
            utilization: 0.5,
            preemptions: 3,
            resumes: 4,
            recomputed_tokens: 5,
            shared_blocks: 6,
            prefix_hits: 7,
            prefix_misses: 8,
            prefix_entries: 9,
            prefix_pinned_blocks: 10,
            prefix_prefill_skips: 11,
            kv_arena_bytes: 12,
            kv_bytes_in_use: 13,
            parked_blocks: 14,
            parked_bytes: 15,
            demoted_blocks: 16,
            promotions: 17,
            false_evictions_avoided: 18,
            swap_out_bytes: 19,
            swap_in_bytes: 20,
            swap_preempts: 21,
            tier_shed_blocks: 22,
            tier_rejects: 23,
        };
        let json = pool_gauges_to_json(&g);
        let obj = json.as_obj().expect("pool json is an object");

        let reg = crate::telemetry::Registry::new();
        g.publish(&reg);
        let exposition = reg.render_prometheus();

        let fields = g.fields();
        assert_eq!(obj.len(), fields.len(), "json has exactly the fields");
        for (name, value, _kind) in &fields {
            assert_eq!(
                json.f64_at(name).unwrap(),
                *value,
                "json missing or wrong for {name}"
            );
            let metric = format!("{}{name}", crate::telemetry::names::POOL_PREFIX);
            let line = format!("{metric} ");
            assert!(
                exposition.lines().any(|l| l.starts_with(&line)),
                "exposition missing {metric}"
            );
        }
        // distinct values survive the round trip (no copy-paste aliasing)
        assert_eq!(json.f64_at("tier_rejects").unwrap(), 23.0);
        assert!(exposition.contains("lazyeviction_pool_tier_rejects 23"));
    }

    /// Labeled pool publishing (fleet mode) keeps per-replica samples
    /// separate in one registry while the JSON surface is per-response.
    #[test]
    fn pool_gauges_publish_labeled_per_replica() {
        let a = PoolGauges {
            free_blocks: 5,
            ..Default::default()
        };
        let b = PoolGauges {
            free_blocks: 9,
            ..Default::default()
        };
        let reg = crate::telemetry::Registry::new();
        a.publish_labeled(&reg, 0);
        b.publish_labeled(&reg, 1);
        let text = reg.render_prometheus();
        assert!(text.contains("lazyeviction_pool_free_blocks{replica=\"0\"} 5"));
        assert!(text.contains("lazyeviction_pool_free_blocks{replica=\"1\"} 9"));
        assert_eq!(
            text.matches("# TYPE lazyeviction_pool_free_blocks gauge").count(),
            1
        );
    }
}
