//! Fixed-width table rendering for bench outputs (rows mirror the paper).

/// Simple column-aligned table printer.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", c, w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format an accuracy cell like the paper (2 decimals, bold markers kept
/// plain-text).
pub fn acc(x: f64) -> String {
    format!("{x:.2}")
}

pub fn ms(x: f64) -> String {
    format!("{:.2}", x * 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Method", "GSM8K"]);
        t.row(vec!["FullKV".into(), acc(81.73)]);
        t.row(vec!["LazyEviction".into(), acc(80.06)]);
        let s = t.render();
        assert!(s.contains("FullKV"));
        assert!(s.contains("81.73"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn width_mismatch_panics() {
        Table::new(&["a"]).row(vec!["x".into(), "y".into()]);
    }
}
