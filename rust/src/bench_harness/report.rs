//! Recorded benchmark trajectories — the `BENCH_pool.json` surface.
//!
//! The CI quick-bench (`cargo bench --bench pool`) emits one
//! [`BenchReport`]: per policy × scenario, the sustained batch, TTFT/TPOT
//! percentiles (from the engine's streaming histograms) and the tier's
//! promotion/park/shed counters, under a fixed `schema_version`. The file
//! is uploaded as a CI artifact, so successive runs form a recorded
//! trajectory tools can diff without parsing bench stdout.
//!
//! [`BenchReport::validate`] is the schema check: the bench asserts the
//! report it just built round-trips through it before writing, and the
//! unit tests here pin the schema against accidental drift (a field
//! rename or type change fails validation, not a downstream dashboard).

use std::path::Path;

use crate::telemetry::StreamingHistogram;
use crate::util::json::Json;

/// Bump when a field is renamed/removed or its meaning changes. Additive
/// fields do not need a bump — `validate` only requires, never forbids.
/// History: 1 = the original policy × scenario grid; 2 = + the optional
/// `fleet` section (multi-replica routing cells; absent when a bench
/// records no fleet scenarios, and validated when present); 3 = + the
/// optional `recurrence` section (eviction-observatory cells: pass and
/// decision counts, MRI and time-to-promotion quantiles, false-eviction
/// postmortem counts; present only for cells run with
/// `observe_recurrence` on).
pub const SCHEMA_VERSION: usize = 3;

/// Latency quantile summary extracted from a [`StreamingHistogram`].
#[derive(Clone, Debug, Default)]
pub struct Quantiles {
    pub n: usize,
    pub mean: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Quantiles {
    pub fn from_hist(h: &StreamingHistogram) -> Quantiles {
        Quantiles {
            n: h.n() as usize,
            mean: h.mean(),
            p50: h.quantile(0.50),
            p90: h.quantile(0.90),
            p99: h.quantile(0.99),
            max: h.max(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n", self.n)
            .set("mean", self.mean)
            .set("p50", self.p50)
            .set("p90", self.p90)
            .set("p99", self.p99)
            .set("max", self.max)
    }
}

/// One measured cell of the policy × scenario grid. Counter fields are raw
/// totals; rates are derivable against `steps` (per-step) or `completed`
/// (per-request), so the report never bakes in a denominator choice.
#[derive(Clone, Debug, Default)]
pub struct BenchScenario {
    pub policy: String,
    pub scenario: String,
    /// Decode steps the scenario ran.
    pub steps: u64,
    /// Mean concurrently-decoding rows (tokens_out / steps).
    pub sustained_batch: f64,
    /// Configured row ceiling for the scenario.
    pub peak_batch: usize,
    /// Requests finished.
    pub completed: u64,
    pub preemptions: u64,
    pub resumes: u64,
    /// Host tier: recurrence-driven promotions (entries swapped back in).
    pub promotions: u64,
    /// Host tier: evicted-block groups parked instead of destroyed.
    pub demoted_blocks: u64,
    /// Host tier: park attempts refused (byte budget exhausted).
    pub tier_rejects: u64,
    /// Host tier: parked entries destroyed under byte pressure.
    pub tier_shed_blocks: u64,
    /// Token events surfaced to a streaming consumer as they were decoded.
    /// Batch-mode cells report 0 (nothing drains the events); the `stream`
    /// cell counts the events its bench-side client drained per step.
    pub streamed_tokens: u64,
    /// Rows/requests torn down by client cancellation or disconnect.
    pub cancelled_rows: u64,
    pub ttft_ms: Quantiles,
    pub tpot_ms: Quantiles,
}

impl BenchScenario {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("policy", self.policy.as_str())
            .set("scenario", self.scenario.as_str())
            .set("steps", self.steps as f64)
            .set("sustained_batch", self.sustained_batch)
            .set("peak_batch", self.peak_batch)
            .set("completed", self.completed as f64)
            .set("preemptions", self.preemptions as f64)
            .set("resumes", self.resumes as f64)
            .set("promotions", self.promotions as f64)
            .set("demoted_blocks", self.demoted_blocks as f64)
            .set("tier_rejects", self.tier_rejects as f64)
            .set("tier_shed_blocks", self.tier_shed_blocks as f64)
            .set("streamed_tokens", self.streamed_tokens as f64)
            .set("cancelled_rows", self.cancelled_rows as f64)
            .set("ttft_ms", self.ttft_ms.to_json())
            .set("tpot_ms", self.tpot_ms.to_json())
    }
}

/// One multi-replica routing cell (the `fleet` section, schema v2): a
/// `sim::capacity::run_fleet` outcome keyed by routing policy × replica
/// count, so CI trajectories record the affinity-vs-blind hit-rate gap and
/// how sustained batch scales with the fleet.
#[derive(Clone, Debug, Default)]
pub struct FleetCell {
    pub routing: String,
    pub replicas: usize,
    /// Fleet-wide sustained batch (sum of per-replica means).
    pub sustained_batch: f64,
    /// Header placements served by an already-resident prefix.
    pub header_hits: u64,
    /// Cold header materializations (duplication = the routing tax).
    pub header_misses: u64,
    /// hits / requests, in [0, 1].
    pub hit_rate: f64,
    pub preemptions: u64,
    pub completed: u64,
}

impl FleetCell {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("routing", self.routing.as_str())
            .set("replicas", self.replicas)
            .set("sustained_batch", self.sustained_batch)
            .set("header_hits", self.header_hits as f64)
            .set("header_misses", self.header_misses as f64)
            .set("hit_rate", self.hit_rate)
            .set("preemptions", self.preemptions as f64)
            .set("completed", self.completed as f64)
    }
}

/// One eviction-observatory cell (the `recurrence` section, schema v3):
/// what the [`crate::eviction::RecurrenceObservatory`] saw for a policy ×
/// scenario run with `observe_recurrence` on. The cell records whether
/// lagged eviction's bet paid off: `time_to_promotion_steps` is how long
/// parked entries sat before recurrence pulled them back, and `postmortem`
/// splits those promotions by parked duration (fast promotions = tokens
/// that should never have left the device tier).
#[derive(Clone, Debug, Default)]
pub struct RecurrenceCell {
    pub policy: String,
    pub scenario: String,
    /// Eviction passes observed.
    pub passes: u64,
    /// Per-token verdicts recorded across all passes.
    pub decisions: u64,
    /// Max recurrence-interval distribution over observed tokens (steps).
    pub mri: Quantiles,
    /// Steps parked in the host tier before promotion.
    pub time_to_promotion_steps: Quantiles,
    /// Promotions by parked duration, in
    /// [`crate::eviction::observatory::POSTMORTEM_LABELS`] order.
    pub postmortem: [u64; 4],
}

impl RecurrenceCell {
    pub fn to_json(&self) -> Json {
        let mut pm = Json::obj();
        for (label, &n) in crate::eviction::observatory::POSTMORTEM_LABELS
            .iter()
            .zip(self.postmortem.iter())
        {
            pm = pm.set(label, n as f64);
        }
        Json::obj()
            .set("policy", self.policy.as_str())
            .set("scenario", self.scenario.as_str())
            .set("passes", self.passes as f64)
            .set("decisions", self.decisions as f64)
            .set("mri", self.mri.to_json())
            .set("time_to_promotion_steps", self.time_to_promotion_steps.to_json())
            .set("postmortem", pm)
    }
}

/// The whole recorded run: metadata + every grid cell.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub bench: String,
    /// Workload size knob the run used (LAZYEVICTION_BENCH_SAMPLES).
    pub samples: usize,
    pub results: Vec<BenchScenario>,
    /// Multi-replica routing cells; empty = no fleet section serialized.
    pub fleet: Vec<FleetCell>,
    /// Eviction-observatory cells; empty = no recurrence section serialized.
    pub recurrence: Vec<RecurrenceCell>,
}

impl BenchReport {
    pub fn new(bench: &str, samples: usize) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            samples,
            results: Vec::new(),
            fleet: Vec::new(),
            recurrence: Vec::new(),
        }
    }

    pub fn push(&mut self, s: BenchScenario) {
        self.results.push(s);
    }

    pub fn push_fleet(&mut self, c: FleetCell) {
        self.fleet.push(c);
    }

    pub fn push_recurrence(&mut self, c: RecurrenceCell) {
        self.recurrence.push(c);
    }

    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self.results.iter().map(|s| s.to_json()).collect();
        let mut j = Json::obj()
            .set("schema_version", SCHEMA_VERSION)
            .set("bench", self.bench.as_str())
            .set("samples", self.samples)
            .set("results", results);
        if !self.fleet.is_empty() {
            let fleet: Vec<Json> = self.fleet.iter().map(|c| c.to_json()).collect();
            j = j.set("fleet", fleet);
        }
        if !self.recurrence.is_empty() {
            let rec: Vec<Json> = self.recurrence.iter().map(|c| c.to_json()).collect();
            j = j.set("recurrence", rec);
        }
        j
    }

    /// Schema check for a serialized report. Returns the first violation.
    pub fn validate(j: &Json) -> Result<(), String> {
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_usize())
            .ok_or("missing schema_version")?;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} != supported {SCHEMA_VERSION}"
            ));
        }
        let bench = j
            .get("bench")
            .and_then(|v| v.as_str())
            .ok_or("missing bench name")?;
        if bench.is_empty() {
            return Err("empty bench name".into());
        }
        j.get("samples")
            .and_then(|v| v.as_f64())
            .ok_or("missing samples")?;
        let results = j
            .get("results")
            .and_then(|v| v.as_arr())
            .ok_or("missing results array")?;
        if results.is_empty() {
            return Err("empty results array".into());
        }
        for (i, s) in results.iter().enumerate() {
            for key in ["policy", "scenario"] {
                s.get(key)
                    .and_then(|v| v.as_str())
                    .ok_or(format!("results[{i}]: missing string '{key}'"))?;
            }
            for key in [
                "steps",
                "sustained_batch",
                "peak_batch",
                "completed",
                "preemptions",
                "resumes",
                "promotions",
                "demoted_blocks",
                "tier_rejects",
                "tier_shed_blocks",
                "streamed_tokens",
                "cancelled_rows",
            ] {
                let v = s
                    .get(key)
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("results[{i}]: missing number '{key}'"))?;
                if v < 0.0 {
                    return Err(format!("results[{i}]: negative '{key}'"));
                }
            }
            for hist in ["ttft_ms", "tpot_ms"] {
                let q = s
                    .get(hist)
                    .ok_or(format!("results[{i}]: missing '{hist}'"))?;
                let mut vals = [0.0f64; 4];
                for (slot, key) in ["p50", "p90", "p99", "max"].iter().enumerate() {
                    vals[slot] = q
                        .get(key)
                        .and_then(|v| v.as_f64())
                        .ok_or(format!("results[{i}].{hist}: missing '{key}'"))?;
                }
                q.get("n")
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("results[{i}].{hist}: missing 'n'"))?;
                q.get("mean")
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("results[{i}].{hist}: missing 'mean'"))?;
                if !(vals[0] <= vals[1] && vals[1] <= vals[2] && vals[2] <= vals[3]) {
                    return Err(format!(
                        "results[{i}].{hist}: quantiles not monotone \
                         (p50 {} p90 {} p99 {} max {})",
                        vals[0], vals[1], vals[2], vals[3]
                    ));
                }
            }
        }
        // the fleet section is additive: absent is fine, present must hold
        if let Some(fleet) = j.get("fleet") {
            let cells = fleet.as_arr().ok_or("fleet is not an array")?;
            if cells.is_empty() {
                return Err("fleet present but empty".into());
            }
            for (i, c) in cells.iter().enumerate() {
                c.get("routing")
                    .and_then(|v| v.as_str())
                    .ok_or(format!("fleet[{i}]: missing string 'routing'"))?;
                for key in [
                    "replicas",
                    "sustained_batch",
                    "header_hits",
                    "header_misses",
                    "preemptions",
                    "completed",
                ] {
                    let v = c
                        .get(key)
                        .and_then(|v| v.as_f64())
                        .ok_or(format!("fleet[{i}]: missing number '{key}'"))?;
                    if v < 0.0 {
                        return Err(format!("fleet[{i}]: negative '{key}'"));
                    }
                }
                let hr = c
                    .get("hit_rate")
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("fleet[{i}]: missing number 'hit_rate'"))?;
                if !(0.0..=1.0).contains(&hr) {
                    return Err(format!("fleet[{i}]: hit_rate {hr} out of [0, 1]"));
                }
                if c.get("replicas").and_then(|v| v.as_usize()).unwrap_or(0) == 0 {
                    return Err(format!("fleet[{i}]: replicas must be >= 1"));
                }
            }
        }
        // the recurrence section is additive too: absent = observatory off
        if let Some(rec) = j.get("recurrence") {
            let cells = rec.as_arr().ok_or("recurrence is not an array")?;
            if cells.is_empty() {
                return Err("recurrence present but empty".into());
            }
            for (i, c) in cells.iter().enumerate() {
                for key in ["policy", "scenario"] {
                    c.get(key)
                        .and_then(|v| v.as_str())
                        .ok_or(format!("recurrence[{i}]: missing string '{key}'"))?;
                }
                for key in ["passes", "decisions"] {
                    let v = c
                        .get(key)
                        .and_then(|v| v.as_f64())
                        .ok_or(format!("recurrence[{i}]: missing number '{key}'"))?;
                    if v < 0.0 {
                        return Err(format!("recurrence[{i}]: negative '{key}'"));
                    }
                }
                for hist in ["mri", "time_to_promotion_steps"] {
                    let q = c
                        .get(hist)
                        .ok_or(format!("recurrence[{i}]: missing '{hist}'"))?;
                    for key in ["n", "mean", "p50", "p90", "p99", "max"] {
                        q.get(key)
                            .and_then(|v| v.as_f64())
                            .ok_or(format!("recurrence[{i}].{hist}: missing '{key}'"))?;
                    }
                }
                let pm = c
                    .get("postmortem")
                    .ok_or(format!("recurrence[{i}]: missing 'postmortem'"))?;
                for label in crate::eviction::observatory::POSTMORTEM_LABELS {
                    let v = pm
                        .get(label)
                        .and_then(|v| v.as_f64())
                        .ok_or(format!("recurrence[{i}].postmortem: missing '{label}'"))?;
                    if v < 0.0 {
                        return Err(format!("recurrence[{i}].postmortem: negative '{label}'"));
                    }
                }
            }
        }
        Ok(())
    }

    /// Validate, then write the report to `path` (pretty-printed).
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let j = self.to_json();
        BenchReport::validate(&j)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        std::fs::write(path, j.to_pretty())?;
        eprintln!("[results] wrote {}", path.display());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> BenchReport {
        let mut hist = StreamingHistogram::latency_ms();
        for ms in [1.0, 2.0, 4.0, 8.0] {
            hist.observe(ms);
        }
        let mut r = BenchReport::new("pool", 8);
        r.push(BenchScenario {
            policy: "lazy".into(),
            scenario: "steady".into(),
            steps: 100,
            sustained_batch: 1.9,
            peak_batch: 2,
            completed: 4,
            ttft_ms: Quantiles::from_hist(&hist),
            tpot_ms: Quantiles::from_hist(&hist),
            ..Default::default()
        });
        r
    }

    #[test]
    fn report_round_trips_and_validates() {
        let j = sample_report().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        BenchReport::validate(&parsed).expect("schema-valid");
        assert_eq!(parsed.usize_at("schema_version").unwrap(), SCHEMA_VERSION);
        let results = parsed.get("results").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].str_at("policy").unwrap(), "lazy");
        assert!(results[0].f64_at("sustained_batch").unwrap() > 0.0);
    }

    #[test]
    fn empty_histogram_is_schema_valid() {
        // a scenario whose TPOT never fired (single-token outputs) must
        // still serialize to a valid report, not NaN-poison it
        let mut r = BenchReport::new("pool", 1);
        r.push(BenchScenario {
            policy: "full".into(),
            scenario: "steady".into(),
            ..Default::default()
        });
        BenchReport::validate(&r.to_json()).expect("empty quantiles are 0.0");
    }

    #[test]
    fn validate_rejects_corruption() {
        let good = sample_report().to_json();
        // wrong version
        let j = Json::parse(&good.to_string())
            .unwrap()
            .set("schema_version", 99usize);
        assert!(BenchReport::validate(&j).is_err());
        // missing results
        let j = Json::obj().set("schema_version", SCHEMA_VERSION).set(
            "bench",
            "pool",
        );
        assert!(BenchReport::validate(&j).is_err());
        // a result missing a required counter
        let bad = r#"{"schema_version":3,"bench":"pool","samples":1,
            "results":[{"policy":"lazy","scenario":"steady"}]}"#;
        assert!(BenchReport::validate(&Json::parse(bad).unwrap()).is_err());
        // non-monotone quantiles
        let mut s = sample_report();
        s.results[0].ttft_ms.p90 = 0.0;
        assert!(BenchReport::validate(&s.to_json()).is_err());
    }

    #[test]
    fn fleet_section_is_optional_but_validated_when_present() {
        // absent: schema-valid (v1-shaped reports upgrade by version bump)
        let mut r = sample_report();
        BenchReport::validate(&r.to_json()).expect("no fleet section needed");
        assert!(r.to_json().get("fleet").is_none(), "empty fleet not serialized");
        // present and well-formed
        r.push_fleet(FleetCell {
            routing: "affinity".into(),
            replicas: 3,
            sustained_batch: 9.5,
            header_hits: 8,
            header_misses: 4,
            hit_rate: 8.0 / 12.0,
            preemptions: 1,
            completed: 12,
        });
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        BenchReport::validate(&j).expect("fleet cell is schema-valid");
        let cells = j.get("fleet").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(cells[0].str_at("routing").unwrap(), "affinity");
        assert_eq!(cells[0].usize_at("replicas").unwrap(), 3);
        // corrupt cells are rejected: hit_rate out of range, replicas 0,
        // missing counter
        let mut bad = r.clone();
        bad.fleet[0].hit_rate = 1.5;
        assert!(BenchReport::validate(&bad.to_json()).is_err());
        let mut bad = r.clone();
        bad.fleet[0].replicas = 0;
        assert!(BenchReport::validate(&bad.to_json()).is_err());
        let bad = r#"{"schema_version":3,"bench":"pool","samples":1,
            "results":[],"fleet":[{"routing":"rr"}]}"#;
        assert!(BenchReport::validate(&Json::parse(bad).unwrap()).is_err());
    }

    #[test]
    fn recurrence_section_is_optional_but_validated_when_present() {
        let mut r = sample_report();
        BenchReport::validate(&r.to_json()).expect("no recurrence section needed");
        assert!(r.to_json().get("recurrence").is_none(), "empty not serialized");
        let mut mri = StreamingHistogram::counts();
        let mut ttp = StreamingHistogram::counts();
        for x in [4.0, 12.0, 40.0] {
            mri.observe(x);
            ttp.observe(x);
        }
        r.push_recurrence(RecurrenceCell {
            policy: "lazy".into(),
            scenario: "tier".into(),
            passes: 7,
            decisions: 120,
            mri: Quantiles::from_hist(&mri),
            time_to_promotion_steps: Quantiles::from_hist(&ttp),
            postmortem: [1, 1, 1, 0],
        });
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        BenchReport::validate(&j).expect("recurrence cell is schema-valid");
        let cells = j.get("recurrence").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(cells[0].str_at("policy").unwrap(), "lazy");
        assert!(cells[0].get("postmortem").unwrap().get("le8").is_some());
        // a cell missing the postmortem labels is rejected (corrupt the
        // serialized form so the failure is recurrence's, not results')
        let good = r.to_json().to_string();
        let bad = good.replace(r#""le32""#, r#""oops""#);
        let err = BenchReport::validate(&Json::parse(&bad).unwrap()).unwrap_err();
        assert!(err.contains("postmortem"), "{err}");
    }

    #[test]
    fn save_writes_schema_valid_file() {
        let dir = std::env::temp_dir().join("lazyeviction_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_pool.json");
        sample_report().save(&path).unwrap();
        let back = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        BenchReport::validate(&back).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
