//! Bench harness: timing (criterion is not in the offline crate set),
//! table rendering matching the paper's rows, and results persistence.

pub mod report;
pub mod simgrid;
pub mod table;
pub mod timing;

use std::path::Path;

use crate::util::json::Json;

/// Write a bench result JSON under results/ and echo where it went.
pub fn save_results(name: &str, payload: Json) -> std::io::Result<()> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, payload.to_pretty())?;
    eprintln!("[results] wrote {}", path.display());
    Ok(())
}

/// Locate the artifacts directory: $LAZYEVICTION_ARTIFACTS or ./artifacts.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("LAZYEVICTION_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

/// True when the AOT artifacts exist (engine benches need them; simulator
/// benches do not).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}
