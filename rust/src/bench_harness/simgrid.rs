//! Shared grid runner for the simulator-driven tables/figures: one *cell* =
//! (policy, model, dataset, compression ratio r) → accuracy/fidelity over N
//! replayed samples. The paper's W rule (80th-pct MRI) is applied per
//! (dataset, model) exactly as §4 prescribes, unless overridden.

use crate::eviction::{self, PolicyParams, ScoreConfig};
use crate::sim::{accuracy_over, replay, AccuracyModel, ReplayConfig, ReplayResult};
use crate::trace::workload::{dataset_index, dataset_profile, model_profile};
use crate::trace::{generator, mri};

#[derive(Clone, Debug)]
pub struct CellSpec {
    pub policy: String,
    pub model: String,
    pub dataset: String,
    /// KV compression ratio r = budget / full-length.
    pub r: f64,
    pub n_samples: usize,
    pub seed: u64,
    /// Override W (None ⇒ paper's 80th-pct-MRI rule).
    pub window: Option<usize>,
    /// Override score config (Table 4/5 ablations).
    pub score: Option<ScoreConfig>,
    /// Override alpha (Table 10).
    pub alpha: Option<f32>,
}

impl CellSpec {
    pub fn new(policy: &str, model: &str, dataset: &str, r: f64) -> CellSpec {
        CellSpec {
            policy: policy.into(),
            model: model.into(),
            dataset: dataset.into(),
            r,
            n_samples: 24,
            seed: 0,
            window: None,
            score: None,
            alpha: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CellResult {
    pub spec: CellSpec,
    pub accuracy: f64,
    pub base_acc: f64,
    pub fidelity: f64,
    pub miss_rate: f64,
    pub window: usize,
    pub mean_evictions: f64,
    pub results: Vec<ReplayResult>,
}

/// Paper §4 W rule for a (dataset, model) pair, measured on a few traces
/// ("offline analysis on ~1% of samples").
pub fn paper_window(dataset: &str, model: &str) -> usize {
    let wp = dataset_profile(dataset);
    let mp = model_profile(model);
    let traces: Vec<_> = (0..4).map(|s| generator::generate(&wp, &mp, 9_000 + s)).collect();
    mri::suggest_window(&traces, mp.alpha, 0.8).clamp(4, 256)
}

/// Run one grid cell.
pub fn run_cell(spec: &CellSpec) -> CellResult {
    let wp = dataset_profile(&spec.dataset);
    let mp = model_profile(&spec.model);
    let window = spec
        .window
        .unwrap_or_else(|| paper_window(&spec.dataset, &spec.model));
    let mut params = PolicyParams {
        window,
        recent: window,
        ..PolicyParams::default()
    };
    if let Some(sc) = spec.score {
        params.score = sc;
    }
    let alpha = spec.alpha.unwrap_or(mp.alpha);
    let policy = eviction::build(&spec.policy, &params).expect("policy spec");

    let mut results = Vec::with_capacity(spec.n_samples);
    for i in 0..spec.n_samples {
        let tr = generator::generate(&wp, &mp, spec.seed * 10_000 + i as u64);
        let budget = ((tr.total_len as f64 * spec.r) as usize).max(window + 8);
        let cfg = ReplayConfig::new(budget, window + wp.locality + 2, alpha);
        results.push(replay(&tr, policy.as_ref(), cfg));
    }
    let base = mp.base_acc[dataset_index(&spec.dataset)];
    let accuracy = accuracy_over(&AccuracyModel::default(), base, &results);
    let fidelity = crate::sim::accuracy::mean_fidelity(&results);
    let miss: f64 =
        results.iter().map(|r| r.miss_rate()).sum::<f64>() / results.len().max(1) as f64;
    let evs: f64 =
        results.iter().map(|r| r.evictions as f64).sum::<f64>() / results.len().max(1) as f64;
    CellResult {
        spec: spec.clone(),
        accuracy,
        base_acc: base,
        fidelity,
        miss_rate: miss,
        window,
        mean_evictions: evs,
        results,
    }
}

/// Samples-per-cell default, overridable via LAZYEVICTION_BENCH_SAMPLES
/// (benches honour this so CI can run quick passes).
pub fn samples_per_cell() -> usize {
    std::env::var("LAZYEVICTION_BENCH_SAMPLES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_runs_and_orders_policies() {
        let mut spec = CellSpec::new("lazy", "ds-llama-8b", "gsm8k", 0.5);
        spec.n_samples = 6;
        let lazy = run_cell(&spec);
        let mut spec_t = spec.clone();
        spec_t.policy = "tova".into();
        let tova = run_cell(&spec_t);
        let mut spec_f = spec.clone();
        spec_f.policy = "full".into();
        let full = run_cell(&spec_f);
        assert!((full.accuracy - full.base_acc).abs() < 1e-9);
        assert!(lazy.accuracy <= full.accuracy + 1e-9);
        // distributional claim with 6 samples: allow a small tolerance
        assert!(
            lazy.accuracy >= tova.accuracy - 2.0,
            "lazy {} far below tova {}",
            lazy.accuracy,
            tova.accuracy
        );
    }

    #[test]
    fn paper_window_in_sane_range() {
        let w = paper_window("gsm8k", "ds-llama-8b");
        assert!((4..=256).contains(&w), "{w}");
    }
}
