//! Minimal benchmarking: warmup + timed iterations + percentile summary.

use std::time::Instant;

use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    /// Per-iteration seconds.
    pub samples: Vec<f64>,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>6} iters  mean {:>9.3}ms  p50 {:>9.3}ms  p99 {:>9.3}ms",
            self.name,
            self.iters,
            self.summary.mean * 1e3,
            self.summary.p50 * 1e3,
            self.summary.p99 * 1e3
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` unrecorded runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let summary = Summary::of(&samples);
    BenchResult {
        name: name.to_string(),
        iters,
        samples,
        summary,
    }
}

/// Time a single run of `f`, returning (result, seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let r = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.iters, 10);
        assert_eq!(r.samples.len(), 10);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, s) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
