//! Request span tracing: bounded, allocation-cheap open/close spans with
//! parent/child links, recorded fleet-wide into one [`SpanRecorder`].
//!
//! Every request gets a *trace id* at the listener — the id of its root
//! `request` span. Each lifecycle stage (router decision, queue wait,
//! prefill vs prefix-skip, decode windows, eviction passes, demote /
//! promote / swap round-trips, preemption round-trips, orphan re-routes)
//! opens a child span carrying that trace id, so an orphaned request's
//! spans stitch into one tree even when two replicas (and the server
//! thread) recorded different stages. Exported three ways:
//!
//! * `GET /trace/spans[?req=N][&limit=N]` — closed spans as nested trees;
//! * the `--trace-out` JSONL sink — v2 `span_open` / `span_close` lines
//!   interleaved with the v1 flight events (see docs/observability.md);
//! * the metrics registry — per-name duration histograms under
//!   `lazyeviction_span_<name>_ms`.
//!
//! Memory is bounded by construction: the closed-span ring and the
//! open-span list both cap out and count drops, and a span is two fixed
//! structs — no per-span allocation beyond the ring slot.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

use super::hist::StreamingHistogram;
use super::registry::Registry;

/// Span names, in rough lifecycle order. `&'static str` so opening a span
/// never allocates for the name. The metric family publishes each as
/// `lazyeviction_span_<name>_ms` (see [`metric_name`]); lazylint's parity
/// rule scans this module's constants, so every name added here must also
/// be documented in docs/observability.md §Spans.
pub mod name {
    /// Root span: listener accept → terminal reply (or cancel/kill).
    pub const REQUEST: &str = "request";
    /// One router decision; `note` = the route reason (affinity/pressure/
    /// rr/rebalanced), `detail` = chosen replica.
    pub const ROUTE: &str = "route";
    /// Scheduler-queue residency on one replica; `note` = SLO class.
    pub const QUEUE_WAIT: &str = "queue_wait";
    /// Prefill execution; `detail` = prompt tokens fed.
    pub const PREFILL: &str = "prefill";
    /// Prefill skipped on a full-prompt prefix hit; `detail` = tokens
    /// premapped from the cache.
    pub const PREFIX_SKIP: &str = "prefix_skip";
    /// A window of consecutive decode steps for one row; `detail` = steps
    /// aggregated (bounded by [`super::DECODE_WINDOW_STEPS`]).
    pub const DECODE_WINDOW: &str = "decode_window";
    /// One eviction pass over a row; `detail` = tokens evicted.
    pub const EVICT_PASS: &str = "evict_pass";
    /// Evicted blocks parked into the host tier; `detail` = tokens parked.
    pub const DEMOTE: &str = "demote";
    /// Parked tokens promoted back on recurrence; `detail` = tokens.
    pub const PROMOTE: &str = "promote";
    /// Whole-table device→host swap (swap-mode preemption).
    pub const SWAP_OUT: &str = "swap_out";
    /// Host→device copy-back on a swap-mode resume; `detail` = bytes.
    pub const SWAP_IN: &str = "swap_in";
    /// Preemption round-trip: victim snapshot → re-queue → re-admission.
    pub const PREEMPT: &str = "preempt";
    /// Orphan re-route hop: a dead replica's queued request re-submitted
    /// through the router; `detail` = the replica that died.
    pub const REROUTE: &str = "reroute";
}

/// Every span name, in lifecycle order — drives the per-name duration
/// histograms and keeps `metric_name` exhaustive.
pub const ALL_NAMES: &[&str] = &[
    name::REQUEST,
    name::ROUTE,
    name::QUEUE_WAIT,
    name::PREFILL,
    name::PREFIX_SKIP,
    name::DECODE_WINDOW,
    name::EVICT_PASS,
    name::DEMOTE,
    name::PROMOTE,
    name::SWAP_OUT,
    name::SWAP_IN,
    name::PREEMPT,
    name::REROUTE,
];

/// Decode steps aggregated into one `decode_window` span (per-step spans
/// would swamp the ring on long reasoning outputs).
pub const DECODE_WINDOW_STEPS: u32 = 32;

/// Metric-name prefix for span duration histograms (trailing `_` marks a
/// prefix constant, like `POOL_PREFIX`).
pub const SPAN_METRIC_PREFIX: &str = "lazyeviction_span_";

/// Registry key for one span name's duration histogram:
/// `lazyeviction_span_<name>_ms`.
pub fn metric_name(span_name: &str) -> String {
    format!("{SPAN_METRIC_PREFIX}{span_name}_ms")
}

/// The (trace, parent) pair a span is opened under. Copied across channel
/// hops (server → actor → engine) so child spans link back without any
/// shared state. `trace == 0` means "no tracing" — every recording helper
/// treats such a context as a no-op, which is how the whole subsystem
/// stays free when telemetry is off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanContext {
    /// Root span id of the request's trace (0 = tracing disabled).
    pub trace: u64,
    /// Parent span id (0 = this span is the root).
    pub parent: u64,
}

impl SpanContext {
    /// Context for children of span `id` inside trace `trace`.
    pub fn child_of(trace: u64, id: u64) -> SpanContext {
        SpanContext { trace, parent: id }
    }

    pub fn is_off(&self) -> bool {
        self.trace == 0
    }
}

/// One span. While open, `dur_ms` is negative (sentinel); closing fills it
/// and moves the span into the closed ring.
#[derive(Clone, Debug)]
pub struct Span {
    /// Globally unique (per recorder) span id; ids start at 1 so 0 can
    /// mean "none" in contexts and wire shapes.
    pub id: u64,
    pub trace: u64,
    pub parent: u64,
    pub req: u64,
    pub name: &'static str,
    /// Replica that recorded the span; `None` for server-side spans.
    pub replica: Option<usize>,
    /// Seconds since the recorder epoch at open.
    pub start_s: f64,
    /// Wall duration; negative while the span is still open.
    pub dur_ms: f64,
    /// Event-specific scalar, documented per name in [`name`].
    pub detail: f64,
    /// Free-form qualifier (route reason, SLO class, teardown cause).
    pub note: &'static str,
}

impl Span {
    /// Flat JSON shape shared by the tree endpoint and the JSONL lines.
    fn fields(&self) -> Json {
        let mut j = Json::obj()
            .set("span", self.id as f64)
            .set("trace", self.trace as f64)
            .set("parent", self.parent as f64)
            .set("req", self.req as f64)
            .set("name", self.name)
            .set("t_s", self.start_s)
            .set("detail", self.detail);
        if let Some(r) = self.replica {
            j = j.set("replica", r);
        }
        if !self.note.is_empty() {
            j = j.set("note", self.note);
        }
        j
    }

    /// The v2 JSONL `span_open` line.
    fn open_line(&self) -> Json {
        self.fields().set("v", 2usize).set("kind", "span_open")
    }

    /// The v2 JSONL `span_close` line (`t_s` stays the open time; the
    /// close time is `t_s + dur_ms / 1e3`).
    fn close_line(&self) -> Json {
        self.fields()
            .set("v", 2usize)
            .set("kind", "span_close")
            .set("dur_ms", self.dur_ms)
    }
}

/// Bounded open-list + closed-ring span store, plus per-name duration
/// histograms. One per [`super::Telemetry`], shared by the whole fleet so
/// span ids (and therefore trace ids) are globally unique.
pub struct SpanRecorder {
    epoch: Instant,
    next_id: u64,
    cap: usize,
    /// Spans opened but not yet closed. Linear scan on close — the open
    /// set is small (≤ active requests × a few stages) and bounded.
    open: Vec<Span>,
    /// Closed spans, oldest first.
    ring: VecDeque<Span>,
    /// Per-name duration histograms, keyed by [`ALL_NAMES`] order.
    hists: Vec<StreamingHistogram>,
    /// Closed spans pushed out of the ring + open spans force-dropped.
    pub dropped: u64,
}

impl SpanRecorder {
    pub const DEFAULT_CAP: usize = 4096;
    /// Open spans are far fewer than closed ones; a leak (opens that are
    /// never closed) hits this cap and gets force-dropped, not hoarded.
    const OPEN_CAP: usize = 1024;

    pub fn new(cap: usize) -> SpanRecorder {
        SpanRecorder {
            epoch: Instant::now(),
            next_id: 1,
            cap: cap.max(1),
            open: Vec::new(),
            ring: VecDeque::with_capacity(cap.max(1).min(1024)),
            hists: ALL_NAMES
                .iter()
                .map(|_| StreamingHistogram::latency_ms())
                .collect(),
            dropped: 0,
        }
    }

    /// Open a span. With `ctx.trace == 0` the new span becomes its own
    /// trace root (listener behavior); otherwise it joins `ctx`'s trace
    /// under `ctx.parent`. Returns the span id and the JSONL `span_open`
    /// line for the caller to forward to the trace sink.
    pub fn open(
        &mut self,
        req: u64,
        name: &'static str,
        ctx: SpanContext,
        replica: Option<usize>,
        detail: f64,
        note: &'static str,
    ) -> (u64, Json) {
        let id = self.next_id;
        self.next_id += 1;
        let span = Span {
            id,
            trace: if ctx.trace == 0 { id } else { ctx.trace },
            parent: ctx.parent,
            req,
            name,
            replica,
            start_s: self.epoch.elapsed().as_secs_f64(),
            dur_ms: -1.0,
            detail,
            note,
        };
        let line = span.open_line();
        if self.open.len() >= Self::OPEN_CAP {
            self.open.remove(0);
            self.dropped += 1;
        }
        self.open.push(span);
        (id, line)
    }

    /// Close span `id`, overriding `detail`/`note` when given. Returns the
    /// JSONL `span_close` line, or `None` for id 0 / an unknown id (spans
    /// force-dropped under pressure close as no-ops, never panics).
    pub fn close(
        &mut self,
        id: u64,
        detail: Option<f64>,
        note: Option<&'static str>,
    ) -> Option<Json> {
        if id == 0 {
            return None;
        }
        let at = self.open.iter().rposition(|s| s.id == id)?;
        let mut span = self.open.swap_remove(at);
        span.dur_ms = ((self.epoch.elapsed().as_secs_f64() - span.start_s) * 1e3).max(0.0);
        if let Some(d) = detail {
            span.detail = d;
        }
        if let Some(n) = note {
            span.note = n;
        }
        if let Some(slot) = ALL_NAMES.iter().position(|&n| n == span.name) {
            if let Some(h) = self.hists.get_mut(slot) {
                h.observe(span.dur_ms);
            }
        }
        let line = span.close_line();
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(span);
        Some(line)
    }

    /// Closed spans (optionally for one request), most recent `limit`
    /// kept, returned oldest-first.
    pub fn spans_for(&self, req: Option<u64>, limit: usize) -> Vec<Span> {
        let mut out: Vec<Span> = self
            .ring
            .iter()
            .filter(|s| req.map_or(true, |r| s.req == r))
            .cloned()
            .collect();
        if out.len() > limit {
            out.drain(..out.len() - limit);
        }
        out
    }

    pub fn open_len(&self) -> usize {
        self.open.len()
    }

    pub fn closed_len(&self) -> usize {
        self.ring.len()
    }

    /// Nested span trees for the `/trace/spans` endpoint: roots are spans
    /// whose parent is 0 or fell out of the selected set, children sorted
    /// by start time. `{"spans": [tree, …], "dropped": n}`.
    pub fn trees_json(&self, req: Option<u64>, limit: usize) -> Json {
        let spans = self.spans_for(req, limit);
        let present: Vec<u64> = spans.iter().map(|s| s.id).collect();
        let mut roots: Vec<Json> = Vec::new();
        for s in &spans {
            if s.parent == 0 || !present.contains(&s.parent) {
                roots.push(tree_node(s, &spans));
            }
        }
        Json::obj()
            .set("spans", roots)
            .set("dropped", self.dropped as f64)
    }

    /// Publish every non-empty per-name duration histogram into the
    /// registry as `lazyeviction_span_<name>_ms`.
    pub fn publish(&self, registry: &Registry) {
        for (slot, span_name) in ALL_NAMES.iter().enumerate() {
            if let Some(h) = self.hists.get(slot) {
                if h.n() > 0 {
                    registry.set_histogram(&metric_name(span_name), h);
                }
            }
        }
    }
}

/// One node of the `/trace/spans` tree: the span's flat fields plus its
/// (start-ordered) children. Recursion depth is bounded by the lifecycle
/// (request → stage → sub-stage, ≤ 4 in practice); a malformed cycle
/// cannot occur because children always have larger ids than parents.
fn tree_node(s: &Span, spans: &[Span]) -> Json {
    let mut children: Vec<&Span> = spans.iter().filter(|c| c.parent == s.id).collect();
    children.sort_by(|a, b| {
        a.start_s
            .partial_cmp(&b.start_s)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.id.cmp(&b.id))
    });
    let kids: Vec<Json> = children.iter().map(|c| tree_node(c, spans)).collect();
    s.fields().set("dur_ms", s.dur_ms).set("children", kids)
}

/// Counts from one pass of [`validate_span_file`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpanFileStats {
    /// v2 `span_open` lines.
    pub opens: u64,
    /// v2 `span_close` lines.
    pub closes: u64,
    /// v1 flight-event lines interleaved in the same file.
    pub flight_events: u64,
}

/// Schema check for a `--trace-out` file carrying v2 span lines: every
/// `span_close` references a previously opened span id, every nonzero
/// parent id resolves to an already-opened span, and no close carries a
/// negative duration. Flight-event lines (no `kind` key) pass through
/// uncounted against the span rules. Returns the first violation.
pub fn validate_span_file(path: &Path) -> Result<SpanFileStats, String> {
    let text =
        std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
    validate_span_lines(&text)
}

/// [`validate_span_file`] over in-memory JSONL text (unit-testable).
pub fn validate_span_lines(text: &str) -> Result<SpanFileStats, String> {
    let mut stats = SpanFileStats::default();
    let mut seen: Vec<u64> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| format!("line {}: {e}", ln + 1))?;
        let kind = match j.get("kind").and_then(|k| k.as_str()) {
            Some(k) => k.to_string(),
            None => {
                // a v1 flight event — carries `event`, not `kind`
                stats.flight_events += 1;
                continue;
            }
        };
        let id = j
            .get("span")
            .and_then(|v| v.as_f64())
            .ok_or(format!("line {}: {kind} without span id", ln + 1))? as u64;
        let parent = j.get("parent").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        match kind.as_str() {
            "span_open" => {
                if parent != 0 && !seen.contains(&parent) {
                    return Err(format!(
                        "line {}: span {id} opens under unknown parent {parent}",
                        ln + 1
                    ));
                }
                seen.push(id);
                stats.opens += 1;
            }
            "span_close" => {
                if !seen.contains(&id) {
                    return Err(format!(
                        "line {}: span_close for never-opened span {id}",
                        ln + 1
                    ));
                }
                let dur = j
                    .get("dur_ms")
                    .and_then(|v| v.as_f64())
                    .ok_or(format!("line {}: span_close without dur_ms", ln + 1))?;
                if dur < 0.0 {
                    return Err(format!(
                        "line {}: span {id} closed with negative duration {dur}",
                        ln + 1
                    ));
                }
                stats.closes += 1;
            }
            other => return Err(format!("line {}: unknown kind '{other}'", ln + 1)),
        }
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_close_links_and_measures() {
        let mut r = SpanRecorder::new(16);
        let (root, line) = r.open(7, name::REQUEST, SpanContext::default(), None, 0.0, "");
        assert_eq!(line.str_at("kind").unwrap(), "span_open");
        assert_eq!(line.f64_at("trace").unwrap() as u64, root);
        let (child, _) = r.open(
            7,
            name::QUEUE_WAIT,
            SpanContext::child_of(root, root),
            Some(1),
            0.0,
            "standard",
        );
        let close = r.close(child, None, None).expect("child closes");
        assert!(close.f64_at("dur_ms").unwrap() >= 0.0);
        assert_eq!(close.f64_at("parent").unwrap() as u64, root);
        r.close(root, Some(42.0), Some("finish")).expect("root closes");
        let spans = r.spans_for(Some(7), usize::MAX);
        assert_eq!(spans.len(), 2);
        let root_span = spans.iter().find(|s| s.id == root).unwrap();
        assert_eq!(root_span.detail, 42.0);
        assert_eq!(root_span.note, "finish");
        assert_eq!(root_span.trace, root);
    }

    #[test]
    fn close_of_unknown_or_zero_id_is_a_noop() {
        let mut r = SpanRecorder::new(4);
        assert!(r.close(0, None, None).is_none());
        assert!(r.close(99, None, None).is_none());
        assert_eq!(r.closed_len(), 0);
    }

    #[test]
    fn ring_and_open_list_are_bounded() {
        let mut r = SpanRecorder::new(2);
        for i in 0..4 {
            let (id, _) = r.open(i, name::ROUTE, SpanContext::default(), None, 0.0, "");
            let _ = r.close(id, None, None);
        }
        assert_eq!(r.closed_len(), 2);
        assert_eq!(r.dropped, 2);
        // open-list cap: force-dropped opens close as no-ops later
        let mut r = SpanRecorder::new(4);
        let mut first = 0;
        for i in 0..(SpanRecorder::OPEN_CAP + 1) as u64 {
            let (id, _) = r.open(i, name::ROUTE, SpanContext::default(), None, 0.0, "");
            if i == 0 {
                first = id;
            }
        }
        assert_eq!(r.open_len(), SpanRecorder::OPEN_CAP);
        assert!(r.close(first, None, None).is_none(), "dropped span is gone");
    }

    #[test]
    fn trees_nest_children_under_parents() {
        let mut r = SpanRecorder::new(64);
        let (root, _) = r.open(3, name::REQUEST, SpanContext::default(), None, 0.0, "");
        let ctx = SpanContext::child_of(root, root);
        let (q, _) = r.open(3, name::QUEUE_WAIT, ctx, Some(0), 0.0, "");
        let _ = r.close(q, None, None);
        let (d, _) = r.open(3, name::DECODE_WINDOW, ctx, Some(0), 8.0, "");
        let _ = r.close(d, None, None);
        // a different request's span must not leak into req=3 trees
        let (other, _) = r.open(4, name::REQUEST, SpanContext::default(), None, 0.0, "");
        let _ = r.close(other, None, None);
        let _ = r.close(root, None, None);
        let trees = r.trees_json(Some(3), usize::MAX);
        let roots = trees.get("spans").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(roots.len(), 1, "{trees:?}");
        assert_eq!(roots[0].str_at("name").unwrap(), "request");
        let kids = roots[0].get("children").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(kids.len(), 2);
        assert_eq!(kids[0].str_at("name").unwrap(), "queue_wait");
        assert_eq!(kids[1].str_at("name").unwrap(), "decode_window");
        assert_eq!(kids[1].f64_at("detail").unwrap(), 8.0);
    }

    #[test]
    fn orphaned_children_surface_as_roots() {
        // parent fell out of the ring (or lives on another page): the
        // child still renders, as a root of its own subtree
        let mut r = SpanRecorder::new(64);
        let ctx = SpanContext {
            trace: 1000,
            parent: 999,
        };
        let (c, _) = r.open(5, name::PREFILL, ctx, Some(2), 12.0, "");
        let _ = r.close(c, None, None);
        let trees = r.trees_json(Some(5), usize::MAX);
        let roots = trees.get("spans").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(roots.len(), 1);
        assert_eq!(roots[0].f64_at("trace").unwrap() as u64, 1000);
    }

    #[test]
    fn limit_keeps_most_recent_spans() {
        let mut r = SpanRecorder::new(64);
        for i in 0..10u64 {
            let (id, _) = r.open(i, name::ROUTE, SpanContext::default(), None, i as f64, "");
            let _ = r.close(id, None, None);
        }
        let spans = r.spans_for(None, 3);
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].detail, 7.0, "oldest of the most recent 3");
        assert_eq!(spans[2].detail, 9.0);
    }

    #[test]
    fn publish_exports_span_histograms() {
        let mut r = SpanRecorder::new(16);
        let (id, _) = r.open(1, name::EVICT_PASS, SpanContext::default(), None, 0.0, "");
        let _ = r.close(id, None, None);
        let reg = Registry::new();
        r.publish(&reg);
        let text = reg.render_prometheus();
        assert!(
            text.contains("lazyeviction_span_evict_pass_ms_count"),
            "{text}"
        );
        assert!(
            !text.contains("lazyeviction_span_route_ms"),
            "empty hists stay unpublished: {text}"
        );
    }

    #[test]
    fn validator_accepts_a_recorded_stream_and_rejects_corruption() {
        let mut r = SpanRecorder::new(64);
        let mut lines = String::new();
        let (root, l) = r.open(9, name::REQUEST, SpanContext::default(), None, 0.0, "");
        lines.push_str(&(l.to_string() + "\n"));
        let (c, l) = r.open(9, name::PREFILL, SpanContext::child_of(root, root), None, 4.0, "");
        lines.push_str(&(l.to_string() + "\n"));
        lines.push_str(&(r.close(c, None, None).unwrap().to_string() + "\n"));
        // a v1 flight line interleaves fine
        lines.push_str("{\"seq\":0,\"t_s\":0.1,\"req\":9,\"event\":\"decode\",\"step\":1,\"live\":1,\"detail\":0}\n");
        lines.push_str(&(r.close(root, None, None).unwrap().to_string() + "\n"));
        let stats = validate_span_lines(&lines).expect("valid stream");
        assert_eq!(stats.opens, 2);
        assert_eq!(stats.closes, 2);
        assert_eq!(stats.flight_events, 1);
        // close without an open
        let bad = "{\"v\":2,\"kind\":\"span_close\",\"span\":5,\"dur_ms\":1.0}\n";
        assert!(validate_span_lines(bad).is_err());
        // unresolved parent
        let bad = "{\"v\":2,\"kind\":\"span_open\",\"span\":5,\"parent\":4}\n";
        assert!(validate_span_lines(bad).is_err());
        // negative duration
        let bad = "{\"v\":2,\"kind\":\"span_open\",\"span\":5,\"parent\":0}\n\
                   {\"v\":2,\"kind\":\"span_close\",\"span\":5,\"dur_ms\":-1.0}\n";
        assert!(validate_span_lines(bad).is_err());
    }

    #[test]
    fn metric_names_cover_all_span_names() {
        for n in ALL_NAMES {
            let m = metric_name(n);
            assert!(m.starts_with(SPAN_METRIC_PREFIX));
            assert!(m.ends_with("_ms"));
        }
        assert_eq!(ALL_NAMES.len(), 13);
    }
}
