//! Bounded-memory streaming histograms.
//!
//! The serve loop used to append every step/prefill latency to a `Vec<f64>`,
//! which grows without bound under a long-running server. A
//! [`StreamingHistogram`] replaces that: fixed bucket bounds chosen at
//! construction, O(buckets) memory forever, exact `n`/`sum`/`min`/`max`
//! (so throughput and mean-latency math is unchanged), and
//! linearly-interpolated quantiles whose error is bounded by bucket width.
//!
//! Bucket bounds are *upper* bounds (Prometheus `le` semantics): a sample
//! lands in the first bucket whose bound is `>= x`; anything above the last
//! bound lands in the implicit `+Inf` overflow bucket.

use crate::util::stats::Summary;

/// Default latency ladder in milliseconds: ~2.5x geometric steps spanning
/// 10µs sim steps through multi-second real-model prefills.
pub const LATENCY_MS_BOUNDS: &[f64] = &[
    0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1000.0, 2500.0, 5000.0, 10000.0,
];

/// Default ladder for live-set sizes (tokens) and other small counts.
pub const COUNT_BOUNDS: &[f64] = &[
    1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0,
    16384.0,
];

/// Fixed-bucket streaming histogram with exact moments.
#[derive(Clone, Debug)]
pub struct StreamingHistogram {
    bounds: &'static [f64],
    /// One count per bound, plus a trailing `+Inf` overflow slot.
    counts: Vec<u64>,
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl StreamingHistogram {
    pub fn new(bounds: &'static [f64]) -> StreamingHistogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds ascending");
        StreamingHistogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            n: 0,
            sum: 0.0,
            sum_sq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Histogram over the default millisecond latency ladder.
    pub fn latency_ms() -> StreamingHistogram {
        StreamingHistogram::new(LATENCY_MS_BOUNDS)
    }

    /// Histogram over the default token/size-count ladder.
    pub fn counts() -> StreamingHistogram {
        StreamingHistogram::new(COUNT_BOUNDS)
    }

    pub fn observe(&mut self, x: f64) {
        if !x.is_finite() {
            return;
        }
        let idx = self
            .bounds
            .iter()
            .position(|&b| x <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn n(&self) -> u64 {
        self.n
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// `(upper_bound, cumulative_count)` pairs, ending with `(+Inf, n)` —
    /// exactly the shape of Prometheus `_bucket{le=...}` series.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut acc = 0u64;
        let mut out = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            acc += c;
            let le = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            out.push((le, acc));
        }
        out
    }

    /// Quantile estimate (`q` in [0,1]) by linear interpolation within the
    /// bucket holding the target rank, clamped to the exact observed
    /// [min, max] so single-bucket distributions do not smear.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        let rank = q * (self.n as f64 - 1.0) + 1.0; // 1-based fractional rank
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let next = acc + c;
            if rank <= next as f64 {
                let lo = if i == 0 { self.min.min(self.bounds[0]) } else { self.bounds[i - 1] };
                let hi = self.bounds.get(i).copied().unwrap_or(self.max);
                let frac = (rank - acc as f64) / c as f64;
                let v = lo + (hi - lo) * frac.clamp(0.0, 1.0);
                return v.clamp(self.min, self.max);
            }
            acc = next;
        }
        self.max
    }

    /// Summary matching `util::stats::Summary`: n/mean/std/min/max exact,
    /// percentiles interpolated from buckets.
    pub fn summary(&self) -> Summary {
        if self.n == 0 {
            return Summary::default();
        }
        let mean = self.mean();
        let var = (self.sum_sq / self.n as f64 - mean * mean).max(0.0);
        Summary {
            n: self.n as usize,
            mean,
            std: var.sqrt(),
            min: self.min,
            p50: self.quantile(0.50),
            p90: self.quantile(0.90),
            p99: self.quantile(0.99),
            max: self.max,
        }
    }

    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.n = 0;
        self.sum = 0.0;
        self.sum_sq = 0.0;
        self.min = f64::INFINITY;
        self.max = f64::NEG_INFINITY;
    }
}

impl Default for StreamingHistogram {
    fn default() -> Self {
        StreamingHistogram::latency_ms()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_are_exact() {
        let mut h = StreamingHistogram::latency_ms();
        for x in [1.0, 2.0, 3.0, 4.0] {
            h.observe(x);
        }
        assert_eq!(h.n(), 4);
        assert!((h.sum() - 10.0).abs() < 1e-12);
        assert!((h.mean() - 2.5).abs() < 1e-12);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 4.0);
    }

    #[test]
    fn quantiles_bounded_by_bucket() {
        let mut h = StreamingHistogram::latency_ms();
        for _ in 0..1000 {
            h.observe(3.0); // all in the (2.5, 5.0] bucket
        }
        let p50 = h.quantile(0.5);
        // clamped to exact min/max: a point mass reports itself exactly
        assert!((p50 - 3.0).abs() < 1e-12, "{p50}");
        assert_eq!(h.quantile(0.99), 3.0);
    }

    #[test]
    fn quantiles_track_spread_samples() {
        let mut h = StreamingHistogram::latency_ms();
        for i in 1..=100 {
            h.observe(i as f64); // 1..100 ms
        }
        let p50 = h.quantile(0.5);
        let p90 = h.quantile(0.9);
        let p99 = h.quantile(0.99);
        assert!(p50 >= h.min() && p99 <= h.max());
        assert!(p50 < p90 && p90 <= p99, "p50={p50} p90={p90} p99={p99}");
        // bucket-width error bound: p50's true value is 50.5, inside (25,50]
        // or (50,100] depending on rank — allow one bucket of slack
        assert!((10.0..=100.0).contains(&p50), "{p50}");
    }

    #[test]
    fn overflow_bucket_catches_huge_samples() {
        let mut h = StreamingHistogram::latency_ms();
        h.observe(1e6);
        let buckets = h.cumulative_buckets();
        let (le, c) = *buckets.last().unwrap();
        assert!(le.is_infinite());
        assert_eq!(c, 1);
        assert_eq!(h.quantile(0.5), 1e6); // clamped to exact max
    }

    #[test]
    fn empty_is_safe() {
        let h = StreamingHistogram::latency_ms();
        assert_eq!(h.n(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        let s = h.summary();
        assert_eq!(s.n, 0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn cumulative_buckets_are_monotone() {
        let mut h = StreamingHistogram::counts();
        for i in 0..50 {
            h.observe(i as f64 * 7.0);
        }
        let b = h.cumulative_buckets();
        for w in b.windows(2) {
            assert!(w[0].1 <= w[1].1);
            assert!(w[0].0 < w[1].0);
        }
        assert_eq!(b.last().unwrap().1, 50);
    }

    #[test]
    fn quantile_empty_is_zero_for_any_q() {
        let h = StreamingHistogram::latency_ms();
        for q in [0.0, 0.5, 1.0, -3.0, 7.0] {
            assert_eq!(h.quantile(q), 0.0, "empty histogram, q={q}");
        }
    }

    #[test]
    fn quantile_single_observation_reports_itself() {
        let mut h = StreamingHistogram::latency_ms();
        h.observe(0.42);
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 0.42, "single sample, q={q}");
        }
    }

    #[test]
    fn quantile_all_in_overflow_bucket_clamps_to_observed_range() {
        // every sample above the last bound lands in the +Inf bucket, whose
        // interpolation upper edge is the observed max — never infinity
        let mut h = StreamingHistogram::counts();
        for x in [20000.0, 30000.0, 40000.0] {
            h.observe(x);
        }
        for q in [0.0, 0.5, 1.0] {
            let v = h.quantile(q);
            assert!(v.is_finite(), "q={q} leaked +Inf: {v}");
            assert!((20000.0..=40000.0).contains(&v), "q={q} out of range: {v}");
        }
        assert_eq!(h.quantile(1.0), 40000.0);
    }

    #[test]
    fn quantile_clamps_out_of_range_q() {
        let mut h = StreamingHistogram::latency_ms();
        for i in 1..=10 {
            h.observe(i as f64);
        }
        // q below 0 behaves as q=0 (the min); above 1 as q=1 (the max)
        assert_eq!(h.quantile(-1.0), h.quantile(0.0));
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(2.0), h.quantile(1.0));
        assert_eq!(h.quantile(1.0), 10.0);
    }

    #[test]
    fn non_finite_observations_are_dropped() {
        let mut h = StreamingHistogram::latency_ms();
        h.observe(f64::NAN);
        h.observe(f64::INFINITY);
        assert_eq!(h.n(), 0);
    }
}
