//! Minimal HTTP/1.0 scrape endpoint for the metrics registry.
//!
//! A dedicated listener (separate from the line-protocol serve port, so a
//! scraper can never head-of-line-block a generation client) answering:
//!
//! * `GET /metrics` — Prometheus text exposition of the registry
//! * `GET /trace?req=N` — JSONL flight-recorder events for request `N`
//! * `GET /trace` — JSONL of retained flight events (most recent
//!   [`DEFAULT_TRACE_LIMIT`]; `?limit=N` overrides, so a full 4096-event
//!   ring never stalls the HTTP/1.0 listener by default)
//! * `GET /trace/spans[?req=N][&limit=N]` — closed request spans as
//!   nested JSON trees (same default limit, applied to spans considered)
//!
//! Hand-rolled on `std::net` like the main server (no hyper/tokio in the
//! offline crate set). Connections are scrape-shaped: read one request
//! head, write one response, close.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use super::Telemetry;
use crate::util::sync::lock_unpoisoned;

/// Events/spans returned by `GET /trace` and `GET /trace/spans` when the
/// client sends no `limit=N` — documented in docs/observability.md.
pub const DEFAULT_TRACE_LIMIT: usize = 1024;

/// Value of `key=` in an HTTP target's query string, if present.
fn query_param(target: &str, key: &str) -> Option<u64> {
    let (_, query) = target.split_once('?')?;
    query
        .split('&')
        .find_map(|kv| kv.strip_prefix(key).and_then(|v| v.strip_prefix('=')))
        .and_then(|v| v.parse::<u64>().ok())
}

/// Bind `addr` and serve scrapes on a background thread until `shutdown`.
/// Returns once the listener is bound (so callers can connect immediately).
pub fn spawn_metrics_listener(
    addr: &str,
    telemetry: Arc<Telemetry>,
    shutdown: Arc<AtomicBool>,
) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    std::thread::spawn(move || {
        while !shutdown.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    let t = telemetry.clone();
                    std::thread::spawn(move || handle_scrape(stream, t));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => break,
            }
        }
    });
    Ok(())
}

fn handle_scrape(mut stream: std::net::TcpStream, telemetry: Arc<Telemetry>) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(2)));
    // read until end-of-head (or EOF/timeout); only the request line matters
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(chunk.get(..n).unwrap_or(&[]));
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("");
    let target = parts.next().unwrap_or("");

    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", String::new())
    } else if target == "/metrics" {
        (
            "200 OK",
            "text/plain; version=0.0.4",
            telemetry.registry.render_prometheus(),
        )
    } else if target == "/trace/spans" || target.starts_with("/trace/spans?") {
        let req_id = query_param(target, "req");
        let limit = query_param(target, "limit")
            .map(|n| n as usize)
            .unwrap_or(DEFAULT_TRACE_LIMIT);
        let spans = lock_unpoisoned(&telemetry.spans);
        let body = spans.trees_json(req_id, limit).to_string();
        ("200 OK", "application/json", body)
    } else if target == "/trace" || target.starts_with("/trace?") {
        let req_id = query_param(target, "req");
        let limit = query_param(target, "limit")
            .map(|n| n as usize)
            .unwrap_or(DEFAULT_TRACE_LIMIT);
        let flight = lock_unpoisoned(&telemetry.flight);
        let mut events = match req_id {
            Some(id) => flight.events_for(id),
            None => flight.events(),
        };
        if events.len() > limit {
            events.drain(..events.len() - limit);
        }
        let body = events
            .iter()
            .map(|e| e.to_json().to_string() + "\n")
            .collect::<String>();
        ("200 OK", "application/jsonl", body)
    } else {
        ("404 Not Found", "text/plain", String::new())
    };

    let _ = write!(
        stream,
        "HTTP/1.0 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.flush();
}
