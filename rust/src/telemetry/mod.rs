//! Telemetry subsystem: metrics registry, flight recorder, scrape endpoint.
//!
//! Three pieces, all bounded-memory by construction (see
//! docs/observability.md for the full surface):
//!
//! * [`hist::StreamingHistogram`] — fixed-bucket latency/size histograms
//!   with exact moments; replaces the unbounded `Vec<f64>` latency logs
//!   `EngineMetrics` used to grow.
//! * [`registry::Registry`] — shared counter/gauge/histogram snapshot store
//!   the engine *publishes into* each serve-loop iteration. Scrapers read
//!   the registry; they never touch engine state. Rendered as Prometheus
//!   text exposition by the [`http`] listener (`--metrics-addr`) and as
//!   JSON by the line-protocol `stats` command.
//! * [`flight::FlightRecorder`] — bounded ring of per-request lifecycle
//!   events (queued → admitted → prefill → decode → evict/demote/promote →
//!   preempt/swap/resume → finish), dumpable as JSONL (`--trace-out`) and
//!   queryable per-request over the wire (`trace` command, `GET /trace`).
//!
//! The engine is single-threaded; [`Telemetry`] is the `Arc` handle shared
//! between it, the serve loop's connection threads, and the scrape
//! listener.

pub mod flight;
pub mod hist;
pub mod http;
pub mod registry;

use std::path::Path;
use std::sync::{Arc, Mutex};

pub use flight::{event, FlightEvent, FlightRecorder};
pub use hist::StreamingHistogram;
pub use http::spawn_metrics_listener;
pub use registry::{MetricKind, Registry};

/// Canonical metric names (the `lazyeviction_` namespace). Pool gauges are
/// published as `lazyeviction_pool_<field>` from `PoolGauges::fields()`.
pub mod names {
    pub const STEP_LATENCY_MS: &str = "lazyeviction_step_latency_ms";
    pub const PREFILL_LATENCY_MS: &str = "lazyeviction_prefill_latency_ms";
    pub const TTFT_MS: &str = "lazyeviction_ttft_ms";
    pub const TPOT_MS: &str = "lazyeviction_tpot_ms";
    pub const QUEUE_WAIT_MS: &str = "lazyeviction_queue_wait_ms";
    pub const EVICTION_PASS_MS: &str = "lazyeviction_eviction_pass_ms";
    pub const LIVE_TOKENS: &str = "lazyeviction_live_tokens";
    pub const TOKENS_OUT: &str = "lazyeviction_tokens_out_total";
    pub const STEPS: &str = "lazyeviction_decode_steps_total";
    pub const REQUESTS_FINISHED: &str = "lazyeviction_requests_finished_total";
    /// Tokens handed to streaming clients as they were decoded.
    pub const STREAMED_TOKENS: &str = "lazyeviction_streamed_tokens_total";
    /// Rows/requests torn down by client cancellation or disconnect.
    pub const CANCELLED_ROWS: &str = "lazyeviction_cancelled_rows_total";
    pub const POOL_PREFIX: &str = "lazyeviction_pool_";
    /// Fleet router placement counters (see `scheduler::routing`).
    pub const ROUTED_AFFINITY: &str = "lazyeviction_router_routed_affinity_total";
    pub const ROUTED_PRESSURE: &str = "lazyeviction_router_routed_pressure_total";
    pub const ROUTED_RR: &str = "lazyeviction_router_routed_rr_total";
    pub const ROUTER_REBALANCES: &str = "lazyeviction_router_rebalances_total";
    /// Replicas currently alive (fleet gauge).
    pub const REPLICAS_ALIVE: &str = "lazyeviction_replicas_alive";
}

/// Registry key for a labeled sample: `labeled("m", "replica", "2")` →
/// `m{replica="2"}`. [`registry::Registry::render_prometheus`] understands
/// this shape — samples sharing a base name render as one family — and the
/// fleet serve loop uses it to publish every replica's engine metrics side
/// by side in one registry. The value must not contain `"`, `\` or
/// newlines (we only ever pass replica indices).
pub fn labeled(name: &str, label: &str, value: impl std::fmt::Display) -> String {
    format!("{name}{{{label}=\"{value}\"}}")
}

/// Shared handle: registry (interior mutex) + flight recorder (mutex).
pub struct Telemetry {
    pub registry: Registry,
    pub flight: Mutex<FlightRecorder>,
}

impl Telemetry {
    /// In-memory telemetry with the default flight-ring capacity.
    pub fn new() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            registry: Registry::new(),
            flight: Mutex::new(FlightRecorder::new(FlightRecorder::DEFAULT_CAP)),
        })
    }

    /// Telemetry whose flight recorder also appends JSONL to `trace_out`.
    pub fn with_trace(cap: usize, trace_out: Option<&Path>) -> std::io::Result<Arc<Telemetry>> {
        let flight = match trace_out {
            Some(p) => FlightRecorder::with_output(cap, p)?,
            None => FlightRecorder::new(cap),
        };
        Ok(Arc::new(Telemetry {
            registry: Registry::new(),
            flight: Mutex::new(flight),
        }))
    }

    /// Record one flight event (convenience that takes the flight lock).
    pub fn record(
        &self,
        req: u64,
        event: &'static str,
        step: usize,
        live: usize,
        detail: f64,
        note: &'static str,
    ) {
        self.flight
            .lock()
            .unwrap()
            .record(req, event, step, live, detail, note);
    }

    /// Retained flight events for one request.
    pub fn events_for(&self, req: u64) -> Vec<FlightEvent> {
        self.flight.lock().unwrap().events_for(req)
    }

    pub fn flush(&self) {
        self.flight.lock().unwrap().flush();
    }
}
