//! Telemetry subsystem: metrics registry, flight recorder, scrape endpoint.
//!
//! Three pieces, all bounded-memory by construction (see
//! docs/observability.md for the full surface):
//!
//! * [`hist::StreamingHistogram`] — fixed-bucket latency/size histograms
//!   with exact moments; replaces the unbounded `Vec<f64>` latency logs
//!   `EngineMetrics` used to grow.
//! * [`registry::Registry`] — shared counter/gauge/histogram snapshot store
//!   the engine *publishes into* each serve-loop iteration. Scrapers read
//!   the registry; they never touch engine state. Rendered as Prometheus
//!   text exposition by the [`http`] listener (`--metrics-addr`) and as
//!   JSON by the line-protocol `stats` command.
//! * [`flight::FlightRecorder`] — bounded ring of per-request lifecycle
//!   events (queued → admitted → prefill → decode → evict/demote/promote →
//!   preempt/swap/resume → finish), dumpable as JSONL (`--trace-out`) and
//!   queryable per-request over the wire (`trace` command, `GET /trace`).
//! * [`span::SpanRecorder`] — causal, timed request spans with
//!   parent/child links stitched across replicas, served as trees
//!   (`GET /trace/spans`), interleaved into the `--trace-out` JSONL as v2
//!   lines, and fed into the registry as `lazyeviction_span_<name>_ms`
//!   duration histograms.
//!
//! The engine is single-threaded; [`Telemetry`] is the `Arc` handle shared
//! between it, the serve loop's connection threads, and the scrape
//! listener.

pub mod flight;
pub mod hist;
pub mod http;
pub mod registry;
pub mod span;

use std::path::Path;
use std::sync::{Arc, Mutex};

pub use flight::{event, FlightEvent, FlightRecorder};
pub use hist::StreamingHistogram;
pub use http::spawn_metrics_listener;
pub use registry::{MetricKind, Registry};
pub use span::{Span, SpanContext, SpanRecorder};

/// Canonical metric names (the `lazyeviction_` namespace). Pool gauges are
/// published as `lazyeviction_pool_<field>` from `PoolGauges::fields()`.
pub mod names {
    pub const STEP_LATENCY_MS: &str = "lazyeviction_step_latency_ms";
    pub const PREFILL_LATENCY_MS: &str = "lazyeviction_prefill_latency_ms";
    pub const TTFT_MS: &str = "lazyeviction_ttft_ms";
    pub const TPOT_MS: &str = "lazyeviction_tpot_ms";
    pub const QUEUE_WAIT_MS: &str = "lazyeviction_queue_wait_ms";
    pub const EVICTION_PASS_MS: &str = "lazyeviction_eviction_pass_ms";
    pub const LIVE_TOKENS: &str = "lazyeviction_live_tokens";
    pub const TOKENS_OUT: &str = "lazyeviction_tokens_out_total";
    pub const STEPS: &str = "lazyeviction_decode_steps_total";
    pub const REQUESTS_FINISHED: &str = "lazyeviction_requests_finished_total";
    /// Tokens handed to streaming clients as they were decoded.
    pub const STREAMED_TOKENS: &str = "lazyeviction_streamed_tokens_total";
    /// Rows/requests torn down by client cancellation or disconnect.
    pub const CANCELLED_ROWS: &str = "lazyeviction_cancelled_rows_total";
    pub const POOL_PREFIX: &str = "lazyeviction_pool_";
    /// Fleet router placement counters (see `scheduler::routing`).
    pub const ROUTED_AFFINITY: &str = "lazyeviction_router_routed_affinity_total";
    pub const ROUTED_PRESSURE: &str = "lazyeviction_router_routed_pressure_total";
    pub const ROUTED_RR: &str = "lazyeviction_router_routed_rr_total";
    pub const ROUTER_REBALANCES: &str = "lazyeviction_router_rebalances_total";
    /// Replicas currently alive (fleet gauge).
    pub const REPLICAS_ALIVE: &str = "lazyeviction_replicas_alive";
}

/// Registry key for a labeled sample: `labeled("m", "replica", "2")` →
/// `m{replica="2"}`. [`registry::Registry::render_prometheus`] understands
/// this shape — samples sharing a base name render as one family — and the
/// fleet serve loop uses it to publish every replica's engine metrics side
/// by side in one registry. The value must not contain `"`, `\` or
/// newlines (we only ever pass replica indices).
pub fn labeled(name: &str, label: &str, value: impl std::fmt::Display) -> String {
    format!("{name}{{{label}=\"{value}\"}}")
}

/// Shared handle: registry (interior mutex) + flight recorder (mutex) +
/// span recorder (mutex). Lock discipline: never hold two of the inner
/// locks at once — the span helpers below take the span lock, release it,
/// then take the flight lock to forward the JSONL line.
pub struct Telemetry {
    pub registry: Registry,
    pub flight: Mutex<FlightRecorder>,
    pub spans: Mutex<SpanRecorder>,
}

impl Telemetry {
    /// In-memory telemetry with the default flight-ring capacity.
    pub fn new() -> Arc<Telemetry> {
        Arc::new(Telemetry {
            registry: Registry::new(),
            flight: Mutex::new(FlightRecorder::new(FlightRecorder::DEFAULT_CAP)),
            spans: Mutex::new(SpanRecorder::new(SpanRecorder::DEFAULT_CAP)),
        })
    }

    /// Telemetry whose flight recorder also appends JSONL to `trace_out`.
    /// Span open/close lines share the same sink (v2 lines, see
    /// docs/observability.md §Spans) and the same ring capacity.
    pub fn with_trace(cap: usize, trace_out: Option<&Path>) -> std::io::Result<Arc<Telemetry>> {
        let flight = match trace_out {
            Some(p) => FlightRecorder::with_output(cap, p)?,
            None => FlightRecorder::new(cap),
        };
        Ok(Arc::new(Telemetry {
            registry: Registry::new(),
            flight: Mutex::new(flight),
            spans: Mutex::new(SpanRecorder::new(cap)),
        }))
    }

    /// Record one flight event (convenience that takes the flight lock).
    pub fn record(
        &self,
        req: u64,
        event: &'static str,
        step: usize,
        live: usize,
        detail: f64,
        note: &'static str,
    ) {
        self.flight
            .lock()
            .unwrap()
            .record(req, event, step, live, detail, note);
    }

    /// Retained flight events for one request.
    pub fn events_for(&self, req: u64) -> Vec<FlightEvent> {
        self.flight.lock().unwrap().events_for(req)
    }

    /// Open a span (see [`span::SpanRecorder::open`]) and forward the v2
    /// JSONL line to the trace sink. Returns the span id; children link to
    /// it via [`SpanContext::child_of`].
    pub fn span_open(
        &self,
        req: u64,
        name: &'static str,
        ctx: SpanContext,
        replica: Option<usize>,
        detail: f64,
        note: &'static str,
    ) -> u64 {
        let (id, line) = self
            .spans
            .lock()
            .unwrap()
            .open(req, name, ctx, replica, detail, note);
        self.flight.lock().unwrap().write_aux(&line, false);
        id
    }

    /// Close span `id`, optionally overriding detail/note, and forward the
    /// v2 JSONL line. No-op for id 0 (tracing off) or an unknown id.
    /// `flush` makes the line durable (close of a terminal `request` span).
    pub fn span_close_full(
        &self,
        id: u64,
        detail: Option<f64>,
        note: Option<&'static str>,
        flush: bool,
    ) {
        if id == 0 {
            return;
        }
        if let Some(line) = self.spans.lock().unwrap().close(id, detail, note) {
            self.flight.lock().unwrap().write_aux(&line, flush);
        }
    }

    /// Close span `id` with its open-time detail/note, unflushed.
    pub fn span_close(&self, id: u64) {
        self.span_close_full(id, None, None, false);
    }

    /// Closed spans for one request (or all), oldest-first.
    pub fn spans_for(&self, req: Option<u64>, limit: usize) -> Vec<Span> {
        self.spans.lock().unwrap().spans_for(req, limit)
    }

    /// Publish the per-name span duration histograms into the registry
    /// (`lazyeviction_span_<name>_ms` families).
    pub fn publish_span_metrics(&self) {
        self.spans.lock().unwrap().publish(&self.registry);
    }

    pub fn flush(&self) {
        self.flight.lock().unwrap().flush();
    }
}
