//! Typed metrics registry with Prometheus text exposition.
//!
//! The engine is single-threaded and owned by its serve loop, so the
//! registry works on a publish model: each loop iteration the engine pushes
//! snapshots of its counters, gauges, and histograms into the shared
//! registry (`Arc<Telemetry>`), and scrape threads read them without ever
//! touching engine state. Counters are clamped monotone on publish so a
//! scraper mid-publish never observes a decrease.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

use super::hist::StreamingHistogram;

/// Whether a published value is cumulative (counter) or instantaneous
/// (gauge) — drives the `# TYPE` annotation in the exposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, StreamingHistogram>,
}

/// Shared snapshot store; all methods take `&self` (interior mutex).
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Publish a cumulative counter. Clamped monotone: a stale or reset
    /// publisher can never make a scraped counter go backwards.
    pub fn set_counter(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Publish an instantaneous gauge (last write wins).
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    /// Publish a histogram snapshot (replaces the previous snapshot).
    pub fn set_histogram(&self, name: &str, h: &StreamingHistogram) {
        self.inner
            .lock()
            .unwrap()
            .hists
            .insert(name.to_string(), h.clone());
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.inner.lock().unwrap().counters.get(name).copied()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Prometheus text exposition (version 0.0.4): counters and gauges as
    /// single samples, histograms as `_bucket{le=...}`/`_sum`/`_count`
    /// families plus explicit `_p50`/`_p90`/`_p99` quantile gauges so
    /// scrapers that don't do bucket math still get percentiles.
    pub fn render_prometheus(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (name, v) in &g.counters {
            out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
        }
        for (name, v) in &g.gauges {
            out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", num(*v)));
        }
        for (name, h) in &g.hists {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, c) in h.cumulative_buckets() {
                let le = if le.is_infinite() {
                    "+Inf".to_string()
                } else {
                    num(le)
                };
                out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {c}\n"));
            }
            out.push_str(&format!("{name}_sum {}\n", num(h.sum())));
            out.push_str(&format!("{name}_count {}\n", h.n()));
            for (q, label) in [(0.50, "p50"), (0.90, "p90"), (0.99, "p99")] {
                out.push_str(&format!(
                    "# TYPE {name}_{label} gauge\n{name}_{label} {}\n",
                    num(h.quantile(q))
                ));
            }
        }
        out
    }

    /// JSON snapshot for the line-protocol `stats` command: counters and
    /// gauges verbatim, histograms as `{count, sum, mean, p50, p90, p99}`.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut counters = Json::obj();
        for (name, v) in &g.counters {
            counters = counters.set(name.as_str(), *v as f64);
        }
        let mut gauges = Json::obj();
        for (name, v) in &g.gauges {
            gauges = gauges.set(name.as_str(), *v);
        }
        let mut hists = Json::obj();
        for (name, h) in &g.hists {
            hists = hists.set(
                name.as_str(),
                Json::obj()
                    .set("count", h.n() as f64)
                    .set("sum", h.sum())
                    .set("mean", h.mean())
                    .set("p50", h.quantile(0.50))
                    .set("p90", h.quantile(0.90))
                    .set("p99", h.quantile(0.99)),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }

    pub fn counter_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().counters.keys().cloned().collect()
    }
}

/// Render a float the way the exposition format expects: integral values
/// without a trailing `.0`, non-finite as Prometheus spec strings.
fn num(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x.is_infinite() {
        if x > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone() {
        let r = Registry::new();
        r.set_counter("x", 5);
        r.set_counter("x", 3); // stale publish must not regress
        assert_eq!(r.counter("x"), Some(5));
        r.set_counter("x", 9);
        assert_eq!(r.counter("x"), Some(9));
    }

    #[test]
    fn gauges_last_write_wins() {
        let r = Registry::new();
        r.set_gauge("g", 5.0);
        r.set_gauge("g", 3.0);
        assert_eq!(r.gauge("g"), Some(3.0));
    }

    #[test]
    fn exposition_contains_all_families() {
        let r = Registry::new();
        r.set_counter("app_requests_total", 7);
        r.set_gauge("app_free_blocks", 12.0);
        let mut h = StreamingHistogram::latency_ms();
        h.observe(1.5);
        h.observe(2.5);
        r.set_histogram("app_step_ms", &h);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE app_requests_total counter"));
        assert!(text.contains("app_requests_total 7"));
        assert!(text.contains("app_free_blocks 12"));
        assert!(text.contains("app_step_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("app_step_ms_count 2"));
        assert!(text.contains("app_step_ms_p50"));
        assert!(text.contains("app_step_ms_p99"));
        // every line is either a comment or `name value`
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn json_snapshot_shape() {
        let r = Registry::new();
        r.set_counter("c", 1);
        r.set_gauge("g", 0.5);
        let mut h = StreamingHistogram::latency_ms();
        h.observe(4.0);
        r.set_histogram("h", &h);
        let j = r.to_json();
        let s = j.to_string();
        let back = Json::parse(&s).expect("stats JSON round-trips");
        assert_eq!(back.req("counters").unwrap().f64_at("c").unwrap(), 1.0);
        assert_eq!(back.req("gauges").unwrap().f64_at("g").unwrap(), 0.5);
        let h = back.req("histograms").unwrap().req("h").unwrap();
        assert_eq!(h.f64_at("count").unwrap(), 1.0);
    }
}
