//! Typed metrics registry with Prometheus text exposition.
//!
//! The engine is single-threaded and owned by its serve loop, so the
//! registry works on a publish model: each loop iteration the engine pushes
//! snapshots of its counters, gauges, and histograms into the shared
//! registry (`Arc<Telemetry>`), and scrape threads read them without ever
//! touching engine state. Counters are clamped monotone on publish so a
//! scraper mid-publish never observes a decrease.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::json::Json;

use super::hist::StreamingHistogram;

/// Whether a published value is cumulative (counter) or instantaneous
/// (gauge) — drives the `# TYPE` annotation in the exposition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricKind {
    Counter,
    Gauge,
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, StreamingHistogram>,
}

/// Shared snapshot store; all methods take `&self` (interior mutex).
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Publish a cumulative counter. Clamped monotone: a stale or reset
    /// publisher can never make a scraped counter go backwards.
    pub fn set_counter(&self, name: &str, v: u64) {
        let mut g = self.inner.lock().unwrap();
        let e = g.counters.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Publish an instantaneous gauge (last write wins).
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), v);
    }

    /// Publish a histogram snapshot (replaces the previous snapshot).
    pub fn set_histogram(&self, name: &str, h: &StreamingHistogram) {
        self.inner
            .lock()
            .unwrap()
            .hists
            .insert(name.to_string(), h.clone());
    }

    pub fn counter(&self, name: &str) -> Option<u64> {
        self.inner.lock().unwrap().counters.get(name).copied()
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.inner.lock().unwrap().gauges.get(name).copied()
    }

    /// Prometheus text exposition (version 0.0.4): counters and gauges as
    /// single samples, histograms as `_bucket{le=...}`/`_sum`/`_count`
    /// families plus explicit `_p50`/`_p90`/`_p99` quantile gauges so
    /// scrapers that don't do bucket math still get percentiles.
    ///
    /// Registry keys may carry a label suffix (`name{replica="0"}`, built
    /// by [`super::labeled`]) — the fleet server publishes each replica's
    /// metrics this way. Samples with the same *base* name are grouped
    /// into one family under a single `# TYPE` line, and histogram labels
    /// are spliced into every derived sample (`_bucket{le="x",replica=…}`,
    /// `_sum{replica=…}`, …) so the output stays spec-valid.
    pub fn render_prometheus(&self) -> String {
        let g = self.inner.lock().unwrap();
        let mut out = String::new();
        for (base, samples) in group_families(g.counters.iter().map(|(k, v)| (k, v.to_string()))) {
            out.push_str(&format!("# TYPE {base} counter\n"));
            for (labels, v) in samples {
                out.push_str(&format!("{base}{labels} {v}\n"));
            }
        }
        for (base, samples) in group_families(g.gauges.iter().map(|(k, v)| (k, num(*v)))) {
            out.push_str(&format!("# TYPE {base} gauge\n"));
            for (labels, v) in samples {
                out.push_str(&format!("{base}{labels} {v}\n"));
            }
        }
        // histograms: group by base, then emit buckets/sum/count per label
        // set under one TYPE line; quantile gauges get their own families.
        let mut hist_groups: BTreeMap<&str, Vec<(&str, &StreamingHistogram)>> = BTreeMap::new();
        for (name, h) in &g.hists {
            let (base, labels) = split_labels(name);
            hist_groups.entry(base).or_default().push((labels, h));
        }
        for (base, entries) in &hist_groups {
            out.push_str(&format!("# TYPE {base} histogram\n"));
            for (labels, h) in entries {
                for (le, c) in h.cumulative_buckets() {
                    let le = if le.is_infinite() {
                        "+Inf".to_string()
                    } else {
                        num(le)
                    };
                    out.push_str(&format!(
                        "{base}_bucket{} {c}\n",
                        splice_label(labels, &format!("le=\"{le}\""))
                    ));
                }
                out.push_str(&format!("{base}_sum{labels} {}\n", num(h.sum())));
                out.push_str(&format!("{base}_count{labels} {}\n", h.n()));
            }
        }
        for (q, qname) in [(0.50, "p50"), (0.90, "p90"), (0.99, "p99")] {
            for (base, entries) in &hist_groups {
                out.push_str(&format!("# TYPE {base}_{qname} gauge\n"));
                for (labels, h) in entries {
                    out.push_str(&format!(
                        "{base}_{qname}{labels} {}\n",
                        num(h.quantile(q))
                    ));
                }
            }
        }
        out
    }

    /// JSON snapshot for the line-protocol `stats` command: counters and
    /// gauges verbatim, histograms as `{count, sum, mean, p50, p90, p99}`.
    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        let mut counters = Json::obj();
        for (name, v) in &g.counters {
            counters = counters.set(name.as_str(), *v as f64);
        }
        let mut gauges = Json::obj();
        for (name, v) in &g.gauges {
            gauges = gauges.set(name.as_str(), *v);
        }
        let mut hists = Json::obj();
        for (name, h) in &g.hists {
            hists = hists.set(
                name.as_str(),
                Json::obj()
                    .set("count", h.n() as f64)
                    .set("sum", h.sum())
                    .set("mean", h.mean())
                    .set("p50", h.quantile(0.50))
                    .set("p90", h.quantile(0.90))
                    .set("p99", h.quantile(0.99)),
            );
        }
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
    }

    pub fn counter_names(&self) -> Vec<String> {
        self.inner.lock().unwrap().counters.keys().cloned().collect()
    }
}

/// Split a registry key into (base name, label suffix). `"a{x=\"1\"}"` →
/// `("a", "{x=\"1\"}")`; an unlabeled key returns an empty suffix.
fn split_labels(key: &str) -> (&str, &str) {
    match key.find('{') {
        Some(i) => key.split_at(i),
        None => (key, ""),
    }
}

/// Merge an extra `k="v"` pair into an existing label suffix:
/// `("", le)` → `{le}`, `("{replica=\"0\"}", le)` → `{le,replica="0"}`.
fn splice_label(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        format!("{{{extra}}}")
    } else {
        format!("{{{extra},{}", &labels[1..])
    }
}

/// Group sorted `(key, rendered_value)` pairs into
/// `base → [(label_suffix, value)]` families for exposition.
fn group_families<'a, I>(it: I) -> BTreeMap<&'a str, Vec<(&'a str, String)>>
where
    I: Iterator<Item = (&'a String, String)>,
{
    let mut out: BTreeMap<&str, Vec<(&str, String)>> = BTreeMap::new();
    for (key, v) in it {
        let (base, labels) = split_labels(key);
        out.entry(base).or_default().push((labels, v));
    }
    out
}

/// Render a float the way the exposition format expects: integral values
/// without a trailing `.0`, non-finite as Prometheus spec strings.
fn num(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x.is_infinite() {
        if x > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_monotone() {
        let r = Registry::new();
        r.set_counter("x", 5);
        r.set_counter("x", 3); // stale publish must not regress
        assert_eq!(r.counter("x"), Some(5));
        r.set_counter("x", 9);
        assert_eq!(r.counter("x"), Some(9));
    }

    #[test]
    fn gauges_last_write_wins() {
        let r = Registry::new();
        r.set_gauge("g", 5.0);
        r.set_gauge("g", 3.0);
        assert_eq!(r.gauge("g"), Some(3.0));
    }

    #[test]
    fn exposition_contains_all_families() {
        let r = Registry::new();
        r.set_counter("app_requests_total", 7);
        r.set_gauge("app_free_blocks", 12.0);
        let mut h = StreamingHistogram::latency_ms();
        h.observe(1.5);
        h.observe(2.5);
        r.set_histogram("app_step_ms", &h);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE app_requests_total counter"));
        assert!(text.contains("app_requests_total 7"));
        assert!(text.contains("app_free_blocks 12"));
        assert!(text.contains("app_step_ms_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("app_step_ms_count 2"));
        assert!(text.contains("app_step_ms_p50"));
        assert!(text.contains("app_step_ms_p99"));
        // every line is either a comment or `name value`
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn labeled_samples_group_under_one_type_line() {
        let r = Registry::new();
        r.set_counter("app_hits_total", 3); // single-engine, unlabeled
        r.set_counter("app_hits_total{replica=\"0\"}", 5);
        r.set_counter("app_hits_total{replica=\"1\"}", 2);
        r.set_gauge("app_free{replica=\"0\"}", 9.0);
        let text = r.render_prometheus();
        assert_eq!(
            text.matches("# TYPE app_hits_total counter").count(),
            1,
            "one TYPE line per family, not per labeled sample:\n{text}"
        );
        assert!(text.contains("app_hits_total 3"));
        assert!(text.contains("app_hits_total{replica=\"0\"} 5"));
        assert!(text.contains("app_hits_total{replica=\"1\"} 2"));
        assert!(text.contains("# TYPE app_free gauge"));
        assert!(text.contains("app_free{replica=\"0\"} 9"));
        // no TYPE line may carry a label suffix
        for line in text.lines().filter(|l| l.starts_with("# TYPE")) {
            assert!(!line.contains('{'), "labeled TYPE line: {line}");
        }
    }

    #[test]
    fn labeled_histogram_splices_labels_into_samples() {
        let r = Registry::new();
        let mut h = StreamingHistogram::latency_ms();
        h.observe(1.5);
        r.set_histogram("app_step_ms{replica=\"2\"}", &h);
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE app_step_ms histogram"));
        assert!(
            text.contains("app_step_ms_bucket{le=\"+Inf\",replica=\"2\"} 1"),
            "bucket labels must merge le with the replica label:\n{text}"
        );
        assert!(text.contains("app_step_ms_sum{replica=\"2\"}"));
        assert!(text.contains("app_step_ms_count{replica=\"2\"} 1"));
        assert!(text.contains("# TYPE app_step_ms_p50 gauge"));
        assert!(text.contains("app_step_ms_p50{replica=\"2\"}"));
        // the exposition line shape invariant survives labels
        for line in text.lines() {
            assert!(
                line.starts_with('#') || line.split_whitespace().count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }

    #[test]
    fn json_snapshot_shape() {
        let r = Registry::new();
        r.set_counter("c", 1);
        r.set_gauge("g", 0.5);
        let mut h = StreamingHistogram::latency_ms();
        h.observe(4.0);
        r.set_histogram("h", &h);
        let j = r.to_json();
        let s = j.to_string();
        let back = Json::parse(&s).expect("stats JSON round-trips");
        assert_eq!(back.req("counters").unwrap().f64_at("c").unwrap(), 1.0);
        assert_eq!(back.req("gauges").unwrap().f64_at("g").unwrap(), 0.5);
        let h = back.req("histograms").unwrap().req("h").unwrap();
        assert_eq!(h.f64_at("count").unwrap(), 1.0);
    }
}
