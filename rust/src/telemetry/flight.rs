//! Per-request flight recorder: a bounded ring buffer of structured
//! lifecycle events for post-mortem of preemption storms.
//!
//! Every request's life is a sequence of events — queued → admitted
//! (possibly via a prefix hit) → prefill → decode → evict/demote/promote →
//! preempt/swap/resume → finish — and under pool pressure the interesting
//! failures are *interleavings* of those sequences across requests. The
//! recorder keeps the most recent `cap` events in memory (queryable
//! per-request over the wire) and, when configured with an output path,
//! appends every event as a JSON line so a full serve run can be replayed
//! offline.

use std::collections::VecDeque;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

/// Event names, in rough lifecycle order. Kept as `&'static str` so
/// recording never allocates for the common fields.
pub mod event {
    /// Request parsed and placed on the scheduler queue.
    pub const QUEUED: &str = "queued";
    /// Admitted into a batch row; `detail` = prompt tokens.
    pub const ADMITTED: &str = "admitted";
    /// Prompt prefix found in the cache; `detail` = tokens premapped.
    pub const PREFIX_HIT: &str = "prefix_hit";
    /// Prefill executed; `detail` = wall milliseconds.
    pub const PREFILL: &str = "prefill";
    /// Prefill skipped outright (full-prompt prefix hit).
    pub const PREFILL_SKIP: &str = "prefill_skip";
    /// First decode step after admission.
    pub const DECODE: &str = "decode";
    /// Eviction pass removed tokens; `detail` = tokens evicted.
    pub const EVICT: &str = "evict";
    /// Evicted blocks parked in the host tier; `detail` = tokens parked.
    pub const DEMOTE: &str = "demote";
    /// The tier refused a park outright (byte budget full of pinned
    /// state); `detail` = cumulative rejects. The demotion stayed
    /// destructive (or the swap preemption fell back to recompute).
    pub const TIER_REJECT: &str = "tier_reject";
    /// Unpinned tier entries destroyed under byte pressure while this
    /// request parked; `detail` = blocks shed (`tier_shed_blocks` delta).
    pub const TIER_SHED: &str = "tier_shed";
    /// Parked tokens promoted back on recurrence; `detail` = tokens.
    pub const PROMOTE: &str = "promote";
    /// Row preempted, recompute snapshot taken; `detail` = live tokens.
    pub const PREEMPT: &str = "preempt";
    /// Row preempted by swapping its table to the host tier.
    pub const PREEMPT_SWAP: &str = "preempt_swap";
    /// Recompute-mode resume; `detail` = tokens re-prefilled.
    pub const RESUME: &str = "resume";
    /// Swap-mode resume; `detail` = bytes copied host→device.
    pub const RESUME_SWAP: &str = "resume_swap";
    /// Resume fell back to a restart from the prompt.
    pub const RESUME_RESTART: &str = "resume_restart";
    /// One decoded token left the engine toward a streaming client;
    /// `detail` = tokens produced so far.
    pub const STREAM_TOKEN: &str = "stream_token";
    /// Row aborted by client cancellation/disconnect; `detail` = tokens
    /// produced before the abort, `note` = what owned the request's state:
    /// "active" (decoding row), "queued" (preempted snapshot discarded) or
    /// "unadmitted" (fresh queued request dropped).
    pub const ABORT: &str = "abort";
    /// Request finished; `detail` = tokens produced, `note` = reason.
    pub const FINISH: &str = "finish";
}

/// One lifecycle event. `step` is the row's sequence position at the time
/// (0 when not yet admitted), `live` the row's live-set size in tokens, and
/// `detail` an event-specific scalar documented on the `event` constants.
#[derive(Clone, Debug)]
pub struct FlightEvent {
    /// Global sequence number (strictly increasing across all requests).
    pub seq: u64,
    /// Seconds since the recorder was created.
    pub t_s: f64,
    /// Request id.
    pub req: u64,
    pub event: &'static str,
    pub step: usize,
    pub live: usize,
    pub detail: f64,
    /// Free-form qualifier (finish reason, preempt mode); "" when unused.
    pub note: &'static str,
}

impl FlightEvent {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj()
            .set("seq", self.seq as f64)
            .set("t_s", self.t_s)
            .set("req", self.req as f64)
            .set("event", self.event)
            .set("step", self.step)
            .set("live", self.live)
            .set("detail", self.detail);
        if !self.note.is_empty() {
            j = j.set("note", self.note);
        }
        j
    }
}

/// Bounded in-memory ring + optional JSONL sink.
pub struct FlightRecorder {
    epoch: Instant,
    next_seq: u64,
    cap: usize,
    ring: VecDeque<FlightEvent>,
    out: Option<BufWriter<File>>,
    /// Events pushed out of the ring since startup (still in the JSONL
    /// sink if one is configured).
    pub dropped: u64,
}

impl FlightRecorder {
    pub const DEFAULT_CAP: usize = 4096;

    pub fn new(cap: usize) -> FlightRecorder {
        FlightRecorder {
            epoch: Instant::now(),
            next_seq: 0,
            cap: cap.max(1),
            ring: VecDeque::with_capacity(cap.max(1).min(1024)),
            out: None,
            dropped: 0,
        }
    }

    /// Ring recorder that also appends every event to `path` as JSONL.
    pub fn with_output(cap: usize, path: &Path) -> std::io::Result<FlightRecorder> {
        let mut r = FlightRecorder::new(cap);
        r.out = Some(BufWriter::new(File::create(path)?));
        Ok(r)
    }

    pub fn record(
        &mut self,
        req: u64,
        event: &'static str,
        step: usize,
        live: usize,
        detail: f64,
        note: &'static str,
    ) {
        let ev = FlightEvent {
            seq: self.next_seq,
            t_s: self.epoch.elapsed().as_secs_f64(),
            req,
            event,
            step,
            live,
            detail,
            note,
        };
        self.next_seq += 1;
        if let Some(w) = self.out.as_mut() {
            let _ = writeln!(w, "{}", ev.to_json().to_string());
            // finish/abort closes a request's sequence — make it durable so
            // a reader tailing the file sees complete lifecycles
            if event == event::FINISH || event == event::ABORT {
                let _ = w.flush();
            }
        }
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(ev);
    }

    /// Append an auxiliary JSONL line (a v2 span line) to the same sink
    /// the flight events stream into, keeping `--trace-out` one
    /// chronological file. No-op without an output path; `flush` makes the
    /// line durable immediately (span closes of terminal spans).
    pub fn write_aux(&mut self, line: &Json, flush: bool) {
        if let Some(w) = self.out.as_mut() {
            let _ = writeln!(w, "{}", line.to_string());
            if flush {
                let _ = w.flush();
            }
        }
    }

    /// All retained events for one request, in emission order.
    pub fn events_for(&self, req: u64) -> Vec<FlightEvent> {
        self.ring.iter().filter(|e| e.req == req).cloned().collect()
    }

    /// All retained events in emission order.
    pub fn events(&self) -> Vec<FlightEvent> {
        self.ring.iter().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn flush(&mut self) {
        if let Some(w) = self.out.as_mut() {
            let _ = w.flush();
        }
    }
}

impl Drop for FlightRecorder {
    fn drop(&mut self) {
        self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let mut r = FlightRecorder::new(3);
        for i in 0..5u64 {
            r.record(i, event::QUEUED, 0, 0, 0.0, "");
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped, 2);
        // oldest two evicted, seq numbering still global
        let seqs: Vec<u64> = r.events().iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![2, 3, 4]);
    }

    #[test]
    fn events_for_filters_and_preserves_order() {
        let mut r = FlightRecorder::new(16);
        r.record(1, event::QUEUED, 0, 0, 0.0, "");
        r.record(2, event::QUEUED, 0, 0, 0.0, "");
        r.record(1, event::ADMITTED, 5, 5, 5.0, "");
        r.record(1, event::FINISH, 12, 9, 7.0, "max_tokens");
        let ev = r.events_for(1);
        let names: Vec<&str> = ev.iter().map(|e| e.event).collect();
        assert_eq!(names, vec!["queued", "admitted", "finish"]);
        assert!(ev.windows(2).all(|w| w[0].seq < w[1].seq));
        assert!(ev.windows(2).all(|w| w[0].t_s <= w[1].t_s));
    }

    #[test]
    fn jsonl_sink_writes_parseable_lines() {
        let dir = std::env::temp_dir().join("lazyeviction-flight-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("trace-{}.jsonl", std::process::id()));
        {
            let mut r = FlightRecorder::with_output(8, &path).unwrap();
            r.record(7, event::QUEUED, 0, 0, 0.0, "");
            r.record(7, event::ADMITTED, 4, 4, 4.0, "");
            r.record(7, event::FINISH, 10, 8, 6.0, "stop");
        } // drop flushes
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let j = Json::parse(line).expect("each trace line is valid JSON");
            assert_eq!(j.f64_at("req").unwrap(), 7.0);
            assert!(j.str_at("event").is_ok());
        }
        let last = Json::parse(lines[2]).unwrap();
        assert_eq!(last.str_at("note").unwrap(), "stop");
        let _ = std::fs::remove_file(&path);
    }
}
