//! KV memory accounting (Fig. 6): bytes held per sequence/engine as a
//! function of generated length, per policy. The model mirrors the paper's
//! setting (bytes = 2 · L · H · dh · dtype_bytes per live token).

/// Static description of a model's per-token KV footprint.
#[derive(Clone, Copy, Debug)]
pub struct KvCost {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub dtype_bytes: usize,
}

impl KvCost {
    pub fn bytes_per_token(&self) -> usize {
        2 * self.n_layers * self.n_heads * self.d_head * self.dtype_bytes
    }

    pub fn bytes_for(&self, live_tokens: usize) -> usize {
        live_tokens * self.bytes_per_token()
    }

    /// The paper's example scale: DS-Qwen-7B-ish (28 layers, 4 KV heads of
    /// 128, fp16) — used by the Fig. 6 bench to report GB on paper-scale axes.
    pub fn paper_7b() -> KvCost {
        KvCost {
            n_layers: 28,
            n_heads: 4,
            d_head: 128,
            dtype_bytes: 2,
        }
    }
}

/// Time series of live-token counts -> memory curve.
pub fn memory_curve(live_counts: &[usize], cost: KvCost) -> Vec<usize> {
    live_counts.iter().map(|&n| cost.bytes_for(n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn per_token_bytes() {
        let c = KvCost {
            n_layers: 4,
            n_heads: 2,
            d_head: 64,
            dtype_bytes: 4,
        };
        assert_eq!(c.bytes_per_token(), 2 * 4 * 2 * 64 * 4);
    }

    #[test]
    fn curve_is_linear_in_tokens() {
        let c = KvCost {
            n_layers: 1,
            n_heads: 1,
            d_head: 1,
            dtype_bytes: 1,
        };
        assert_eq!(memory_curve(&[0, 5, 10], c), vec![0, 10, 20]);
    }

    #[test]
    fn paper_scale_sane() {
        // 16k tokens on the 7B profile ≈ 0.9 GB per sequence — the paper's
        // "100GB at batch 32" claim is the 8B-Llama profile at 16k; order of
        // magnitude must match (GBs, not MBs).
        let gb = KvCost::paper_7b().bytes_for(16_384) as f64 / 1e9;
        assert!(gb > 0.3 && gb < 3.0, "{gb}");
    }
}
