//! Host-side KV cache bookkeeping for one sequence.
//!
//! The actual K/V tensors live on the PJRT device (runtime::ModelExecutor);
//! this module owns the *metadata* the eviction policies operate on: one
//! `TokenRecord` per live slot, compacted so live tokens always occupy slots
//! `[0, len)` — which keeps the slot mask trivial and turns an eviction into
//! a single device `gather` with the keep-list as indices.
//!
//! When the engine runs against a shared [`kvpool`](crate::kvpool) budget, a
//! `SeqKv` additionally carries a `BlockTable` view (slot → block/offset):
//! `push_pooled` grows it a block at a time and `apply_keep_pooled` returns
//! whole freed blocks to the pool after compaction.
//!
//! With *physical* paging (K/V bytes in pool-shaped backend storage), the
//! `_cow`/`_moves` method variants additionally report what the logical
//! mutation implies for the bytes: a shared-tail push or privatization
//! emits [`BlockCopy`] descriptors, a compaction emits the [`RowMove`] list
//! relocating every surviving row. Callers must apply those to the backend
//! storage before the next write/allocation, or live tables read stale rows.

pub mod memory;

use crate::kvpool::{BlockCopy, BlockId, BlockPool, BlockTable, RowMove};

/// Per-token tracking state. All per-token signals any of the implemented
/// policies need are kept here so that compaction reorders them uniformly.
#[derive(Clone, Debug)]
pub struct TokenRecord {
    /// Absolute position in the sequence (0-based; prompt included).
    pub pos: u32,
    /// Creation decoding step (== pos for self-generated tokens).
    pub born: u32,
    /// Last "important" step: updated to t whenever attention >= alpha
    /// (RaaS-style timestamp; LazyEviction Eq. 1 input).
    pub ts: u32,
    /// Maximum Recurrence Interval (LazyEviction Eq. 1).
    pub mri: u32,
    /// Attention score from the most recent step (TOVA).
    pub last_attn: f32,
    /// Cumulative attention (H2O heavy-hitter score).
    pub cum_attn: f32,
    /// Number of steps with attention >= alpha (Scissorhands persistence).
    pub hits: u32,
    /// Key sketch for similarity-based policies (R-KV): layer-0 key vector,
    /// empty when the producer cannot supply one.
    pub key_sketch: Vec<f32>,
    /// Trace-provided redundancy group (u32::MAX = none) — lets the
    /// simulator model R-KV without materializing key vectors.
    pub sim_group: u32,
}

impl TokenRecord {
    pub fn new(pos: u32, step: u32) -> TokenRecord {
        TokenRecord {
            pos,
            born: step,
            ts: step,
            mri: 0,
            last_attn: 0.0,
            cum_attn: 0.0,
            hits: 0,
            key_sketch: Vec::new(),
            sim_group: u32::MAX,
        }
    }

    pub fn with_sketch(mut self, sketch: Vec<f32>) -> TokenRecord {
        self.key_sketch = sketch;
        self
    }

    pub fn with_group(mut self, g: u32) -> TokenRecord {
        self.sim_group = g;
        self
    }
}

/// An eviction event (kept for analysis/benches when logging is enabled).
#[derive(Clone, Debug)]
pub struct Eviction {
    pub step: u32,
    pub pos: u32,
}

/// Compacted per-sequence slot state.
#[derive(Clone, Debug)]
pub struct SeqKv {
    pub capacity: usize,
    records: Vec<TokenRecord>,
    pub log_evictions: bool,
    pub evictions: Vec<Eviction>,
    /// Peak live count (memory accounting).
    pub peak_live: usize,
    /// Paged view: present iff this sequence draws from a shared BlockPool.
    block_table: Option<BlockTable>,
}

impl SeqKv {
    pub fn new(capacity: usize) -> SeqKv {
        SeqKv {
            capacity,
            records: Vec::with_capacity(capacity),
            log_evictions: false,
            evictions: Vec::new(),
            peak_live: 0,
            block_table: None,
        }
    }

    /// Attach a paged-view block table before any token is pushed. The
    /// table is either fresh (empty) or a prefix fork whose whole-block
    /// mapping the prompt's leading records will fill in — in the forked
    /// case `push_pooled` consumes the premapped slots without allocating,
    /// and once `records.len()` catches up the two grow in lockstep again.
    pub fn attach_block_table(&mut self, table: BlockTable) {
        assert!(
            self.records.is_empty(),
            "block table must be attached to an empty sequence"
        );
        assert!(
            table.len() % table.block_size() == 0,
            "prefix forks premap whole blocks only (len {})",
            table.len()
        );
        self.block_table = Some(table);
    }

    pub fn block_table(&self) -> Option<&BlockTable> {
        self.block_table.as_ref()
    }

    /// Will the next pooled push need a fresh block from the pool? True at
    /// block boundaries and when the push would copy-on-write a shared tail
    /// block (both paths call `BlockPool::alloc`).
    pub fn needs_block_for_next(&self, pool: &BlockPool) -> bool {
        match &self.block_table {
            Some(t) => {
                if self.records.len() < t.len() {
                    false // premapped by a prefix fork: no allocation
                } else {
                    t.at_block_boundary() || t.tail_is_shared(pool)
                }
            }
            None => false,
        }
    }

    /// `push` through the paged view: maps one more token in the block
    /// table first (allocating at block boundaries, or consuming a slot a
    /// prefix fork premapped). Returns `None` with state unchanged when the
    /// pool is exhausted.
    pub fn push_pooled(&mut self, rec: TokenRecord, pool: &mut BlockPool) -> Option<usize> {
        self.push_pooled_inner(rec, pool, None)
    }

    /// [`push_pooled`](Self::push_pooled) for physical paging: a push that
    /// copy-on-writes a shared tail block reports the implied [`BlockCopy`]
    /// so the caller can duplicate the K/V rows in backend storage before
    /// writing the new token's row.
    pub fn push_pooled_cow(
        &mut self,
        rec: TokenRecord,
        pool: &mut BlockPool,
        copies: &mut Vec<BlockCopy>,
    ) -> Option<usize> {
        self.push_pooled_inner(rec, pool, Some(copies))
    }

    fn push_pooled_inner(
        &mut self,
        rec: TokenRecord,
        pool: &mut BlockPool,
        copies: Option<&mut Vec<BlockCopy>>,
    ) -> Option<usize> {
        if let Some(t) = self.block_table.as_mut() {
            if self.records.len() >= t.len() {
                let pushed = match copies {
                    Some(c) => t.push_token_cow(pool, c),
                    None => t.push_token(pool),
                };
                if !pushed {
                    return None;
                }
            }
        }
        Some(self.push(rec))
    }

    /// Copy-on-write every shared block so compaction/eviction can mutate
    /// the mapping freely. True when the table is fully private (or absent);
    /// false when the pool could not supply replacement blocks — the table
    /// stays consistent and the call can be retried after shedding/preempting.
    pub fn make_private(&mut self, pool: &mut BlockPool) -> bool {
        match self.block_table.as_mut() {
            Some(t) => t.ensure_private(pool),
            None => true,
        }
    }

    /// [`make_private`](Self::make_private) for physical paging: reports one
    /// [`BlockCopy`] per privatized block. Copies already reported remain
    /// valid (and must be applied) even on a `false` return — they describe
    /// blocks that *were* swapped. (The bodies differ only in which
    /// `BlockTable` variant they call, which already deduplicates the real
    /// logic via `ensure_private_inner`.)
    pub fn make_private_cow(&mut self, pool: &mut BlockPool, copies: &mut Vec<BlockCopy>) -> bool {
        match self.block_table.as_mut() {
            Some(t) => t.ensure_private_cow(pool, copies),
            None => true,
        }
    }

    /// `apply_keep` through the paged view: compaction shrinks the live set
    /// to `keep.len()`, and whole trailing blocks go back to the pool.
    /// Returns (evicted positions, blocks freed).
    pub fn apply_keep_pooled(
        &mut self,
        keep: &[u32],
        step: u32,
        pool: &mut BlockPool,
    ) -> (Vec<u32>, usize) {
        let evicted = self.apply_keep(keep, step);
        let freed = match self.block_table.as_mut() {
            Some(t) => t.truncate(self.records.len(), pool),
            None => 0,
        };
        (evicted, freed)
    }

    /// [`apply_keep_pooled`](Self::apply_keep_pooled) for physical paging:
    /// appends to `moves` the relocation of every surviving K/V row from its
    /// pre-compaction to its post-compaction arena location (identity moves
    /// are skipped). The caller MUST apply the moves to backend storage
    /// before the next pool allocation — sources may sit in blocks this
    /// compaction just freed, whose bytes are only valid until reuse. The
    /// table must already be private (see
    /// [`make_private_cow`](Self::make_private_cow)); moving rows inside
    /// shared blocks would corrupt the other holders.
    pub fn apply_keep_pooled_moves(
        &mut self,
        keep: &[u32],
        step: u32,
        pool: &mut BlockPool,
        moves: &mut Vec<RowMove>,
    ) -> (Vec<u32>, usize) {
        let srcs: Option<Vec<(BlockId, usize)>> = self.block_table.as_ref().map(|t| {
            debug_assert_eq!(t.n_shared_blocks(pool), 0, "compaction over shared blocks");
            keep.iter()
                .map(|&k| t.locate(k as usize).expect("keep slot is mapped"))
                .collect()
        });
        let evicted = self.apply_keep(keep, step);
        let freed = match self.block_table.as_mut() {
            Some(t) => t.truncate(self.records.len(), pool),
            None => 0,
        };
        if let (Some(srcs), Some(t)) = (srcs, self.block_table.as_ref()) {
            for (j, (sb, so)) in srcs.into_iter().enumerate() {
                let (db, doff) = t.locate(j).expect("kept slot stays mapped");
                if (sb, so) != (db, doff) {
                    moves.push(RowMove {
                        src_block: sb,
                        src_off: so,
                        dst_block: db,
                        dst_off: doff,
                    });
                }
            }
        }
        (evicted, freed)
    }

    /// [`apply_keep_pooled_moves`](Self::apply_keep_pooled_moves) that also
    /// reports every *evicted* row as a demotion candidate: its
    /// pre-compaction arena location plus a clone of its observation record
    /// (the TS/MRI history the promotion pass scores). Entries are appended
    /// in slot order, so rows from the same source block are contiguous —
    /// the caller groups them into one host-tier entry per block. The
    /// caller MUST read (swap out) the demoted bytes before applying the
    /// `RowMove` list or allocating from the pool: compaction moves and
    /// block reuse are exactly what invalidates those locations.
    pub fn apply_keep_pooled_demote(
        &mut self,
        keep: &[u32],
        step: u32,
        pool: &mut BlockPool,
        moves: &mut Vec<RowMove>,
        demoted: &mut Vec<(BlockId, usize, TokenRecord)>,
    ) -> (Vec<u32>, usize) {
        if let Some(t) = self.block_table.as_ref() {
            let mut kept = vec![false; self.records.len()];
            for &k in keep {
                kept[k as usize] = true;
            }
            for (slot, r) in self.records.iter().enumerate() {
                if !kept[slot] {
                    let (b, o) = t.locate(slot).expect("live slot is mapped");
                    demoted.push((b, o, r.clone()));
                }
            }
        }
        self.apply_keep_pooled_moves(keep, step, pool, moves)
    }

    /// Tracker snapshot for recompute-mode preemption: hand the live
    /// records (keep-set, in slot order) to the caller. The per-record
    /// TS/MRI/attention history is the observation state the paper's lagged
    /// eviction depends on — a preempted row carries it across the re-queue
    /// round trip instead of losing it to re-initialization.
    pub fn take_records(&mut self) -> Vec<TokenRecord> {
        std::mem::take(&mut self.records)
    }

    /// Tracker restore for recompute-mode resume: map one paged slot per
    /// record (block-at-a-time, like `push_pooled` — a fresh table never
    /// CoWs), then install the records verbatim. No tracker field is
    /// re-initialized. Returns `false` when the pool cannot cover the
    /// mapping; the caller releases the partially grown table and retries
    /// once capacity returns (records stay with the caller untouched —
    /// they were not consumed).
    pub fn restore_pooled(&mut self, recs: &[TokenRecord], pool: &mut BlockPool) -> bool {
        assert!(
            self.records.is_empty(),
            "restore into a non-empty sequence"
        );
        assert!(
            recs.len() <= self.capacity,
            "restore overflow: {} records, capacity {}",
            recs.len(),
            self.capacity
        );
        if let Some(t) = self.block_table.as_mut() {
            while t.len() < recs.len() {
                if !t.push_token(pool) {
                    return false;
                }
            }
        }
        self.records = recs.to_vec();
        self.peak_live = self.peak_live.max(self.records.len());
        true
    }

    /// Return every held block to the pool (sequence finished or preempted).
    pub fn release_blocks(&mut self, pool: &mut BlockPool) -> usize {
        match self.block_table.as_mut() {
            Some(t) => t.release_all(pool),
            None => 0,
        }
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.records.len() >= self.capacity
    }

    pub fn records(&self) -> &[TokenRecord] {
        &self.records
    }

    pub fn records_mut(&mut self) -> &mut [TokenRecord] {
        &mut self.records
    }

    /// Append a token at the next free slot; returns its slot index.
    pub fn push(&mut self, rec: TokenRecord) -> usize {
        assert!(
            self.records.len() < self.capacity,
            "SeqKv overflow: len {} == capacity {}",
            self.records.len(),
            self.capacity
        );
        self.records.push(rec);
        self.peak_live = self.peak_live.max(self.records.len());
        self.records.len() - 1
    }

    /// Apply a keep-set (slot indices into the current layout, any order).
    /// Records are reordered to match; the same list must be fed to the
    /// device `gather`. Returns the evicted positions.
    pub fn apply_keep(&mut self, keep: &[u32], step: u32) -> Vec<u32> {
        debug_assert!(keep.len() <= self.records.len());
        let mut kept_flags = vec![false; self.records.len()];
        let mut new_records = Vec::with_capacity(keep.len());
        for &slot in keep {
            let slot = slot as usize;
            assert!(slot < self.records.len(), "keep index {slot} out of range");
            assert!(!kept_flags[slot], "duplicate keep index {slot}");
            kept_flags[slot] = true;
            new_records.push(self.records[slot].clone());
        }
        let mut evicted = Vec::new();
        for (slot, kept) in kept_flags.iter().enumerate() {
            if !kept {
                evicted.push(self.records[slot].pos);
                if self.log_evictions {
                    self.evictions.push(Eviction {
                        step,
                        pos: self.records[slot].pos,
                    });
                }
            }
        }
        self.records = new_records;
        evicted
    }

    /// Build the device gather index vector: keep-list followed by identity
    /// padding (slot values past `len` are never read thanks to the mask).
    pub fn gather_indices(&self, keep: &[u32]) -> Vec<i32> {
        let mut idx: Vec<i32> = keep.iter().map(|&k| k as i32).collect();
        let mut fill = keep.len();
        while idx.len() < self.capacity {
            idx.push(fill as i32 % self.capacity as i32);
            fill += 1;
        }
        idx
    }

    /// Slot mask for the step executable: 1.0 for live slots [0, len).
    pub fn slot_mask(&self, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.capacity);
        let n = self.records.len();
        out[..n].fill(1.0);
        out[n..].fill(0.0);
    }

    /// Does the live set contain this absolute position?
    pub fn contains_pos(&self, pos: u32) -> bool {
        self.records.iter().any(|r| r.pos == pos)
    }

    pub fn clear(&mut self) {
        self.records.clear();
        self.evictions.clear();
        self.peak_live = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_with(n: usize) -> SeqKv {
        let mut s = SeqKv::new(16);
        for i in 0..n {
            s.push(TokenRecord::new(i as u32, i as u32));
        }
        s
    }

    #[test]
    fn push_assigns_sequential_slots() {
        let s = seq_with(5);
        assert_eq!(s.len(), 5);
        assert_eq!(s.records()[3].pos, 3);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn push_over_capacity_panics() {
        let mut s = SeqKv::new(2);
        s.push(TokenRecord::new(0, 0));
        s.push(TokenRecord::new(1, 1));
        s.push(TokenRecord::new(2, 2));
    }

    #[test]
    fn apply_keep_compacts_in_order() {
        let mut s = seq_with(6);
        let evicted = s.apply_keep(&[5, 0, 3], 10);
        assert_eq!(s.len(), 3);
        assert_eq!(
            s.records().iter().map(|r| r.pos).collect::<Vec<_>>(),
            vec![5, 0, 3]
        );
        assert_eq!(evicted, vec![1, 2, 4]);
    }

    #[test]
    fn eviction_log() {
        let mut s = seq_with(4);
        s.log_evictions = true;
        s.apply_keep(&[0, 1], 9);
        assert_eq!(s.evictions.len(), 2);
        assert_eq!(s.evictions[0].step, 9);
    }

    #[test]
    #[should_panic(expected = "duplicate")]
    fn duplicate_keep_rejected() {
        let mut s = seq_with(4);
        s.apply_keep(&[1, 1], 0);
    }

    #[test]
    fn gather_indices_padded() {
        let s = seq_with(6);
        let idx = s.gather_indices(&[5, 0, 3]);
        assert_eq!(idx.len(), 16);
        assert_eq!(&idx[..3], &[5, 0, 3]);
        assert_eq!(idx[3], 3); // identity-ish padding
    }

    #[test]
    fn slot_mask_matches_len() {
        let s = seq_with(4);
        let mut m = vec![9.0; 16];
        s.slot_mask(&mut m);
        assert_eq!(&m[..5], &[1.0, 1.0, 1.0, 1.0, 0.0]);
        assert!(m[5..].iter().all(|&x| x == 0.0));
    }

    fn pooled_pair() -> (SeqKv, crate::kvpool::BlockPool) {
        use crate::kvpool::{BlockPool, BlockTable, PoolConfig};
        let pool = BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap();
        let mut s = SeqKv::new(32);
        s.attach_block_table(BlockTable::new(pool.block_size()));
        (s, pool)
    }

    #[test]
    fn pooled_push_grows_blocks_in_lockstep() {
        let (mut s, mut pool) = pooled_pair();
        for i in 0..9 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        let t = s.block_table().unwrap();
        assert_eq!(t.len(), s.len());
        assert_eq!(t.n_blocks(), 3);
        assert_eq!(pool.used_blocks(), 3);
        assert!(!s.needs_block_for_next(&pool)); // 9 < 12
        for i in 9..12 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        assert!(s.needs_block_for_next(&pool));
    }

    #[test]
    fn prefix_fork_premaps_prompt_slots() {
        use crate::kvpool::BlockTable;
        let (mut donor, mut pool) = pooled_pair();
        for i in 0..8 {
            donor.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        assert_eq!(pool.used_blocks(), 2);
        // fork the donor's 2 whole blocks into a new sequence
        let fork = BlockTable::fork_prefix(donor.block_table().unwrap(), 8, &mut pool);
        let mut s = SeqKv::new(32);
        s.attach_block_table(fork);
        assert!(!s.needs_block_for_next(&pool));
        // the first 8 records consume premapped slots: no allocation
        for i in 0..8 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        assert_eq!(pool.used_blocks(), 2, "shared prefix allocated nothing");
        assert_eq!(s.block_table().unwrap().len(), 8);
        // caught up: the 9th record grows the table privately again
        assert!(s.needs_block_for_next(&pool));
        s.push_pooled(TokenRecord::new(8, 8), &mut pool).unwrap();
        assert_eq!(pool.used_blocks(), 3);
        assert_eq!(s.block_table().unwrap().len(), 9);
        // CoW before compaction: the shared prefix becomes private
        assert_eq!(s.block_table().unwrap().n_shared_blocks(&pool), 2);
        assert!(s.make_private(&mut pool));
        assert_eq!(s.block_table().unwrap().n_shared_blocks(&pool), 0);
        assert_eq!(pool.used_blocks(), 5);
        s.release_blocks(&mut pool);
        donor.release_blocks(&mut pool);
        assert_eq!(pool.free_blocks(), 8);
    }

    #[test]
    fn pooled_apply_keep_frees_whole_blocks() {
        let (mut s, mut pool) = pooled_pair();
        for i in 0..16 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        assert_eq!(pool.used_blocks(), 4);
        let keep: Vec<u32> = (0..5).collect();
        let (evicted, freed) = s.apply_keep_pooled(&keep, 20, &mut pool);
        assert_eq!(evicted.len(), 11);
        assert_eq!(freed, 2); // 5 tokens still need 2 blocks
        assert_eq!(s.block_table().unwrap().len(), 5);
        assert_eq!(pool.used_blocks(), 2);
        // block table stays consistent with the compacted layout
        assert_eq!(s.block_table().unwrap().locate(4).unwrap().1, 0);
        assert!(s.block_table().unwrap().locate(5).is_none());
    }

    #[test]
    fn pooled_apply_keep_reports_row_moves() {
        let (mut s, mut pool) = pooled_pair(); // block_size 4
        for i in 0..16 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        let t = s.block_table().unwrap();
        let (b0, b1, b3) = (t.blocks()[0], t.blocks()[1], t.blocks()[3]);
        let keep = vec![0u32, 5, 14];
        let mut moves = Vec::new();
        let (evicted, freed) = s.apply_keep_pooled_moves(&keep, 20, &mut pool, &mut moves);
        assert_eq!(evicted.len(), 13);
        assert_eq!(freed, 3); // 3 survivors need 1 block
        // slot 0 stays put (identity skipped); 5 → slot 1, 14 → slot 2
        assert_eq!(
            moves,
            vec![
                crate::kvpool::RowMove {
                    src_block: b1,
                    src_off: 1,
                    dst_block: b0,
                    dst_off: 1
                },
                crate::kvpool::RowMove {
                    src_block: b3,
                    src_off: 2,
                    dst_block: b0,
                    dst_off: 2
                },
            ]
        );
    }

    #[test]
    fn pooled_apply_keep_demote_reports_evicted_rows_in_slot_order() {
        let (mut s, mut pool) = pooled_pair(); // block_size 4
        for i in 0..16 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        let t = s.block_table().unwrap();
        let (b0, b1) = (t.blocks()[0], t.blocks()[1]);
        let keep = vec![0u32, 5, 14];
        let mut moves = Vec::new();
        let mut demoted = Vec::new();
        let (evicted, freed) =
            s.apply_keep_pooled_demote(&keep, 20, &mut pool, &mut moves, &mut demoted);
        assert_eq!(evicted.len(), 13);
        assert_eq!(freed, 3);
        assert_eq!(demoted.len(), 13, "every evicted row is a demotion candidate");
        // slot order ⇒ same-block entries contiguous, offsets ascending
        assert_eq!(demoted[0].0, b0);
        assert_eq!(demoted[0].1, 1); // slot 1 (slot 0 kept)
        assert_eq!(demoted[0].2.pos, 1);
        assert_eq!((demoted[2].0, demoted[2].1, demoted[2].2.pos), (b0, 3, 3));
        assert_eq!(demoted[3].0, b1);
        assert_eq!(demoted[3].1, 0); // slot 4 (slot 5 kept)
        assert_eq!(demoted[3].2.pos, 4);
        for w in demoted.windows(2) {
            let same_block = w[0].0 == w[1].0;
            assert!(
                !same_block || w[0].1 < w[1].1,
                "offsets must ascend within a block"
            );
        }
        // the move list is unchanged by the demote reporting
        assert_eq!(moves.len(), 2);
    }

    #[test]
    fn pooled_push_fails_cleanly_on_exhaustion() {
        use crate::kvpool::{BlockPool, BlockTable, PoolConfig};
        let mut pool = BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: 1,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap();
        let mut s = SeqKv::new(32);
        s.attach_block_table(BlockTable::new(4));
        for i in 0..4 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        assert!(s.push_pooled(TokenRecord::new(4, 4), &mut pool).is_none());
        assert_eq!(s.len(), 4); // record count untouched by the failed push
        assert_eq!(s.release_blocks(&mut pool), 1);
        assert_eq!(pool.free_blocks(), 1);
    }

    #[test]
    fn unpooled_seq_ignores_pool_ops() {
        use crate::kvpool::{BlockPool, PoolConfig};
        let mut pool = BlockPool::new(PoolConfig::default()).unwrap();
        let mut s = seq_with(6);
        assert!(!s.needs_block_for_next(&pool));
        let (evicted, freed) = s.apply_keep_pooled(&[0, 1], 9, &mut pool);
        assert_eq!(evicted.len(), 4);
        assert_eq!(freed, 0);
        assert_eq!(s.release_blocks(&mut pool), 0);
    }

    #[test]
    fn take_and_restore_round_trip_preserves_tracker_state() {
        let (mut s, mut pool) = pooled_pair();
        for i in 0..9 {
            s.push_pooled(TokenRecord::new(i, i), &mut pool).unwrap();
        }
        // accumulate non-trivial tracker state, then evict to a keep-set
        for r in s.records_mut() {
            r.ts = r.pos + 3;
            r.mri = 7;
            r.cum_attn = 0.5;
            r.hits = 2;
        }
        s.apply_keep_pooled(&[8, 0, 5], 12, &mut pool);
        let snapshot = s.take_records();
        assert_eq!(snapshot.len(), 3);
        assert!(s.is_empty());
        s.release_blocks(&mut pool);
        assert_eq!(pool.free_blocks(), 8);

        // restore into a fresh pooled sequence: same order, same state
        let mut s2 = SeqKv::new(32);
        s2.attach_block_table(crate::kvpool::BlockTable::new(pool.block_size()));
        assert!(s2.restore_pooled(&snapshot, &mut pool));
        assert_eq!(s2.len(), 3);
        assert_eq!(s2.block_table().unwrap().len(), 3);
        assert_eq!(pool.used_blocks(), 1);
        for (a, b) in snapshot.iter().zip(s2.records().iter()) {
            assert_eq!(a.pos, b.pos);
            assert_eq!(a.ts, b.ts);
            assert_eq!(a.mri, b.mri);
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.cum_attn, b.cum_attn);
        }
    }

    #[test]
    fn restore_pooled_fails_cleanly_on_exhaustion() {
        use crate::kvpool::{BlockPool, BlockTable, PoolConfig};
        let mut pool = BlockPool::new(PoolConfig {
            block_size: 4,
            n_blocks: 1,
            low_watermark: 0,
            high_watermark: 0,
        })
        .unwrap();
        let recs: Vec<TokenRecord> = (0..6).map(|i| TokenRecord::new(i, i)).collect();
        let mut s = SeqKv::new(32);
        s.attach_block_table(BlockTable::new(4));
        assert!(!s.restore_pooled(&recs, &mut pool));
        assert!(s.is_empty(), "failed restore must not install records");
        // caller releases the partially grown table
        assert_eq!(s.release_blocks(&mut pool), 1);
        assert_eq!(pool.free_blocks(), 1);
    }

    #[test]
    fn peak_live_tracks_high_water() {
        let mut s = seq_with(6);
        s.apply_keep(&[0, 1], 0);
        assert_eq!(s.peak_live, 6);
        for i in 6..9 {
            s.push(TokenRecord::new(i, i));
        }
        assert_eq!(s.peak_live, 6.max(5));
    }
}
