//! # LazyEviction — lagged KV eviction for efficient long reasoning
//!
//! A three-layer serving stack reproducing *LazyEviction: Lagged KV Eviction
//! with Attention Pattern Observation for Efficient Long Reasoning*
//! (ACL 2026): a Rust request coordinator (this crate) drives AOT-compiled
//! JAX/Pallas model executables through PJRT, with the paper's
//! observation-window lagged KV eviction (plus all of its baselines) as a
//! first-class pluggable policy.
//!
//! Layer map (DESIGN.md §2):
//! * [`runtime`] — PJRT client, artifact manifest, device-resident executor,
//!   and the `DecodeBackend` abstraction (PJRT or the artifact-free sim)
//! * [`kvcache`] + [`attention`] — slot records, TS/MRI tracking (Eq. 1)
//! * [`kvpool`] — shared paged-KV block pool: refcounted fixed-size blocks,
//!   per-sequence block tables, pressure watermarks (admission/preemption),
//!   and the physical side — pool-shaped K/V arenas + prompt-prefix cache
//!   whose full-prompt hits skip prefill outright (see ARCHITECTURE.md)
//! * [`kvtier`] — host-memory spill tier under the pool: eviction demotes
//!   blocks instead of destroying them, recurrence promotes them back, and
//!   preemption can swap a whole row out/in instead of recomputing it
//! * [`eviction`] — LazyEviction (Eq. 2/5) and baselines
//! * [`scheduler`] + [`coordinator`] + [`server`] — continuous batching
//!   with pool-pressure admission control, decode loop with youngest-row
//!   preemption, TCP front-end
//! * [`trace`] + [`sim`] — synthetic TIR workloads, trace-driven replay,
//!   fidelity/accuracy metrics for the paper's tables, and pool-capacity
//!   replay (effective batch under a fixed global block budget)
//! * [`bench_harness`] — table/figure regeneration harness
//! * [`analysis`] — `lazylint`, the repo's own static-analysis pass: the
//!   contracts the layers above rely on (deterministic failure routing,
//!   doc/metric/flag parity, replay determinism, the bench-report schema)
//!   enforced mechanically; its runtime counterpart is [`kvpool::audit`]
//! * [`util`] — offline substrate (JSON, RNG, stats, CLI)

// The whole stack is safe Rust; the only unsafe in the tree lives in the
// vendored PJRT shim crates (separate crates, so this attribute does not
// reach them). Enforced here rather than linted so a violation is a
// compile error, not a finding.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod attention;
pub mod bench_harness;
pub mod coordinator;
pub mod eviction;
pub mod kvcache;
pub mod kvpool;
pub mod kvtier;
pub mod metrics;
pub mod runtime;
pub mod scheduler;
pub mod server;
pub mod sim;
pub mod telemetry;
pub mod tokenizer;
pub mod trace;
pub mod util;
