//! The decode-loop engine: continuous batching over a fixed-row executable,
//! TS/MRI tracking from the step's exported attention, and lagged/greedy KV
//! eviction compiled down to device-side gathers. This is the request path —
//! no Python, no model code, just backend executions orchestrated from Rust.
//!
//! The engine drives any [`DecodeBackend`] (the PJRT `ModelExecutor`, or the
//! artifact-free `SimBackend` via [`Engine::new_sim`]). With a
//! `kvpool::PoolConfig` in the engine config, rows stop assuming dedicated
//! capacity and instead allocate KV blocks from a shared pool:
//!
//! * `submit` consults the prompt-prefix cache first: an identical prompt
//!   header forks the donor's whole blocks for free, and admission only has
//!   to cover the *private* remainder (+1 headroom block) — stale cache
//!   pins are shed LRU-first before a request is declined;
//! * before each decode step the engine ensures every active row can map
//!   one more token; if the pool is dry it sheds cache pins, then
//!   **preempts the youngest row** (highest admission ticket): blocks are
//!   returned and the request is handed back via [`Engine::take_preempted`]
//!   (oldest victim first) carrying a full decode-state snapshot, so its
//!   re-admission **resumes** the row — one batched recompute prefill of
//!   prompt + generated tokens, tracker records restored verbatim —
//!   byte-identical to a never-preempted run (vLLM-style recompute mode);
//! * the eviction pass privatizes a row's shared blocks (copy-on-write)
//!   before compacting, so a donor's mapping is never mutated, and
//!   (`apply_keep_pooled_moves`) returns whole freed blocks to the pool —
//!   lagged eviction becomes cross-sequence capacity.
//!
//! With a pool the paging is *physical*: `init_paged` swaps the backend's
//! per-row worst-case `[B, L, H, S, dh]` caches for pool-shaped block
//! arenas, prefill/decode K/V rows are written through each row's block
//! table, the decode step gathers context via `step_paged`, CoW duplicates
//! real bytes (`copy_block`) and compaction relocates them
//! (`gather_kv_rows`). A full-prompt prefix-cache hit therefore skips the
//! prefill executable entirely: the donor's blocks *are* the prompt K/V,
//! and the entry's [`PrefillSeed`] supplies the tail rows, tracker seed and
//! first prediction (disabled under `collect_sketches`, which needs the
//! prompt keys host-side). Ordering contract with the backend: CoW copies
//! are applied before the next row write, compaction moves before the next
//! pool allocation — and, with a host tier, demotion swap-outs before the
//! moves land (see `kvtier` for the demotion/promotion/swap lifecycle the
//! engine drives on top of this).

use std::collections::HashMap;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::attention::{observe, TrackerConfig};
use crate::coordinator::row::RowState;
use crate::coordinator::{
    EngineConfig, PreemptMode, PreemptedState, Request, Response, TokenEvent,
};
use crate::eviction::observatory::RecurrenceObservatory;
use crate::eviction::score::importance;
use crate::eviction::{self, Policy};
use crate::kvcache::TokenRecord;
use crate::kvpool::{
    BlockCopy, BlockId, BlockPool, BlockTable, PoolPressure, PrefillSeed, PrefixCache, RowMove,
};
use crate::kvtier::{HostTier, ParkedEntry, SwappedBlock, TierBlockId};
use crate::metrics::{EngineMetrics, PoolGauges, RequestMetrics};
use crate::runtime::{Client, DecodeBackend, Manifest, ModelExecutor, SimBackend};
use crate::telemetry::{event, span, SpanContext};
use crate::tokenizer::Tokenizer;

pub struct Engine {
    pub cfg: EngineConfig,
    exec: Box<dyn DecodeBackend>,
    pub tokenizer: Tokenizer,
    policy: Box<dyn Policy>,
    rows: Vec<Option<RowState>>,
    /// Shared block pool (present iff cfg.pool is set).
    pool: Option<BlockPool>,
    /// Prompt-prefix cache (present iff pool + cfg.prefix_cache are set).
    prefix_cache: Option<PrefixCache>,
    /// Host spill tier (present iff pool + cfg.host_tier are set): parked
    /// evicted blocks awaiting promotion, and swap-preempted tables.
    tier: Option<HostTier>,
    /// Requests preempted since the last `take_preempted` drain, each
    /// tagged with the victim row's admission ticket so the drain can hand
    /// them back oldest-first.
    preempted: Vec<(u64, Request)>,
    /// Next admission ticket (monotone; youngest row = max ticket).
    admit_seq: u64,
    pub metrics: EngineMetrics,
    /// Shared telemetry sink (serve mode): flight events are recorded at
    /// each lifecycle point, and `publish_telemetry` pushes registry
    /// snapshots. `None` costs nothing on any hot path.
    telemetry: Option<std::sync::Arc<crate::telemetry::Telemetry>>,
    /// Fleet identity: set by the actor wrapper when this engine is one of
    /// N replicas sharing a registry. Decorates every published metric
    /// with a `{replica="i"}` label; `None` (single-engine) publishes the
    /// exact unlabeled names PRs 6–7 established.
    replica: Option<usize>,
    vocab: usize,
    /// Max blocks a row's table can hold (paged staging width).
    blocks_per_row: usize,
    // staging buffers reused across steps (no per-step allocation)
    mask_buf: Vec<f32>,
    tok_buf: Vec<i32>,
    pos_buf: Vec<i32>,
    idx_buf: Vec<i32>,
    gather_buf: Vec<i32>,
    /// Paged staging: flattened `[B, blocks_per_row]` block tables + lens.
    tbl_buf: Vec<i32>,
    len_buf: Vec<i32>,
    /// Pending physical CoW copies / compaction moves (drained to the
    /// backend immediately after the logical op that produced them).
    copy_buf: Vec<BlockCopy>,
    move_buf: Vec<RowMove>,
    /// Demotion staging: the eviction pass's evicted rows — pre-compaction
    /// arena location + frozen record — swapped out to the tier before the
    /// compaction moves invalidate those locations.
    demote_buf: Vec<(BlockId, usize, TokenRecord)>,
    /// Tokens decoded since the last `drain_token_events` call, in
    /// production order. The serve loop drains these every iteration to
    /// feed streaming clients; `run_all` drains them per step so the
    /// buffer stays bounded in batch runs too.
    token_events: Vec<TokenEvent>,
    /// Trace contexts noted via [`Engine::note_span`] before submission:
    /// request id → the root-span link every engine-side span nests under.
    /// Entries are removed at finish/abort; preempted requests keep theirs
    /// for the resume round trip.
    span_ctxs: HashMap<u64, SpanContext>,
    /// Open `preempt` round-trip span per preempted request id, closed when
    /// the request is re-admitted (resume) or discarded. A request orphaned
    /// to another replica leaves its entry behind; the bounded open-span
    /// ring in the recorder absorbs the leak.
    preempt_spans: HashMap<u64, u64>,
    /// Recurrence observatory (present iff `cfg.observe_recurrence`):
    /// records eviction-pass decisions and promotion outcomes. Strictly
    /// read-only over decode state — output is byte-identical either way.
    recurrence: Option<RecurrenceObservatory>,
}

impl Engine {
    /// Real-model engine over compiled PJRT artifacts.
    pub fn new(client: &Client, manifest: &Manifest, cfg: EngineConfig) -> Result<Engine> {
        let exec = ModelExecutor::new(client, manifest, cfg.batch, cfg.cache)
            .context("building executor")?;
        Engine::with_backend(Box::new(exec), &manifest.charset, cfg)
    }

    /// Artifact-free engine over the deterministic sim backend — the same
    /// decode loop, eviction policies, pool and server, no PJRT required.
    pub fn new_sim(cfg: EngineConfig) -> Result<Engine> {
        let exec = SimBackend::new(cfg.batch, cfg.cache);
        let charset = exec.charset();
        Engine::with_backend(Box::new(exec), charset, cfg)
    }

    /// Engine over any backend (the two constructors above delegate here).
    /// With a pool configured, the backend is switched to physical paging
    /// here — before any request touches it.
    pub fn with_backend(
        mut exec: Box<dyn DecodeBackend>,
        charset: &str,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        cfg.validate()?;
        let tokenizer = Tokenizer::new(charset);
        let policy = eviction::build(&cfg.policy, &cfg.params)?;
        let pool = match &cfg.pool {
            Some(pc) => Some(BlockPool::new(pc.clone())?),
            None => None,
        };
        let mut blocks_per_row = 0;
        if let Some(p) = &pool {
            exec.init_paged(p.total_blocks(), p.block_size())
                .context("switching backend to paged KV")?;
            blocks_per_row = p.blocks_for(cfg.cache);
        }
        let prefix_cache = match (&pool, &cfg.prefix_cache) {
            (Some(_), Some(pc)) => Some(PrefixCache::new(pc.clone())),
            _ => None,
        };
        let tier = match (&pool, &cfg.host_tier) {
            (Some(_), Some(tc)) => Some(HostTier::new(tc.max_bytes)),
            _ => None,
        };
        let (b, s) = (cfg.batch, cfg.cache);
        Ok(Engine {
            vocab: exec.dims().vocab,
            tokenizer,
            policy,
            rows: (0..b).map(|_| None).collect(),
            pool,
            prefix_cache,
            tier,
            preempted: Vec::new(),
            admit_seq: 0,
            metrics: EngineMetrics::default(),
            telemetry: None,
            replica: None,
            blocks_per_row,
            mask_buf: vec![0.0; b * s],
            tok_buf: vec![0; b],
            pos_buf: vec![0; b],
            idx_buf: vec![0; b],
            gather_buf: vec![0; b * s],
            tbl_buf: vec![-1; b * blocks_per_row],
            len_buf: vec![0; b],
            copy_buf: Vec::new(),
            move_buf: Vec::new(),
            demote_buf: Vec::new(),
            token_events: Vec::new(),
            span_ctxs: HashMap::new(),
            preempt_spans: HashMap::new(),
            recurrence: cfg.observe_recurrence.then(RecurrenceObservatory::new),
            exec,
            cfg,
        })
    }

    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    pub fn active(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }

    pub fn has_free_row(&self) -> bool {
        self.rows.iter().any(|r| r.is_none())
    }

    pub fn exec_counts(&self) -> crate::runtime::executor::ExecCounts {
        self.exec.exec_counts()
    }

    /// Pool watermark signal for the scheduler's admission controller.
    pub fn pool_pressure(&self) -> Option<PoolPressure> {
        self.pool.as_ref().map(|p| p.pressure())
    }

    /// Pool gauges for metrics export / server responses.
    pub fn pool_gauges(&self) -> Option<PoolGauges> {
        self.pool.as_ref().map(|p| {
            // physical bytes: the whole arena, and the live-block share
            let kv_arena_bytes = self.exec.device_cache_bytes();
            let block_bytes = if p.total_blocks() == 0 {
                0
            } else {
                kv_arena_bytes / p.total_blocks()
            };
            let mut g = PoolGauges {
                free_blocks: p.free_blocks(),
                total_blocks: p.total_blocks(),
                utilization: p.utilization(),
                preemptions: self.metrics.preemptions,
                resumes: self.metrics.resumes,
                recomputed_tokens: self.metrics.recomputed_tokens,
                shared_blocks: p.shared_blocks(),
                kv_arena_bytes,
                kv_bytes_in_use: p.used_blocks() * block_bytes,
                ..PoolGauges::default()
            };
            if let Some(pc) = &self.prefix_cache {
                g.prefix_hits = pc.hits;
                g.prefix_misses = pc.misses;
                g.prefix_entries = pc.len();
                g.prefix_pinned_blocks = pc.pinned_blocks();
                g.prefix_prefill_skips = self.metrics.prefill_skips;
            }
            if let Some(t) = &self.tier {
                g.parked_blocks = t.parked_blocks();
                g.parked_bytes = t.bytes_in_use();
                g.demoted_blocks = self.metrics.demoted_blocks;
                g.promotions = self.metrics.promotions;
                g.false_evictions_avoided = self.metrics.false_evictions_avoided;
                g.swap_out_bytes = self.metrics.swap_out_bytes;
                g.swap_in_bytes = self.metrics.swap_in_bytes;
                g.swap_preempts = self.metrics.swap_preempts;
                g.tier_shed_blocks = t.shed_blocks;
            }
            // refused parks can also come from swap-mode preemptions, so
            // export unconditionally (0 without a tier)
            g.tier_rejects = self.metrics.tier_rejects;
            g
        })
    }

    /// Attach a shared telemetry handle: from here on the engine records
    /// flight events at every request-lifecycle point and
    /// `publish_telemetry` pushes registry snapshots.
    pub fn attach_telemetry(&mut self, t: std::sync::Arc<crate::telemetry::Telemetry>) {
        self.telemetry = Some(t);
    }

    /// The attached telemetry handle, if any (the fleet pump shares it).
    pub fn telemetry(&self) -> Option<&std::sync::Arc<crate::telemetry::Telemetry>> {
        self.telemetry.as_ref()
    }

    /// Mark this engine as replica `r` of a fleet: every metric published
    /// from here on carries a `{replica="r"}` label so N engines can share
    /// one registry without clobbering each other.
    pub fn set_replica_label(&mut self, r: usize) {
        self.replica = Some(r);
    }

    pub fn replica(&self) -> Option<usize> {
        self.replica
    }

    /// The prefix cache's routing digest (sorted whole-block header
    /// hashes), or empty without a cache. The fleet actor exports this to
    /// its published status each iteration; the router probes request
    /// header hashes against it.
    pub fn prefix_digest(&self) -> Vec<u64> {
        self.prefix_cache
            .as_ref()
            .map(|c| c.digest())
            .unwrap_or_default()
    }

    fn tele_event(
        &self,
        req: u64,
        event: &'static str,
        step: usize,
        live: usize,
        detail: f64,
        note: &'static str,
    ) {
        if let Some(t) = &self.telemetry {
            t.record(req, event, step, live, detail, note);
        }
    }

    /// Note request `id`'s trace context before its submit: every
    /// engine-side span (prefill, decode windows, eviction passes,
    /// demote/promote/swap) for that request links under `ctx`. A default
    /// (off) context clears any stale entry. The actor forwards this from
    /// the queued request; callers that never trace never call it.
    pub fn note_span(&mut self, id: u64, ctx: SpanContext) {
        if ctx.is_off() {
            self.span_ctxs.remove(&id);
        } else {
            self.span_ctxs.insert(id, ctx);
        }
    }

    /// The recurrence observatory, present iff `cfg.observe_recurrence`.
    pub fn recurrence(&self) -> Option<&RecurrenceObservatory> {
        self.recurrence.as_ref()
    }

    /// Open a span under `ctx`; 0 (a no-op id for [`Engine::span_close`])
    /// when tracing is off for this request or no telemetry is attached.
    fn span_open(
        &self,
        req: u64,
        name: &'static str,
        ctx: SpanContext,
        detail: f64,
        note: &'static str,
    ) -> u64 {
        if ctx.is_off() {
            return 0;
        }
        match &self.telemetry {
            Some(t) => t.span_open(req, name, ctx, self.replica, detail, note),
            None => 0,
        }
    }

    /// Close a span opened by [`Engine::span_open`] (no-op for id 0).
    fn span_close(&self, id: u64, detail: Option<f64>, note: Option<&'static str>) {
        if id == 0 {
            return;
        }
        if let Some(t) = &self.telemetry {
            t.span_close_full(id, detail, note, false);
        }
    }

    /// Close the `preempt` round-trip span for `rid`, if one is open. The
    /// note records how the round trip ended (resume mode or discard).
    fn close_preempt_span(&mut self, rid: u64, note: &'static str) {
        if let Some(sid) = self.preempt_spans.remove(&rid) {
            self.span_close(sid, None, Some(note));
        }
    }

    /// Push counter/gauge/histogram snapshots into the attached registry.
    /// No-op without telemetry; called by the serve loop each iteration so
    /// scrapers read fresh values without touching engine state.
    pub fn publish_telemetry(&self) {
        use crate::telemetry::names;
        let Some(t) = &self.telemetry else { return };
        let reg = &t.registry;
        let m = &self.metrics;
        // fleet replicas decorate every name; single-engine keeps the
        // exact unlabeled names existing scrapers and tests assert on
        let key = |n: &str| match self.replica {
            Some(r) => crate::telemetry::labeled(n, "replica", r),
            None => n.to_string(),
        };
        reg.set_counter(&key(names::TOKENS_OUT), m.tokens_out);
        reg.set_counter(&key(names::STEPS), m.steps);
        reg.set_counter(&key(names::REQUESTS_FINISHED), m.requests_finished);
        reg.set_counter(&key("lazyeviction_eviction_passes_total"), m.eviction_count);
        reg.set_counter(&key("lazyeviction_prefill_skips_total"), m.prefill_skips);
        reg.set_counter(&key("lazyeviction_resume_fallbacks_total"), m.resume_fallbacks);
        reg.set_counter(&key(names::STREAMED_TOKENS), m.streamed_tokens);
        reg.set_counter(&key(names::CANCELLED_ROWS), m.cancelled_rows);
        reg.set_gauge(&key("lazyeviction_active_rows"), self.active() as f64);
        reg.set_gauge(&key("lazyeviction_batch_rows"), self.cfg.batch as f64);
        reg.set_gauge(&key("lazyeviction_throughput_tokens_per_s"), m.throughput());
        reg.set_histogram(&key(names::STEP_LATENCY_MS), &m.step_hist_ms);
        reg.set_histogram(&key(names::PREFILL_LATENCY_MS), &m.prefill_hist_ms);
        reg.set_histogram(&key(names::TTFT_MS), &m.ttft_hist_ms);
        reg.set_histogram(&key(names::TPOT_MS), &m.tpot_hist_ms);
        reg.set_histogram(&key(names::QUEUE_WAIT_MS), &m.queue_wait_hist_ms);
        reg.set_histogram(&key(names::EVICTION_PASS_MS), &m.evict_hist_ms);
        reg.set_histogram(&key(names::LIVE_TOKENS), &m.live_hist);
        if let Some(g) = self.pool_gauges() {
            match self.replica {
                Some(r) => g.publish_labeled(reg, r),
                None => g.publish(reg),
            }
        }
        if let Some(obs) = &self.recurrence {
            use crate::eviction::observatory::POSTMORTEM_LABELS;
            reg.set_counter(&key("lazyeviction_recurrence_passes_total"), obs.passes_total);
            reg.set_counter(
                &key("lazyeviction_recurrence_decisions_total"),
                obs.decisions_total,
            );
            reg.set_histogram(&key("lazyeviction_recurrence_mri"), &obs.mri_hist);
            reg.set_histogram(
                &key("lazyeviction_time_to_promotion_steps"),
                &obs.promotion_hist,
            );
            for (label, &count) in POSTMORTEM_LABELS.iter().zip(obs.postmortem.iter()) {
                let k = match self.replica {
                    // two labels: render_prometheus groups on the base name
                    // before '{', so the composite key stays one family
                    Some(r) => format!(
                        "lazyeviction_false_eviction_postmortem_total{{parked_steps=\"{label}\",replica=\"{r}\"}}"
                    ),
                    None => crate::telemetry::labeled(
                        "lazyeviction_false_eviction_postmortem_total",
                        "parked_steps",
                        label,
                    ),
                };
                reg.set_counter(&k, count);
            }
        }
        // span duration histograms share the registry with engine metrics
        t.publish_span_metrics();
    }

    /// Test/debug introspection: `(pos, block, offset)` for every live slot
    /// of row `i` (paged mode) — lets tier/e2e tests byte-compare a row's
    /// stored K/V against a control engine position by position.
    pub fn debug_row_slots(&self, i: usize) -> Option<Vec<(u32, BlockId, usize)>> {
        let row = self.rows.get(i)?.as_ref()?;
        let t = row.seq.block_table()?;
        Some(
            row.seq
                .records()
                .iter()
                .enumerate()
                .map(|(slot, r)| {
                    let (b, o) = t.locate(slot).expect("live slot is mapped");
                    (r.pos, b, o)
                })
                .collect(),
        )
    }

    /// Test/debug passthrough: the K/V bytes the backend stores at an arena
    /// location (paged mode, host-readable backends only).
    pub fn backend_kv_row(&self, block: u32, offset: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.exec.debug_kv_row(block, offset)
    }

    /// Drain pending physical CoW copies to the backend. Must run after any
    /// logical op that may have pushed into `copy_buf`, before the next
    /// K/V row write. A single copy (the common shared-tail case) goes
    /// through `copy_block`; several (multi-block privatization) are merged
    /// into one row-relocation pass — on the device backend that is one
    /// arena permute instead of one whole-arena pass per copied block.
    fn flush_block_copies(&mut self) -> Result<()> {
        match self.copy_buf.len() {
            0 => Ok(()),
            1 => {
                let c = self.copy_buf.pop().expect("len checked");
                self.exec.copy_block(c)
            }
            _ => {
                let copies = std::mem::take(&mut self.copy_buf);
                let moves: Vec<RowMove> = copies
                    .iter()
                    .flat_map(|c| {
                        (0..c.rows).map(move |r| RowMove {
                            src_block: c.src,
                            src_off: r,
                            dst_block: c.dst,
                            dst_off: r,
                        })
                    })
                    .collect();
                self.exec.gather_kv_rows(&moves)?;
                // keep the buffer's allocation across steps
                self.copy_buf = copies;
                self.copy_buf.clear();
                Ok(())
            }
        }
    }

    /// Drop every prompt-prefix cache entry, releasing its block pins
    /// (admin reset; also lets tests assert the pool drains to fully free).
    pub fn clear_prefix_cache(&mut self) {
        if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
            pc.clear(pool);
        }
    }

    /// Shed prefix-cache pins (LRU-first) until free blocks reach the
    /// pool's high watermark or the cache is empty. The serve loop calls
    /// this when admission is gated but *nothing is decoding*: with no row
    /// left to finish and free more blocks, stale pins are the only thing
    /// keeping the latch closed, and without this valve the queue would
    /// hang forever.
    pub fn shed_prefix_to_high_watermark(&mut self) {
        let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) else {
            return;
        };
        while pool.free_blocks() < pool.config().high_watermark {
            if !pc.shed_lru_reclaimable(pool) {
                break;
            }
        }
    }

    /// Drain the requests preempted since the last call, **oldest victim
    /// first** (ascending admission ticket). Each carries its
    /// [`PreemptedState`] in `Request::resume`, so re-submitting it makes
    /// the engine *resume* the row (recompute mode) rather than restart it.
    /// Callers must keep this order when re-queuing — put the whole batch
    /// at the queue front in slice order (`RequestQueue::push_front_all`);
    /// a per-request `push_front` loop would reverse it and let the
    /// youngest victim resume ahead of rows preempted before it.
    pub fn take_preempted(&mut self) -> Vec<Request> {
        let mut v = std::mem::take(&mut self.preempted);
        v.sort_by_key(|&(ticket, _)| ticket);
        v.into_iter().map(|(_, r)| r).collect()
    }

    /// Error recovery: drop every active row, returning blocks to the pool
    /// and reporting the owning request ids so the caller can fail their
    /// replies. Unlike preemption, aborted requests are NOT re-queued — the
    /// engine state behind them is unrecoverable and the client must be
    /// told, not silently retried.
    pub fn abort_rows(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        let mut closes: Vec<(u64, u32)> = Vec::new();
        for slot in self.rows.iter_mut() {
            if let Some(mut row) = slot.take() {
                if let Some(pool) = self.pool.as_mut() {
                    row.seq.release_blocks(pool);
                }
                if let Some(tier) = self.tier.as_mut() {
                    for e in row.parked.entries.drain(..) {
                        tier.release(e.tier_id);
                    }
                }
                if row.decode_span != 0 {
                    closes.push((row.decode_span, row.decode_span_steps));
                }
                ids.push(row.req.id);
            }
        }
        for (sid, steps) in closes {
            self.span_close(sid, Some(steps as f64), Some("abort"));
        }
        for id in &ids {
            self.span_ctxs.remove(id);
        }
        ids
    }

    /// Take the tokens decoded since the last drain, in production order.
    /// The serve loop forwards them to streaming clients; concatenating
    /// `text` over one request's events is byte-identical to the final
    /// `Response::text`.
    pub fn drain_token_events(&mut self) -> Vec<TokenEvent> {
        std::mem::take(&mut self.token_events)
    }

    /// Client cancellation: tear down the active row owned by request `id`,
    /// returning its blocks to the pool and releasing its parked tier
    /// entries. Returns false when no active row belongs to `id` (the
    /// request is queued, preempted, or already finished — the caller
    /// handles those via `RequestQueue::remove` + `release_discarded_state`).
    /// Unlike preemption nothing is snapshotted: the client is gone.
    pub fn abort_request(&mut self, id: u64) -> bool {
        let Some(i) = self
            .rows
            .iter()
            .position(|r| r.as_ref().map(|row| row.req.id == id).unwrap_or(false))
        else {
            return false;
        };
        let mut row = self.rows[i].take().expect("ownership checked");
        if let Some(pool) = self.pool.as_mut() {
            row.seq.release_blocks(pool);
        }
        if let Some(tier) = self.tier.as_mut() {
            for e in row.parked.entries.drain(..) {
                tier.release(e.tier_id);
            }
        }
        if row.decode_span != 0 {
            self.span_close(
                row.decode_span,
                Some(row.decode_span_steps as f64),
                Some("abort"),
            );
        }
        self.span_ctxs.remove(&id);
        self.metrics.cancelled_rows += 1;
        self.tele_event(
            id,
            event::ABORT,
            row.pos as usize,
            0,
            row.produced as f64,
            "active",
        );
        true
    }

    /// Client cancellation of a request that is *queued* with a preemption
    /// snapshot: release the tier state riding in it — the pinned entries
    /// of a swap-parked table (which nothing else would ever free: only a
    /// resume consumes them) and the unpinned demotion ledger. Without this
    /// sweep an abandoned swap-parked request leaks pinned tier budget
    /// forever. Safe against double-release: `HostTier::release` ignores
    /// unknown ids, and shed unpinned entries are already gone.
    pub fn release_discarded_state(&mut self, st: &PreemptedState, id: u64) {
        if let Some(tier) = self.tier.as_mut() {
            if let Some(swapped) = &st.swapped {
                for sb in swapped {
                    tier.release(sb.tier_id);
                }
            }
            for e in &st.parked.entries {
                tier.release(e.tier_id);
            }
        }
        self.close_preempt_span(id, "discard");
        self.span_ctxs.remove(&id);
        self.metrics.cancelled_rows += 1;
        self.tele_event(
            id,
            event::ABORT,
            st.pos as usize,
            st.records.len(),
            st.produced as f64,
            "queued",
        );
    }

    /// Extract the layer-0 concat-heads key vector for slot data laid out
    /// as [L, H, ..., dh] — the R-KV similarity sketch.
    fn sketch_from(&self, data: &[f32], h_stride: usize, slot: usize) -> Vec<f32> {
        let d = self.exec.dims();
        let (h, dh) = (d.n_heads, d.d_head);
        let mut out = Vec::with_capacity(h * dh);
        for head in 0..h {
            let base = (head * h_stride + slot) * dh;
            out.extend_from_slice(&data[base..base + dh]);
        }
        out
    }

    /// Admit a request into a free row: prefill, insert, initialize records.
    /// Returns false (caller's request untouched) when no row is free, or
    /// when the block pool cannot cover the prompt — the scheduler holds it
    /// queued. A request carrying a [`PreemptedState`] snapshot is *resumed*
    /// instead (recompute mode — see [`Engine::submit_resumed`]); its
    /// effective queue wait is computed from the snapshot, so `queued_s` is
    /// ignored for it.
    pub fn submit(&mut self, mut req: Request, queued_s: f64) -> Result<bool> {
        if let Some(st) = req.resume.take() {
            return self.submit_resumed(req, st);
        }
        let req_id = req.id;
        let ctx = self.span_ctxs.get(&req_id).copied().unwrap_or_default();
        let Some(row_idx) = self.rows.iter().position(|r| r.is_none()) else {
            return Ok(false);
        };
        let p_bucket = self.exec.prefill_bucket();
        let ids = self
            .tokenizer
            .encode(&req.prompt)
            .map_err(|e| anyhow::anyhow!("prompt: {e}"))?;
        anyhow::ensure!(!ids.is_empty(), "empty prompt");
        anyhow::ensure!(
            ids.len() <= p_bucket,
            "prompt len {} exceeds prefill bucket {}",
            ids.len(),
            p_bucket
        );
        anyhow::ensure!(
            ids.len() < self.cfg.budget,
            "prompt len {} must be < budget {}",
            ids.len(),
            self.cfg.budget
        );
        // pressure-driven admission. With a prefix-cache hit the row's
        // leading whole blocks are forked from the donor for free, so only
        // the *private* remainder (plus one headroom block for the first
        // decode token) must fit in the free part of the pool. Stale cache
        // pins are shed LRU-first before declining, so a cache-heavy pool
        // can never starve admissions.
        let mut fork: Option<BlockTable> = None;
        let mut full_hit = false;
        if self.pool.is_some() {
            let needed = {
                let pool = self.pool.as_mut().expect("checked");
                if let Some(pc) = self.prefix_cache.as_mut() {
                    if let Some(hit) = pc.lookup(&ids, pool.block_size()) {
                        // a seed for this exact prompt lets prefill be
                        // skipped — unless sketches are collected (rkv needs
                        // the prompt keys host-side, which only a real
                        // prefill produces)
                        full_hit = hit.seed.is_some() && !self.cfg.collect_sketches;
                        fork = Some(BlockTable::fork_prefix(hit.table, ids.len(), pool));
                    }
                }
                let shared = fork.as_ref().map_or(0, |t| t.n_blocks());
                pool.blocks_for(ids.len() + 1).saturating_sub(shared)
            };
            if !self.shed_pins_to_cover(needed) {
                if let (Some(pool), Some(mut t)) = (self.pool.as_mut(), fork.take()) {
                    t.release_all(pool);
                }
                return Ok(false);
            }
        }
        let prefix_hit = fork.is_some();
        let premapped = fork.as_ref().map_or(0, |t| t.len());
        let p = ids.len();
        let d = self.exec.dims().clone();
        let row_elems = d.n_layers * d.n_heads * d.d_head;

        // a backend error must not leak the fork's block references
        let release_fork = |slf: &mut Engine, fork: &mut Option<BlockTable>| {
            if let (Some(pool), Some(mut t)) = (slf.pool.as_mut(), fork.take()) {
                t.release_all(pool);
            }
        };

        // Where the prompt's K/V, tracker seed and first logits came from:
        // Seeded  — full-prompt prefix hit under physical paging: the
        //           donor's blocks hold the prompt K/V, zero model compute;
        // Rows    — paged prefill (token-major rows, no worst-case buffer);
        // Dense   — dense prefill + device insert (no pool configured).
        enum Prefilled {
            Seeded(PrefillSeed),
            Rows(crate::runtime::PrefillRows),
            Dense(crate::runtime::PrefillOut),
        }
        // the seed can only have vanished if admission shedding destroyed
        // the entry — impossible while our fork pins its blocks, but a
        // prefill fallback is cheaper than an invariant panic
        let seed_opt = if full_hit {
            self.prefix_cache
                .as_ref()
                .and_then(|pc| pc.seed_for(&ids))
                .cloned()
        } else {
            None
        };
        let mut prefill_ms = None;
        let pre = if let Some(seed) = seed_opt {
            self.metrics.prefill_skips += 1;
            let sid = self.span_open(req_id, span::name::PREFIX_SKIP, ctx, premapped as f64, "");
            self.span_close(sid, None, None);
            Prefilled::Seeded(seed)
        } else {
            let sid = self.span_open(req_id, span::name::PREFILL, ctx, p as f64, "");
            let t0 = Instant::now();
            let (toks, valid) = padded_tokens(&ids, p_bucket);
            let prefilled = if self.pool.is_some() {
                self.exec.prefill_rows(&toks, &valid).map(Prefilled::Rows)
            } else {
                self.exec.prefill(&toks, &valid).map(Prefilled::Dense)
            };
            let out = match prefilled {
                Ok(o) => o,
                Err(e) => {
                    self.span_close(sid, None, Some("error"));
                    release_fork(self, &mut fork);
                    return Err(e);
                }
            };
            if let Prefilled::Dense(o) = &out {
                if let Err(e) = self.exec.insert(&o.k_seq, &o.v_seq, row_idx) {
                    self.span_close(sid, None, Some("error"));
                    release_fork(self, &mut fork);
                    return Err(e);
                }
            }
            let dt = t0.elapsed();
            self.metrics.record_prefill(dt);
            prefill_ms = Some(dt.as_secs_f64() * 1e3);
            self.span_close(sid, None, None);
            out
        };

        let mut row = RowState::new(req, self.cfg.cache, queued_s);
        row.span = ctx;
        row.admit_seq = self.admit_seq;
        self.admit_seq += 1;
        if let Some(pool) = self.pool.as_ref() {
            let table = fork
                .take()
                .unwrap_or_else(|| BlockTable::new(pool.block_size()));
            row.seq.attach_block_table(table);
        }
        let h_stride = self.cfg.cache; // dense k_seq is [L, H, S, dh]
        let sketch_span = d.n_heads * h_stride * d.d_head;
        for i in 0..p {
            let mut rec = TokenRecord::new(i as u32, i as u32);
            rec.last_attn = 1.0;
            if self.cfg.collect_sketches {
                rec.key_sketch = match &pre {
                    Prefilled::Dense(o) => {
                        self.sketch_from(&o.k_seq[..sketch_span], h_stride, i)
                    }
                    // token-major row i, layer 0 = leading H·dh lanes
                    Prefilled::Rows(r) => {
                        r.k_rows[i * row_elems..i * row_elems + d.n_heads * d.d_head].to_vec()
                    }
                    Prefilled::Seeded(_) => unreachable!("skip disabled under sketches"),
                };
            }
            match self.pool.as_mut() {
                Some(pool) => {
                    if row.seq.push_pooled_cow(rec, pool, &mut self.copy_buf).is_none() {
                        // Free-count was checked above; this is unreachable
                        // in the single-threaded loop, but stay safe: give
                        // the blocks back and leave the request queued.
                        row.seq.release_blocks(pool);
                        return Ok(false);
                    }
                }
                None => {
                    row.seq.push(rec);
                }
            }
        }
        debug_assert!(
            self.copy_buf.is_empty(),
            "admission pushes premap or allocate at boundaries — never CoW"
        );

        // physical paging: scatter the prompt's K/V rows into the row's
        // private blocks. Slots below `premapped` already hold the donor's
        // bytes (and writing into those shared blocks would corrupt it).
        if self.pool.is_some() {
            let (k_rows, v_rows, src_base): (&[f32], &[f32], usize) = match &pre {
                Prefilled::Rows(r) => (&r.k_rows, &r.v_rows, 0),
                // seed tail rows start exactly at the entry's coverage
                Prefilled::Seeded(s) => (&s.tail_k, &s.tail_v, premapped),
                Prefilled::Dense(_) => unreachable!("pooled engines prefill rows"),
            };
            let mut i = premapped;
            while i < p {
                let (blk, off, run) = {
                    let t = row.seq.block_table().expect("pooled row has a table");
                    let (blk, off) = t.locate(i).expect("prompt slot mapped");
                    (blk, off, (t.block_size() - off).min(p - i))
                };
                let a = (i - src_base) * row_elems;
                let b = a + run * row_elems;
                if let Err(e) = self.exec.write_kv_rows(blk, off, &k_rows[a..b], &v_rows[a..b]) {
                    if let Some(pool) = self.pool.as_mut() {
                        row.seq.release_blocks(pool);
                    }
                    return Err(e);
                }
                i += run;
            }
        }

        // the admission actually went through: settle the hit/miss counters
        // (a lookup whose admission was declined counts as neither), and
        // register this prompt's whole-block prefix so later identical
        // headers fork it (no-op if an entry already covers it). Under
        // physical paging a fresh prefill also leaves its seed behind, so
        // the *next* identical prompt skips prefill entirely.
        if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
            if prefix_hit {
                pc.hits += 1;
            } else {
                pc.misses += 1;
            }
            if let Some(t) = row.seq.block_table() {
                let seed = match &pre {
                    Prefilled::Rows(r) => {
                        let covered = (p.min(t.len()) / pool.block_size()) * pool.block_size();
                        Some(PrefillSeed {
                            prompt: ids.clone(),
                            tail_k: r.k_rows[covered * row_elems..p * row_elems].to_vec(),
                            tail_v: r.v_rows[covered * row_elems..p * row_elems].to_vec(),
                            attn_last: r.attn_last.clone(),
                            logits_last: r.logits_last.clone(),
                        })
                    }
                    _ => None,
                };
                pc.insert(&ids, t, seed, pool);
            }
        }
        // one observation from the last prompt row's attention
        let (attn_seed, logits_seed): (&[f32], &[f32]) = match &pre {
            Prefilled::Seeded(s) => (&s.attn_last, &s.logits_last),
            Prefilled::Rows(r) => (&r.attn_last, &r.logits_last),
            Prefilled::Dense(o) => (&o.attn_last, &o.logits_last),
        };
        observe(
            row.seq.records_mut(),
            &attn_seed[..p],
            (p - 1) as u32,
            TrackerConfig {
                alpha: self.cfg.alpha,
            },
        );
        row.pos = p as u32;

        // first prediction comes from the prefill (or seeded) logits
        let pred_id = argmax(logits_seed);
        let pred = self.tokenizer.char_of(pred_id as u32).unwrap_or(' ');
        match row.advance_with_prediction(pred, self.cfg.stop_char) {
            Some(c) => {
                row.next_token = self.tokenizer.id(c).unwrap_or(0);
                self.rows[row_idx] = Some(row);
            }
            None => {
                // degenerate: finished without a single decode step
                self.rows[row_idx] = Some(row);
            }
        }
        self.metrics.record_queue_wait(queued_s);
        if self.telemetry.is_some() {
            let (step, live) = self.rows[row_idx]
                .as_ref()
                .map(|r| (r.pos as usize, r.seq.len()))
                .unwrap_or((p, p));
            self.tele_event(req_id, event::ADMITTED, step, live, p as f64, "");
            if prefix_hit {
                self.tele_event(req_id, event::PREFIX_HIT, step, live, premapped as f64, "");
            }
            match prefill_ms {
                Some(ms) => self.tele_event(req_id, event::PREFILL, step, live, ms, ""),
                None => self.tele_event(req_id, event::PREFILL_SKIP, step, live, 0.0, ""),
            }
        }
        Ok(true)
    }

    /// Admission-side pool check shared by fresh and resumed submits: shed
    /// reclaimable prefix-cache pins LRU-first — but only when the total
    /// reclaimable pins can actually cover the shortfall, so a hopeless
    /// demand never wipes the cache (and every later identical-prompt
    /// admission's sharing) for nothing — then report whether `needed`
    /// free blocks are available. Always true without a pool.
    fn shed_pins_to_cover(&mut self, needed: usize) -> bool {
        let Some(pool) = self.pool.as_mut() else {
            return true;
        };
        if let Some(pc) = self.prefix_cache.as_mut() {
            if pool.free_blocks() + pc.reclaimable_blocks(pool) >= needed {
                while pool.free_blocks() < needed {
                    if !pc.shed_lru_reclaimable(pool) {
                        break;
                    }
                }
            }
        }
        pool.free_blocks() >= needed
    }

    /// Resume a preempted row from its snapshot (vLLM-style recompute
    /// mode). The fed-token stream the row had consumed — prompt plus every
    /// emitted char except the pending one — is re-prefilled in **one
    /// batched `prefill_rows` pass**; only the K/V rows the live keep-set
    /// still references are written back through a fresh block table (the
    /// recompute covers every position, so evicted slots simply are not
    /// written). The tracker records are restored verbatim — the row's
    /// observation history (TS/MRI) and therefore its future eviction
    /// decisions are identical to a never-preempted run's. The recompute
    /// pass's attention/logits are discarded: the snapshot already holds
    /// the pending input token, so no `observe`/advance runs here.
    ///
    /// Falls back to a restart from the prompt (counted in
    /// `resume_fallbacks`) when the stream has outgrown the prefill bucket
    /// or the engine has no pool (preemption never produces the latter; the
    /// guard keeps a hand-crafted request from wedging a dense engine).
    /// Returns Ok(false) without consuming pool capacity when no row is
    /// free or the pool cannot cover the live set — the caller still holds
    /// its copy of the request (snapshot included) and retries later.
    fn submit_resumed(&mut self, req: Request, st: std::sync::Arc<PreemptedState>) -> Result<bool> {
        if self.rows.iter().all(|r| r.is_some()) {
            return Ok(false);
        }
        let rid = req.id;
        // cumulative wait: everything queued before earlier admissions plus
        // the wait since this preemption (re-queue happens at preemption)
        let queued_s = st.queued_s + st.preempted_at.elapsed().as_secs_f64();
        // finished-but-preempted (a mid-step privatization victim): nothing
        // to recompute — restore the outputs and let step() collect it
        if st.finish.is_some() {
            let row_idx = self.rows.iter().position(|r| r.is_none()).expect("checked");
            let mut row = RowState::resume(req, self.cfg.cache, queued_s, &st);
            row.span = self.span_ctxs.get(&rid).copied().unwrap_or_default();
            row.admit_seq = self.admit_seq;
            self.admit_seq += 1;
            self.metrics.resumes += 1;
            self.rows[row_idx] = Some(row);
            self.metrics.record_queue_wait(queued_s);
            self.close_preempt_span(rid, "finished");
            self.tele_event(rid, event::RESUME, st.pos as usize, st.records.len(), 0.0, "finished");
            return Ok(true);
        }
        // swap-mode snapshot: the K/V bytes are parked in the host tier —
        // no fed-stream recompute, no prefill-bucket limit
        if st.swapped.is_some() {
            return self.submit_swapped(req, st, queued_s);
        }
        // the fed-token stream: prompt, then every emitted char except the
        // last (that one is `next_token`, still pending its decode step)
        let mut ids = self
            .tokenizer
            .encode(&req.prompt)
            .map_err(|e| anyhow::anyhow!("prompt: {e}"))?;
        for c in st.out_text.chars().take(st.produced.saturating_sub(1)) {
            ids.push(self.tokenizer.id(c).unwrap_or(0));
        }
        anyhow::ensure!(
            ids.len() == st.pos as usize,
            "resume stream length {} != snapshot pos {}",
            ids.len(),
            st.pos
        );
        let p_bucket = self.exec.prefill_bucket();
        if self.pool.is_none() || ids.len() > p_bucket {
            // cannot recompute in one pass: restart from the prompt (the
            // pre-resume behavior). Counted only when the restart actually
            // admits — a decline leaves the snapshot with the caller, and
            // its retries must not inflate the fallback metric.
            let admitted = self.submit(req, queued_s)?;
            if admitted {
                self.metrics.resume_fallbacks += 1;
                self.close_preempt_span(rid, "restart");
                self.tele_event(rid, event::RESUME_RESTART, st.pos as usize, 0, 0.0, "");
                // the restart regenerates tokens, but the request's
                // timeline is still the original one: keep the
                // first-admission timestamps so ttft_s/total_s honor the
                // documented "original admission" metrics contract
                let ticket = self.admit_seq - 1;
                if let Some(row) = self
                    .rows
                    .iter_mut()
                    .flatten()
                    .find(|r| r.admit_seq == ticket)
                {
                    row.admitted_at = st.admitted_at;
                    row.first_token_at = st.first_token_at.or(row.first_token_at);
                }
            }
            return Ok(admitted);
        }
        let n_live = st.records.len();
        anyhow::ensure!(n_live > 0, "resume snapshot has an empty live set");
        anyhow::ensure!(
            st.records.iter().all(|r| (r.pos as usize) < ids.len()),
            "resume record position outside the recompute stream"
        );
        // a still-cached prompt prefix is re-forked instead of re-allocated
        // privately: possible whenever the keep-set's leading slots hold
        // exactly positions 0.. in order — true for any row preempted
        // before its first eviction pass reordered the slots (the common
        // case: preemption victims are the *youngest* rows). Counted under
        // `prefix_hits`; the forked whole blocks already hold those
        // positions' K/V, so the write-back below skips them — and when the
        // fork covers the entire live set, the recompute prefill is skipped
        // outright (counted under `prefill_skips`).
        let mut fork: Option<BlockTable> = None;
        if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
            let mut lead = 0usize;
            while lead < n_live && st.records[lead].pos as usize == lead {
                lead += 1;
            }
            if lead >= pool.block_size() {
                if let Some(hit) = pc.lookup(&ids[..lead], pool.block_size()) {
                    let t = BlockTable::fork_prefix(hit.table, lead, pool);
                    if !t.is_empty() {
                        fork = Some(t);
                    }
                }
            }
        }
        let premapped = fork.as_ref().map_or(0, |t| t.len());
        // admission: the resumed row needs blocks for its live set plus one
        // headroom block, minus whatever the fork shares; stale prefix-cache
        // pins are shed like any other admission.
        let needed = {
            let pool = self.pool.as_ref().expect("checked above");
            pool.blocks_for(n_live + 1)
                .saturating_sub(fork.as_ref().map_or(0, |t| t.n_blocks()))
        };
        if !self.shed_pins_to_cover(needed) {
            if let (Some(pool), Some(mut t)) = (self.pool.as_mut(), fork.take()) {
                t.release_all(pool);
            }
            return Ok(false);
        }
        // one batched recompute prefill over the whole fed stream — K/V for
        // every position the keep-set might reference, no worst-case buffer
        let pre = if premapped < n_live {
            let t0 = Instant::now();
            let (toks, valid) = padded_tokens(&ids, p_bucket);
            let out = match self.exec.prefill_rows(&toks, &valid) {
                Ok(o) => o,
                Err(e) => {
                    if let (Some(pool), Some(mut t)) = (self.pool.as_mut(), fork.take()) {
                        t.release_all(pool);
                    }
                    return Err(e);
                }
            };
            self.metrics.record_prefill(t0.elapsed());
            Some(out)
        } else {
            self.metrics.prefill_skips += 1;
            None
        };

        let row_idx = self.rows.iter().position(|r| r.is_none()).expect("checked");
        let mut row = RowState::resume(req, self.cfg.cache, queued_s, &st);
        row.span = self.span_ctxs.get(&rid).copied().unwrap_or_default();
        row.admit_seq = self.admit_seq;
        self.admit_seq += 1;
        {
            let pool = self.pool.as_mut().expect("checked above");
            let table = fork
                .take()
                .unwrap_or_else(|| BlockTable::new(pool.block_size()));
            row.seq.attach_block_table(table);
            if !row.seq.restore_pooled(&st.records, pool) {
                // free count was checked above; unreachable single-threaded,
                // but roll back safely and leave the request queued
                row.seq.release_blocks(pool);
                return Ok(false);
            }
        }
        // scatter the surviving rows: slot j holds the token born at
        // records[j].pos, whose recomputed K/V is row `pos` of the prefill
        // output. Runs of consecutive positions within a block batch up.
        // Slots below `premapped` already hold the donor's bytes (and those
        // shared blocks must never be written through this table).
        let re = {
            let d = self.exec.dims();
            d.n_layers * d.n_heads * d.d_head
        };
        let positions: Vec<u32> = st.records.iter().map(|r| r.pos).collect();
        let mut j = premapped;
        while j < n_live {
            let (blk, off, run) = {
                let t = row.seq.block_table().expect("pooled row has a table");
                let (blk, off) = t.locate(j).expect("restored slot mapped");
                let max_run = (t.block_size() - off).min(n_live - j);
                let mut run = 1;
                while run < max_run && positions[j + run] == positions[j] + run as u32 {
                    run += 1;
                }
                (blk, off, run)
            };
            let a = positions[j] as usize * re;
            let b = a + run * re;
            let rows = pre.as_ref().expect("prefill ran: premapped < n_live");
            if let Err(e) =
                self.exec
                    .write_kv_rows(blk, off, &rows.k_rows[a..b], &rows.v_rows[a..b])
            {
                if let Some(pool) = self.pool.as_mut() {
                    row.seq.release_blocks(pool);
                }
                return Err(e);
            }
            j += run;
        }
        if premapped > 0 {
            if let Some(pc) = self.prefix_cache.as_mut() {
                pc.hits += 1;
            }
        }
        self.metrics.resumes += 1;
        let recomputed = if pre.is_some() { ids.len() } else { 0 };
        self.metrics.recomputed_tokens += recomputed as u64;
        self.rows[row_idx] = Some(row);
        self.metrics.record_queue_wait(queued_s);
        self.close_preempt_span(rid, "recompute");
        self.tele_event(rid, event::RESUME, st.pos as usize, n_live, recomputed as f64, "");
        Ok(true)
    }

    /// Swap-mode resume: re-map the live set onto fresh blocks and copy the
    /// parked bytes back from the host tier — no model compute at all, and
    /// no prefill-bucket limit on the fed stream. The tracker records are
    /// restored verbatim exactly as in recompute mode, so the resumed row's
    /// decode and future eviction decisions are byte-identical to a
    /// never-preempted run's. If the tier no longer holds every parked
    /// block (possible only if the snapshot crossed engines), the pinned
    /// entries are released and the resume falls back to a recompute
    /// snapshot of the same state.
    fn submit_swapped(
        &mut self,
        req: Request,
        st: std::sync::Arc<PreemptedState>,
        queued_s: f64,
    ) -> Result<bool> {
        let rid = req.id;
        let swapped = st.swapped.clone().expect("caller checked");
        let n_live = st.records.len();
        anyhow::ensure!(n_live > 0, "swap snapshot has an empty live set");
        let resident = self.pool.is_some()
            && match self.tier.as_ref() {
                Some(t) => swapped.iter().all(|sw| t.contains(sw.tier_id)),
                None => false,
            };
        if !resident {
            if let Some(t) = self.tier.as_mut() {
                for sw in &swapped {
                    t.release(sw.tier_id);
                }
            }
            let mut fallback = (*st).clone();
            fallback.swapped = None;
            return self.submit_resumed(req, std::sync::Arc::new(fallback));
        }
        let needed = self
            .pool
            .as_ref()
            .expect("resident check covers the pool")
            .blocks_for(n_live + 1);
        if !self.shed_pins_to_cover(needed) {
            return Ok(false); // snapshot and pinned tier entries stay intact
        }
        let row_idx = self.rows.iter().position(|r| r.is_none()).expect("checked");
        let mut row = RowState::resume(req, self.cfg.cache, queued_s, &st);
        row.span = self.span_ctxs.get(&rid).copied().unwrap_or_default();
        row.admit_seq = self.admit_seq;
        self.admit_seq += 1;
        {
            let pool = self.pool.as_mut().expect("checked above");
            row.seq
                .attach_block_table(BlockTable::new(pool.block_size()));
            if !row.seq.restore_pooled(&st.records, pool) {
                row.seq.release_blocks(pool);
                return Ok(false);
            }
        }
        debug_assert_eq!(
            row.seq.block_table().map(|t| t.n_blocks()).unwrap_or(0),
            swapped.len(),
            "the parked table and the restored live set must agree"
        );
        let swap_span = self.span_open(rid, span::name::SWAP_IN, row.span, 0.0, "");
        let mut moved = 0usize;
        for (bi, sw) in swapped.iter().enumerate() {
            let blk = {
                let t = row.seq.block_table().expect("attached above");
                t.blocks()[bi]
            };
            let (k, v, rows) = self
                .tier
                .as_mut()
                .expect("resident check covers the tier")
                .take(sw.tier_id)
                .expect("pinned entries cannot vanish mid-admission");
            debug_assert_eq!(rows, sw.rows, "parked row count drifted");
            moved += (k.len() + v.len()) * std::mem::size_of::<f32>();
            if let Err(e) = self.exec.swap_in_block(blk, &k, &v) {
                if let Some(pool) = self.pool.as_mut() {
                    row.seq.release_blocks(pool);
                }
                // the request dies here (step error path): free the pinned
                // entries not yet consumed, or they would shrink the tier
                // budget for the engine's lifetime
                if let Some(t) = self.tier.as_mut() {
                    for later in &swapped[bi + 1..] {
                        t.release(later.tier_id);
                    }
                }
                self.span_close(swap_span, None, Some("error"));
                self.close_preempt_span(rid, "error");
                return Err(e);
            }
        }
        self.span_close(swap_span, Some(moved as f64), None);
        self.metrics.resumes += 1;
        self.metrics.swap_in_bytes += moved as u64;
        self.rows[row_idx] = Some(row);
        self.metrics.record_queue_wait(queued_s);
        self.close_preempt_span(rid, "swap");
        self.tele_event(rid, event::RESUME_SWAP, st.pos as usize, n_live, moved as f64, "");
        Ok(true)
    }

    /// Preempt row `i`: return its blocks to the pool and queue its request
    /// for re-admission with a full decode-state snapshot attached
    /// (recompute mode). The snapshot carries the generated text, template
    /// cursor, pending input token, the tracker records (TS/MRI observation
    /// history — restored verbatim on resume, never re-initialized) and the
    /// original admission timing, so the resumed row continues
    /// byte-identically to a never-preempted run instead of regenerating
    /// from the prompt.
    fn preempt_row(&mut self, i: usize) {
        let Some(mut row) = self.rows[i].take() else {
            return;
        };
        self.metrics.preemptions += 1;
        let rid = row.req.id;
        let pos = row.pos as usize;
        let live = row.seq.len();
        if row.decode_span != 0 {
            self.span_close(
                row.decode_span,
                Some(row.decode_span_steps as f64),
                Some("preempt"),
            );
            row.decode_span = 0;
            row.decode_span_steps = 0;
        }
        let preempt_span = self.span_open(rid, span::name::PREEMPT, row.span, live as f64, "");
        if preempt_span != 0 {
            self.preempt_spans.insert(rid, preempt_span);
        }
        // swap mode: park the whole table before the blocks are released —
        // `None` means the recompute snapshot below carries the row instead
        let swapped = self.try_swap_out_row(&row);
        let was_swap = swapped.is_some();
        if let Some(pool) = self.pool.as_mut() {
            row.seq.release_blocks(pool);
        }
        let records = row.seq.take_records();
        let parked = std::mem::take(&mut row.parked);
        let mut req = row.req;
        // a row preempted twice carries the freshest snapshot only
        req.resume = Some(std::sync::Arc::new(PreemptedState {
            records,
            swapped,
            parked,
            pos: row.pos,
            next_token: row.next_token,
            next_forced: row.next_forced,
            template_cursor: row.template_cursor,
            out_text: row.out_text,
            hole_predictions: row.hole_predictions,
            produced: row.produced,
            finish: row.finish,
            evictions: row.evictions,
            live_curve: row.live_curve,
            queued_s: row.queued_s,
            admitted_at: row.admitted_at,
            first_token_at: row.first_token_at,
            preempted_at: Instant::now(),
        }));
        self.preempted.push((row.admit_seq, req));
        let ev = if was_swap {
            event::PREEMPT_SWAP
        } else {
            event::PREEMPT
        };
        self.tele_event(rid, ev, pos, live, live as f64, "");
    }

    /// Swap-mode half of [`preempt_row`]: copy every occupied row of the
    /// row's table into pinned host-tier entries, one per block in table
    /// order. Returns `None` — and releases any partial progress — whenever
    /// the mode resolves to recompute, the row is already finished (nothing
    /// left to serve), the engine has no tier, or the tier cannot hold the
    /// whole table; the caller's recompute snapshot stays correct in every
    /// fallback case.
    fn try_swap_out_row(&mut self, row: &RowState) -> Option<Vec<SwappedBlock>> {
        if row.finish.is_some() {
            return None;
        }
        let live = row.seq.len();
        if live == 0 {
            return None;
        }
        let use_swap = match self.cfg.preempt_mode {
            PreemptMode::Recompute => false,
            PreemptMode::Swap => true,
            PreemptMode::Auto => {
                crate::scheduler::preempt::swap_beats_recompute(live, row.pos as usize)
            }
        };
        if !use_swap || self.tier.is_none() {
            return None;
        }
        let swap_span = self.span_open(row.req.id, span::name::SWAP_OUT, row.span, 0.0, "");
        let shed_before = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0);
        let Some(t) = row.seq.block_table() else {
            self.span_close(swap_span, None, Some("no_table"));
            return None;
        };
        let bs = t.block_size();
        let blocks: Vec<(BlockId, usize)> = t
            .blocks()
            .iter()
            .enumerate()
            .map(|(bi, &b)| (b, (live - bi * bs).min(bs)))
            .collect();
        let mut parked: Vec<SwappedBlock> = Vec::with_capacity(blocks.len());
        let mut moved = 0usize;
        for (blk, rows) in blocks {
            let ok = match self.exec.swap_out_block(blk, rows) {
                Ok((k, v)) => {
                    moved += (k.len() + v.len()) * std::mem::size_of::<f32>();
                    self.tier
                        .as_mut()
                        .expect("checked above")
                        .park(k, v, rows, true)
                        .map(|id| parked.push(SwappedBlock { tier_id: id, rows }))
                        .is_some()
                }
                Err(_) => false,
            };
            if !ok {
                let tier = self.tier.as_mut().expect("checked above");
                for sw in parked {
                    tier.release(sw.tier_id);
                }
                self.metrics.tier_rejects += 1;
                self.tele_event(
                    row.req.id,
                    event::TIER_REJECT,
                    row.pos as usize,
                    live,
                    self.metrics.tier_rejects as f64,
                    "swap_out",
                );
                self.span_close(swap_span, None, Some("rejected"));
                return None;
            }
        }
        let shed = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0) - shed_before;
        if shed > 0 {
            self.tele_event(
                row.req.id,
                event::TIER_SHED,
                row.pos as usize,
                live,
                shed as f64,
                "swap_out",
            );
        }
        self.metrics.swap_preempts += 1;
        self.metrics.swap_out_bytes += moved as u64;
        self.span_close(swap_span, Some(moved as f64), None);
        Some(parked)
    }

    /// Make sure every active row can map one more token this step. When
    /// the pool cannot cover the demand, shed prefix-cache pins LRU-first,
    /// then preempt youngest rows. Terminates: each round either satisfies
    /// the demand, sheds a (finite) cache entry, or removes a row, and
    /// config validation guarantees a solo row with no stale pins always
    /// fits (`n_blocks * block_size >= cache`).
    fn ensure_block_headroom(&mut self) {
        loop {
            let Some(pool) = self.pool.as_ref() else { return };
            let free = pool.free_blocks();
            let needed = self
                .rows
                .iter()
                .flatten()
                .filter(|r| r.seq.needs_block_for_next(pool))
                .count();
            if needed <= free {
                return;
            }
            // stale cache pins go before live rows — but only pins whose
            // shedding actually frees blocks; still-shared entries would
            // relieve nothing and are kept for future admissions
            if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
                if pc.shed_lru_reclaimable(pool) {
                    continue;
                }
            }
            let victim = self
                .rows
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.as_ref().map(|row| (row.admit_seq, i)))
                .max_by_key(|&(seq, _)| seq)
                .map(|(_, i)| i);
            match victim {
                Some(i) => self.preempt_row(i),
                None => return,
            }
        }
    }

    /// Copy-on-write row `i`'s shared blocks so an eviction pass can mutate
    /// its mapping. Allocation pressure is resolved by shedding prefix-cache
    /// pins LRU-first, then preempting the youngest *other* row (whose
    /// released references often privatize `i`'s blocks with no allocation
    /// at all). The physical byte duplications every logical swap implies
    /// are applied to the backend immediately — including on the partial
    /// progress of a failed attempt, whose swapped blocks are already live.
    /// Returns Ok(false) only when the row still shares blocks and nothing
    /// is left to shed or preempt — the caller skips the eviction pass for
    /// that row this step and retries next step.
    fn make_row_private(&mut self, i: usize) -> Result<bool> {
        loop {
            let (done, shared_ids) = {
                let Some(pool) = self.pool.as_mut() else { return Ok(true) };
                let Some(row) = self.rows[i].as_mut() else { return Ok(true) };
                if row.seq.make_private_cow(pool, &mut self.copy_buf) {
                    (true, Vec::new())
                } else {
                    let ids = row
                        .seq
                        .block_table()
                        .map(|t| t.shared_block_ids(pool))
                        .unwrap_or_default();
                    (false, ids)
                }
            };
            self.flush_block_copies()?;
            if done {
                return Ok(true);
            }
            if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
                // first drop cache entries holding *this row's* shared
                // blocks — that lowers their refcount directly, often
                // privatizing the row with no allocation at all...
                if pc.shed_lru_overlapping(&shared_ids, pool) {
                    continue;
                }
                // ...then entries whose shedding frees blocks for the copy
                if pc.shed_lru_reclaimable(pool) {
                    continue;
                }
            }
            let victim = self
                .rows
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .filter_map(|(j, r)| r.as_ref().map(|row| (row.admit_seq, j)))
                .max_by_key(|&(seq, _)| seq)
                .map(|(_, j)| j);
            match victim {
                Some(j) => self.preempt_row(j),
                None => return Ok(false),
            }
        }
    }

    /// One decode iteration over all active rows. Returns finished responses
    /// (preempted requests are reported via `take_preempted`, not here).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let (b, s) = (self.cfg.batch, self.cfg.cache);
        // collect immediately-finished rows (prefill-finished), and
        // force-finish rows whose cache is physically full and whose policy
        // cannot shed tokens (FullKV hitting capacity)
        let mut finished = Vec::new();
        for i in 0..b {
            if let Some(row) = self.rows[i].as_mut() {
                if row.finish.is_none() && row.seq.len() >= self.cfg.cache {
                    row.finish = Some(crate::coordinator::FinishReason::MaxTokens);
                }
            }
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        // paged mode: every surviving row must be able to map one more token
        if self.pool.is_some() {
            self.ensure_block_headroom();
        }
        if self.rows.iter().all(|r| r.is_none()) {
            return Ok(finished);
        }

        // open a decode-window span for every traced row that lacks one;
        // each span aggregates up to DECODE_WINDOW_STEPS decode steps so
        // long generations stay cheap to trace
        let opens: Vec<(usize, u64)> = self
            .rows
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.as_ref().map(|row| (i, row)))
            .filter(|(_, row)| row.decode_span == 0 && !row.span.is_off())
            .map(|(i, row)| {
                (
                    i,
                    self.span_open(row.req.id, span::name::DECODE_WINDOW, row.span, 0.0, ""),
                )
            })
            .collect();
        for (i, sid) in opens {
            if sid != 0 {
                if let Some(row) = self.rows[i].as_mut() {
                    row.decode_span = sid;
                }
            }
        }

        let t0 = Instant::now();
        let paged = self.pool.is_some();
        // stage inputs: block tables + lens (paged) or slot masks (dense)
        self.tok_buf.fill(0);
        self.pos_buf.fill(0);
        if paged {
            self.tbl_buf.fill(-1);
            self.len_buf.fill(0);
        } else {
            self.mask_buf.fill(0.0);
            self.idx_buf.fill(0);
        }
        let mut active = 0u64;
        for i in 0..b {
            if let Some(row) = &self.rows[i] {
                if paged {
                    let t = row.seq.block_table().expect("pooled row has a table");
                    let bpr = self.blocks_per_row;
                    for (j, &blk) in t.blocks().iter().enumerate() {
                        self.tbl_buf[i * bpr + j] = blk as i32;
                    }
                    self.len_buf[i] = row.seq.len() as i32;
                } else {
                    row.seq.slot_mask(&mut self.mask_buf[i * s..(i + 1) * s]);
                    self.idx_buf[i] = row.seq.len() as i32;
                }
                self.tok_buf[i] = row.next_token as i32;
                self.pos_buf[i] = row.pos as i32;
                active += 1;
            }
        }

        let out = if paged {
            // K/V context is gathered through the block tables on the
            // backend; the new rows come back for table-routed appends
            self.exec.step_paged(
                &self.tbl_buf,
                self.blocks_per_row,
                &self.len_buf,
                &self.tok_buf,
                &self.pos_buf,
            )?
        } else {
            let o = self.exec.step(&self.mask_buf, &self.tok_buf, &self.pos_buf)?;
            self.exec.append(&o.k_new, &o.v_new, &self.idx_buf)?;
            o
        };

        let d = self.exec.dims().clone();
        let (nh, dh, nl) = (d.n_heads, d.d_head, d.n_layers);
        let per_row_new = nl * nh * dh;
        let alpha_cfg = TrackerConfig {
            alpha: self.cfg.alpha,
        };

        // per-row: observe attention, record the new token, pick next input
        for i in 0..b {
            // phase 1 (row borrow): tracker update + logical push + output
            let (write_at, decode_ev, tok_ev, win_ev) = {
                let Some(row) = self.rows[i].as_mut() else {
                    continue;
                };
                let step_t = row.pos;
                let live = row.seq.len();
                let attn_row = &out.attn[i * s..i * s + live];
                observe(row.seq.records_mut(), attn_row, step_t, alpha_cfg);

                let mut rec = TokenRecord::new(step_t, step_t);
                rec.last_attn = 1.0; // self-attention at birth; overwritten next step
                if self.cfg.collect_sketches {
                    // k_new row layout: [L, H, dh] for this batch row
                    let base = i * per_row_new;
                    let mut sk = Vec::with_capacity(nh * dh);
                    for head in 0..nh {
                        let off = base + head * dh; // layer 0
                        sk.extend_from_slice(&out.k_new[off..off + dh]);
                    }
                    rec.key_sketch = sk;
                }
                match self.pool.as_mut() {
                    Some(pool) => {
                        row.seq
                            .push_pooled_cow(rec, pool, &mut self.copy_buf)
                            .expect("block headroom ensured before step");
                    }
                    None => {
                        row.seq.push(rec);
                    }
                }
                if self.cfg.record_live {
                    row.live_curve.push(row.seq.len());
                }
                self.metrics.record_live(row.seq.len());
                row.pos += 1;
                // first decode step of this admission: flight-record it once
                let decode_ev = if row.decode_logged {
                    None
                } else {
                    row.decode_logged = true;
                    Some((row.req.id, row.pos as usize, row.seq.len()))
                };
                // fold this step into the open decode-window span; a full
                // window closes (phase 2) and the next step opens a new one
                row.decode_span_steps += 1;
                let win_ev =
                    if row.decode_span != 0 && row.decode_span_steps >= span::DECODE_WINDOW_STEPS {
                        let ev = (row.decode_span, row.decode_span_steps);
                        row.decode_span = 0;
                        row.decode_span_steps = 0;
                        Some(ev)
                    } else {
                        None
                    };

                let logits = &out.logits[i * self.vocab..(i + 1) * self.vocab];
                let pred = self
                    .tokenizer
                    .char_of(argmax(logits) as u32)
                    .unwrap_or(' ');
                // capture the output delta around the advance: whatever
                // chars land in out_text this step (predicted or
                // template-forced) are exactly what a streaming client must
                // see, so concat(stream) == Response::text byte-for-byte
                let out_len_before = row.out_text.len();
                if let Some(c) = row.advance_with_prediction(pred, self.cfg.stop_char) {
                    row.next_token = self.tokenizer.id(c).unwrap_or(0);
                }
                let tok_ev = if row.out_text.len() > out_len_before {
                    Some((
                        TokenEvent {
                            req: row.req.id,
                            text: row.out_text[out_len_before..].to_string(),
                            produced: row.produced,
                            first: row.produced == 1,
                        },
                        row.pos as usize,
                        row.seq.len(),
                    ))
                } else {
                    None
                };
                let write_at = if paged {
                    let slot = row.seq.len() - 1;
                    let t = row.seq.block_table().expect("pooled row has a table");
                    Some(t.locate(slot).expect("just pushed ⇒ mapped"))
                } else {
                    None
                };
                (write_at, decode_ev, tok_ev, win_ev)
            };
            // phase 2 (backend): any shared-tail CoW copy lands first, then
            // the new token's K/V row goes to its table-mapped location
            if let Some((blk, off)) = write_at {
                self.flush_block_copies()?;
                let base = i * per_row_new;
                self.exec.write_kv_rows(
                    blk,
                    off,
                    &out.k_new[base..base + per_row_new],
                    &out.v_new[base..base + per_row_new],
                )?;
            }
            if let Some((rid, stp, lv)) = decode_ev {
                self.tele_event(rid, event::DECODE, stp, lv, 0.0, "");
            }
            if let Some((ev, pos, live)) = tok_ev {
                self.tele_event(
                    ev.req,
                    event::STREAM_TOKEN,
                    pos,
                    live,
                    ev.produced as f64,
                    "",
                );
                self.token_events.push(ev);
            }
            if let Some((sid, steps)) = win_ev {
                self.span_close(sid, Some(steps as f64), None);
            }
        }
        self.metrics.record_step(t0.elapsed(), active);

        // eviction pass (lagged or greedy per policy; forced at capacity).
        // In paged mode compaction also returns whole freed blocks, and the
        // surviving rows' bytes are relocated between blocks immediately —
        // before any later row's CoW could reuse the freed blocks.
        let te = Instant::now();
        let mut any_evict = false;
        for i in 0..b {
            let wants = match &self.rows[i] {
                Some(row) => {
                    let live = row.seq.len();
                    let step_t = row.pos;
                    (self
                        .policy
                        .should_evict(live, self.cfg.budget, step_t)
                        || live >= self.cfg.cache)
                        && live > self.cfg.budget
                }
                None => false,
            };
            let range = i * s..(i + 1) * s;
            // CoW before compaction: eviction reorders slot contents, so a
            // row still sharing prefix blocks must detach them first. If
            // privatization is impossible right now, defer this row's pass.
            let wants = wants && (self.pool.is_none() || self.make_row_private(i)?);
            if wants {
                self.demote_buf.clear();
                let pass_span = {
                    let row = self.rows[i].as_ref().expect("wants ⇒ row present");
                    self.span_open(row.req.id, span::name::EVICT_PASS, row.span, 0.0, "")
                };
                let evict_ev = {
                    let row = self.rows[i].as_mut().unwrap();
                    let keep =
                        self.policy
                            .select_keep(row.seq.records(), self.cfg.budget, row.pos);
                    // observe the pass *before* apply_keep mutates/reorders
                    // the records — verdicts must reflect decision time
                    if let Some(obs) = self.recurrence.as_mut() {
                        obs.observe_pass(
                            row.req.id,
                            row.pos,
                            row.seq.records(),
                            &keep,
                            self.tier.is_some(),
                            self.cfg.params.window,
                            &self.cfg.params.score,
                        );
                    }
                    let n_evicted = row.seq.len() - keep.len();
                    row.evictions += n_evicted;
                    match self.pool.as_mut() {
                        Some(pool) => {
                            self.move_buf.clear();
                            if self.tier.is_some() {
                                // tiered: evicted rows demote to the host
                                // tier instead of being destroyed
                                row.seq.apply_keep_pooled_demote(
                                    &keep,
                                    row.pos,
                                    pool,
                                    &mut self.move_buf,
                                    &mut self.demote_buf,
                                );
                            } else {
                                row.seq.apply_keep_pooled_moves(
                                    &keep,
                                    row.pos,
                                    pool,
                                    &mut self.move_buf,
                                );
                            }
                        }
                        None => {
                            row.seq.apply_keep(&keep, row.pos);
                            let idx = row.seq.gather_indices(&keep);
                            self.gather_buf[range].copy_from_slice(&idx);
                        }
                    }
                    (row.req.id, row.pos as usize, keep.len(), n_evicted)
                };
                let (rid, pos, kept, n_evicted) = evict_ev;
                self.tele_event(rid, event::EVICT, pos, kept, n_evicted as f64, "");
                // demotion swap-outs read the evicted rows at their
                // pre-compaction locations — they must land before the
                // compaction moves overwrite those rows below
                if !self.demote_buf.is_empty() {
                    self.park_demoted(i)?;
                }
                if paged && !self.move_buf.is_empty() {
                    // keep the buffer's allocation across steps
                    let moves = std::mem::take(&mut self.move_buf);
                    self.exec.gather_kv_rows(&moves)?;
                    self.move_buf = moves;
                    self.move_buf.clear();
                }
                self.span_close(pass_span, Some(n_evicted as f64), None);
                any_evict = true;
            } else if !paged {
                for (j, v) in self.gather_buf[range].iter_mut().enumerate() {
                    *v = j as i32;
                }
            }
        }
        if any_evict {
            if !paged {
                self.exec.gather(&self.gather_buf)?;
            }
            self.metrics.record_eviction(te.elapsed());
        }

        // recurrence-driven promotion: a parked token whose importance score
        // re-crossed the keep threshold brings its whole entry back
        if self.tier.is_some() {
            for i in 0..b {
                self.promote_parked(i)?;
            }
        }

        // collect rows that finished this step
        for i in 0..b {
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        // debug builds audit the pool/tier conservation laws every step;
        // non-strict pins because undrained preemption snapshots may be
        // held by the caller (run_all's pending queue, the serve queues)
        #[cfg(debug_assertions)]
        self.audit_invariants(&[], false, "step end");
        Ok(finished)
    }

    /// Check the pool/tier conservation laws ([`crate::kvpool::audit`])
    /// against everything this engine can see: live row tables,
    /// prefix-cache forks, tier entries, and the preemption snapshots still
    /// queued inside the engine. `external` lists snapshot-carrying
    /// requests the *caller* holds (drained preemptions waiting in its
    /// queue) so their tier pins and ledgers are attributed rather than
    /// flagged. `strict_pins` additionally requires every pinned tier
    /// entry to be owned by a visible snapshot — only sound when
    /// `external` plus the engine's own queue covers all of them (i.e.
    /// after a full drain). Panics with an owner dump on violation.
    ///
    /// Dense-mode engines (no pool) have no distributed ownership to
    /// check; the call is a no-op. Public (and compiled in release) so the
    /// CI quick-bench gate can audit at drain points; only the automatic
    /// per-step hook above is debug-only.
    pub fn audit_invariants(&self, external: &[&Request], strict_pins: bool, context: &str) {
        use crate::kvpool::audit::{Auditor, LedgerRef, PinRef, TableRef, TierView};
        let Some(pool) = &self.pool else { return };
        let mut tables: Vec<TableRef> = Vec::new();
        let mut ledgers: Vec<LedgerRef> = Vec::new();
        for (i, row) in self.rows.iter().enumerate() {
            let Some(row) = row else { continue };
            if let Some(t) = row.seq.block_table() {
                tables.push(TableRef {
                    owner: format!("row {i} (req {})", row.req.id),
                    table: t,
                });
            }
            for e in &row.parked.entries {
                ledgers.push(LedgerRef {
                    owner: format!("row {i} (req {})", row.req.id),
                    tier_id: e.tier_id,
                    records: e.records.len(),
                });
            }
        }
        let mut pins: Vec<PinRef> = Vec::new();
        let queued = self.preempted.iter().map(|(_, r)| r);
        for r in queued.chain(external.iter().copied()) {
            let Some(st) = &r.resume else { continue };
            if let Some(swapped) = &st.swapped {
                for sb in swapped {
                    pins.push(PinRef {
                        owner: format!("preempted req {}", r.id),
                        tier_id: sb.tier_id,
                        rows: sb.rows,
                    });
                }
            }
            for e in &st.parked.entries {
                ledgers.push(LedgerRef {
                    owner: format!("preempted req {}", r.id),
                    tier_id: e.tier_id,
                    records: e.records.len(),
                });
            }
        }
        Auditor {
            pool,
            tables,
            cache_blocks: self
                .prefix_cache
                .as_ref()
                .map(|c| c.pinned_block_ids())
                .unwrap_or_default(),
            tier: self.tier.as_ref().map(TierView::of),
            pins,
            ledgers,
            strict_pins,
        }
        .assert_clean(context);
    }

    /// Park the eviction pass's demoted rows (`demote_buf`, slot order ⇒
    /// same-block rows contiguous with ascending offsets) into the host
    /// tier, one entry per source block, and record them in row `i`'s
    /// ledger. Must run after the logical compaction but before its
    /// `RowMove` list is applied (and before the next pool allocation) —
    /// the moves/reuse are what invalidates the demoted bytes. A park the
    /// tier refuses (budget full of pinned state) leaves that eviction
    /// destructive, exactly the pre-tier behavior.
    fn park_demoted(&mut self, i: usize) -> Result<()> {
        if self.tier.is_none() {
            self.demote_buf.clear();
            return Ok(());
        }
        let step_t = self.rows[i].as_ref().map(|r| r.pos).unwrap_or(0);
        let rid = self.rows[i].as_ref().map(|r| r.req.id).unwrap_or(0);
        let row_ctx = self.rows[i].as_ref().map(|r| r.span).unwrap_or_default();
        let demote_span = self.span_open(rid, span::name::DEMOTE, row_ctx, 0.0, "");
        let shed_before = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0);
        let re = {
            let d = self.exec.dims();
            d.n_layers * d.n_heads * d.d_head
        };
        let mut parked_tokens = 0usize;
        let demoted = std::mem::take(&mut self.demote_buf);
        let mut gi = 0;
        while gi < demoted.len() {
            let blk = demoted[gi].0;
            let mut ge = gi;
            while ge < demoted.len() && demoted[ge].0 == blk {
                ge += 1;
            }
            // offsets ascend within a block: the last one bounds the read
            let (k_all, v_all) = self.exec.swap_out_block(blk, demoted[ge - 1].1 + 1)?;
            let n = ge - gi;
            let mut k = Vec::with_capacity(n * re);
            let mut v = Vec::with_capacity(n * re);
            let mut records = Vec::with_capacity(n);
            for (_, off, rec) in &demoted[gi..ge] {
                k.extend_from_slice(&k_all[off * re..(off + 1) * re]);
                v.extend_from_slice(&v_all[off * re..(off + 1) * re]);
                records.push(rec.clone());
            }
            let bytes = (k.len() + v.len()) * std::mem::size_of::<f32>();
            match self
                .tier
                .as_mut()
                .expect("checked above")
                .park(k, v, n, false)
            {
                Some(id) => {
                    self.metrics.demoted_blocks += 1;
                    self.metrics.swap_out_bytes += bytes as u64;
                    parked_tokens += n;
                    if let Some(row) = self.rows[i].as_mut() {
                        row.parked.entries.push(ParkedEntry {
                            tier_id: id,
                            parked_at: step_t,
                            records,
                        });
                    }
                }
                None => {
                    self.metrics.tier_rejects += 1;
                    let live = self.rows[i].as_ref().map(|r| r.seq.len()).unwrap_or(0);
                    self.tele_event(
                        rid,
                        event::TIER_REJECT,
                        step_t as usize,
                        live,
                        self.metrics.tier_rejects as f64,
                        "demote",
                    );
                }
            }
            gi = ge;
        }
        self.demote_buf = demoted;
        self.demote_buf.clear();
        let shed = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0) - shed_before;
        if shed > 0 {
            let live = self.rows[i].as_ref().map(|r| r.seq.len()).unwrap_or(0);
            self.tele_event(rid, event::TIER_SHED, step_t as usize, live, shed as f64, "demote");
        }
        if parked_tokens > 0 {
            let live = self.rows[i].as_ref().map(|r| r.seq.len()).unwrap_or(0);
            self.tele_event(rid, event::DEMOTE, step_t as usize, live, parked_tokens as f64, "");
        }
        self.span_close(demote_span, Some(parked_tokens as f64), None);
        Ok(())
    }

    /// Promote row `i`'s parked entries whose observed importance score
    /// re-crossed the keep threshold — the weakest score the last eviction
    /// pass retained over the non-recent (scored) portion of the keep-set.
    /// A promoted entry's records are spliced back verbatim (the TS/MRI
    /// observation history is never re-initialized) and its K/V bytes are
    /// written at the freshly mapped slots, so from the next step on the
    /// token is attended exactly as if it had never been evicted. Promotion
    /// stays inside the lagged-design headroom (`live <= budget + W`) so it
    /// can never force-finish a row by filling the physical cache.
    fn promote_parked(&mut self, i: usize) -> Result<()> {
        if self.tier.is_none() {
            return Ok(());
        }
        // drop ledger refs to entries the tier shed under byte pressure —
        // those demotions silently became plain evictions — and bump the
        // recency of the survivors: this row is live and actively probing
        // them, so under budget pressure the tier sheds entries whose rows
        // are parked in the queue (nobody scoring them) first
        {
            let ids: Vec<TierBlockId> = {
                let tier = self.tier.as_ref().expect("checked above");
                let Some(row) = self.rows[i].as_mut() else {
                    return Ok(());
                };
                row.parked.entries.retain(|e| tier.contains(e.tier_id));
                row.parked.entries.iter().map(|e| e.tier_id).collect()
            };
            let tier = self.tier.as_mut().expect("checked above");
            for id in ids {
                tier.touch(id);
            }
        }
        let score_cfg = self.cfg.params.score;
        let w = self.cfg.params.window;
        let (step_t, rid, row_ctx, plan) = {
            let Some(row) = self.rows[i].as_ref() else {
                return Ok(());
            };
            if row.parked.entries.is_empty() || row.finish.is_some() {
                return Ok(());
            }
            let step_t = row.pos;
            let recs = row.seq.records();
            let mut by_pos: Vec<u32> = recs.iter().map(|r| r.pos).collect();
            by_pos.sort_unstable_by_key(|&p| std::cmp::Reverse(p));
            if by_pos.len() <= w {
                return Ok(()); // every live slot is the recent window
            }
            let cut = if w == 0 { u32::MAX } else { by_pos[w - 1] };
            let threshold = recs
                .iter()
                .filter(|r| r.pos < cut)
                .map(|r| importance(r, step_t, &score_cfg))
                .fold(f64::INFINITY, f64::min);
            let headroom_cap = (self.cfg.budget + w).min(self.cfg.cache.saturating_sub(1));
            let mut room = headroom_cap.saturating_sub(recs.len());
            let mut plan: Vec<TierBlockId> = Vec::new();
            for e in &row.parked.entries {
                if e.parked_at >= step_t || e.records.len() > room {
                    continue; // parked this very pass, or no headroom left
                }
                if e.records
                    .iter()
                    .any(|r| importance(r, step_t, &score_cfg) >= threshold)
                {
                    room -= e.records.len();
                    plan.push(e.tier_id);
                }
            }
            (step_t, row.req.id, row.span, plan)
        };
        if plan.is_empty() {
            return Ok(());
        }
        let promote_span = self.span_open(rid, span::name::PROMOTE, row_ctx, 0.0, "");
        let shed_before = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0);
        let mut promoted_tokens = 0usize;
        let re = {
            let d = self.exec.dims();
            d.n_layers * d.n_heads * d.d_head
        };
        for id in plan {
            // pull the entry out of the ledger and its bytes out of the tier
            let (records, parked_at, k, v) = {
                let row = self.rows[i].as_mut().expect("checked in planning");
                let at = row
                    .parked
                    .entries
                    .iter()
                    .position(|e| e.tier_id == id)
                    .expect("planned from this ledger");
                let entry = row.parked.entries.remove(at);
                let (k, v, rows) = self
                    .tier
                    .as_mut()
                    .expect("checked above")
                    .take(id)
                    .expect("ledger retained only resident entries");
                debug_assert_eq!(rows, entry.records.len());
                (entry.records, entry.parked_at, k, v)
            };
            let n = records.len();
            // the pool must cover the growth (plus a CoW of a shared tail,
            // which allocates one extra block); if it cannot, the bytes go
            // back to the tier untouched and promotion retries later
            let can = {
                let row = self.rows[i].as_ref().expect("checked");
                let pool = self.pool.as_ref().expect("tier implies pool");
                let t = row.seq.block_table().expect("pooled row has a table");
                let cow = usize::from(t.tail_is_shared(pool));
                let need = pool
                    .blocks_for(row.seq.len() + n)
                    .saturating_sub(t.n_blocks())
                    + cow;
                pool.free_blocks() >= need
            };
            if !can {
                let row = self.rows[i].as_mut().expect("checked");
                if let Some(nid) = self
                    .tier
                    .as_mut()
                    .expect("checked above")
                    .park(k, v, n, false)
                {
                    row.parked.entries.push(ParkedEntry {
                        tier_id: nid,
                        parked_at: step_t,
                        records,
                    });
                }
                break;
            }
            // splice: map one slot per record, then restore its exact bytes
            let bytes = (k.len() + v.len()) * std::mem::size_of::<f32>();
            for (j, rec) in records.into_iter().enumerate() {
                let (blk, off) = {
                    let row = self.rows[i].as_mut().expect("checked");
                    let pool = self.pool.as_mut().expect("tier implies pool");
                    let slot = row
                        .seq
                        .push_pooled_cow(rec, pool, &mut self.copy_buf)
                        .expect("pool headroom checked above");
                    let t = row.seq.block_table().expect("pooled row has a table");
                    t.locate(slot).expect("just pushed ⇒ mapped")
                };
                self.flush_block_copies()?;
                self.exec
                    .write_kv_rows(blk, off, &k[j * re..(j + 1) * re], &v[j * re..(j + 1) * re])?;
                self.metrics.false_evictions_avoided += 1;
            }
            // a promotion is a false eviction avoided: record how long the
            // token sat parked before its importance re-crossed the bar
            if let Some(obs) = self.recurrence.as_mut() {
                for _ in 0..n {
                    obs.observe_promotion(step_t.saturating_sub(parked_at));
                }
            }
            self.metrics.promotions += 1;
            self.metrics.swap_in_bytes += bytes as u64;
            promoted_tokens += n;
            let live = self.rows[i].as_ref().map(|r| r.seq.len()).unwrap_or(0);
            self.tele_event(rid, event::PROMOTE, step_t as usize, live, n as f64, "");
        }
        let shed = self.tier.as_ref().map(|t| t.shed_blocks).unwrap_or(0) - shed_before;
        if shed > 0 {
            let live = self.rows[i].as_ref().map(|r| r.seq.len()).unwrap_or(0);
            self.tele_event(rid, event::TIER_SHED, step_t as usize, live, shed as f64, "promote");
        }
        self.span_close(promote_span, Some(promoted_tokens as f64), None);
        Ok(())
    }

    fn finish_row(&mut self, i: usize) -> Response {
        let mut row = self.rows[i].take().expect("finish_row on empty row");
        if let Some(pool) = self.pool.as_mut() {
            row.seq.release_blocks(pool);
        }
        if let Some(tier) = self.tier.as_mut() {
            for e in row.parked.entries.drain(..) {
                tier.release(e.tier_id);
            }
        }
        if row.decode_span != 0 {
            self.span_close(row.decode_span, Some(row.decode_span_steps as f64), None);
            row.decode_span = 0;
        }
        self.span_ctxs.remove(&row.req.id);
        let total = row.admitted_at.elapsed().as_secs_f64();
        let ttft = row
            .first_token_at
            .map(|t| t.duration_since(row.admitted_at).as_secs_f64())
            .unwrap_or(total);
        self.metrics.record_finish(ttft, total, row.produced);
        self.tele_event(
            row.req.id,
            event::FINISH,
            row.pos as usize,
            row.seq.len(),
            row.produced as f64,
            row.finish.as_ref().map(|f| f.as_str()).unwrap_or(""),
        );
        Response {
            id: row.req.id,
            text: row.out_text,
            hole_predictions: row.hole_predictions,
            finish: row.finish.unwrap(),
            metrics: RequestMetrics {
                queued_s: row.queued_s,
                ttft_s: ttft,
                total_s: total,
                tokens_out: row.produced,
                evictions: row.evictions,
            },
            live_curve: row.live_curve,
        }
    }

    /// Convenience driver: run a whole list of requests to completion with
    /// continuous batching. Preempted requests rejoin the front of the
    /// pending queue oldest-victim-first and *resume* (recompute mode).
    /// Returns responses in completion order. Queue waits are measured from
    /// each request's enqueue, so `Response::metrics.queued_s` reports real
    /// hold time under pool pressure rather than a hard-coded zero.
    pub fn run_all(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut pending: std::collections::VecDeque<(Request, Instant)> =
            reqs.into_iter().map(|r| (r, t0)).collect();
        let mut done = Vec::new();
        self.metrics.start();
        loop {
            while self.has_free_row() {
                let Some((r, enq)) = pending.pop_front() else {
                    break;
                };
                if !self.submit(r.clone(), enq.elapsed().as_secs_f64())? {
                    // pool pressure: hold it until blocks free up
                    pending.push_front((r, enq));
                    break;
                }
            }
            if self.active() == 0 && pending.is_empty() {
                break;
            }
            done.extend(self.step()?);
            // nobody streams in batch mode — drop the step's token events
            // so the buffer stays bounded over arbitrarily long runs
            self.token_events.clear();
            self.publish_telemetry();
            // oldest victim first: reverse-push so slice order survives the
            // front insertion (resumed waits are tracked in the snapshot)
            let now = Instant::now();
            for r in self.take_preempted().into_iter().rev() {
                pending.push_front((r, now));
            }
        }
        self.metrics.stop();
        Ok(done)
    }
}

/// Stage a token stream into the prefill executable's padded bucket:
/// tokens at [0, n), zero padding and a matching validity mask beyond.
/// Shared by fresh prefill and recompute-mode resume.
fn padded_tokens(ids: &[u32], bucket: usize) -> (Vec<i32>, Vec<f32>) {
    debug_assert!(ids.len() <= bucket);
    let mut toks = vec![0i32; bucket];
    let mut valid = vec![0f32; bucket];
    for (i, &id) in ids.iter().enumerate() {
        toks[i] = id as i32;
        valid[i] = 1.0;
    }
    (toks, valid)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FinishReason;
    use crate::kvpool::PoolConfig;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    fn sim_cfg(batch: usize, pool: Option<PoolConfig>) -> EngineConfig {
        let mut cfg = EngineConfig {
            batch,
            cache: 64,
            budget: 40,
            policy: "lazy".into(),
            record_live: true,
            pool,
            ..Default::default()
        };
        cfg.params.window = 8;
        cfg.params.recent = 8;
        cfg
    }

    fn req(id: u64, max_new: usize) -> Request {
        Request {
            id,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            resume: None,
        }
    }

    #[test]
    fn sim_engine_generates_deterministically() {
        let mut e1 = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let mut e2 = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r1 = e1.run_all(vec![req(1, 32)]).unwrap();
        let r2 = e2.run_all(vec![req(1, 32)]).unwrap();
        assert_eq!(r1.len(), 1);
        assert_eq!(r1[0].text, r2[0].text);
        assert_eq!(r1[0].metrics.tokens_out, 32);
        assert_eq!(r1[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn sim_engine_evicts_under_tight_budget() {
        let mut e = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r = e.run_all(vec![req(1, 60)]).unwrap();
        assert!(r[0].metrics.evictions > 0, "no evictions at budget 40");
        assert!(r[0].live_curve.iter().all(|&l| l <= 64));
    }

    #[test]
    fn sim_engine_fills_template_holes() {
        let mut e = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r = e
            .run_all(vec![Request {
                id: 9,
                prompt: "#A=3;\n>".into(),
                template: "A=?;".into(),
                max_new: 32,
                resume: None,
            }])
            .unwrap();
        assert_eq!(r[0].finish, FinishReason::TemplateDone);
        assert_eq!(r[0].hole_predictions.len(), 1);
        assert!(r[0].text.starts_with("A="));
    }

    #[test]
    fn pooled_engine_tracks_block_usage() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 1,
            high_watermark: 2,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        let g0 = e.pool_gauges().unwrap();
        assert_eq!(g0.free_blocks, 16);
        let r = e.run_all(vec![req(1, 40)]).unwrap();
        assert_eq!(r[0].metrics.tokens_out, 40);
        // drained up to the prefix cache's pin on the prompt's whole block
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 1);
        assert_eq!(g.prefix_pinned_blocks, 1); // 11-token prompt, 8-block
        assert_eq!(g.free_blocks, 15);
        assert_eq!(g.preemptions, 0);
        // clearing the cache releases the pin: fully free again
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
    }

    #[test]
    fn observe_recurrence_is_output_invariant_and_records() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 1,
            high_watermark: 2,
        };
        let mk = |observe: bool| {
            let mut cfg = sim_cfg(1, Some(pool.clone()));
            cfg.host_tier = Some(crate::kvtier::HostTierConfig::default());
            cfg.observe_recurrence = observe;
            Engine::new_sim(cfg).unwrap()
        };
        let mut on = mk(true);
        let mut off = mk(false);
        let r_on = on.run_all(vec![req(1, 60)]).unwrap();
        let r_off = off.run_all(vec![req(1, 60)]).unwrap();
        // the observatory only observes: engine output is byte-identical
        assert_eq!(r_on[0].text, r_off[0].text);
        assert_eq!(r_on[0].metrics.evictions, r_off[0].metrics.evictions);
        assert_eq!(r_on[0].live_curve, r_off[0].live_curve);
        assert!(off.recurrence().is_none());
        let obs = on.recurrence().expect("flag on ⇒ observatory present");
        assert!(obs.passes_total > 0, "budget 40 / 60 tokens must evict");
        assert!(obs.decisions_total > 0);
        assert!(obs.mri_hist.n() > 0);
        let pass = obs.passes().next().expect("ring holds the passes");
        assert_eq!(pass.req, 1);
        assert!(!pass.decisions.is_empty());
    }

    #[test]
    fn pool_preemption_round_trip() {
        // 9 blocks x 8 tokens: one row needs ~6 blocks near its 40-token
        // budget (+window), so two concurrent rows must collide and the
        // youngest must be preempted, re-queued, and still complete.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 9,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        let reqs = (0..3).map(|i| req(i, 50)).collect();
        let rs = e.run_all(reqs).unwrap();
        assert_eq!(rs.len(), 3);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &rs {
            assert_eq!(r.metrics.tokens_out, 50, "request {} cut short", r.id);
        }
        assert!(
            e.metrics.preemptions >= 1,
            "two 6-block rows in a 9-block pool must preempt"
        );
        assert!(
            e.metrics.resumes >= 1 && e.metrics.resume_fallbacks == 0,
            "preempted rows must resume via recompute, not restart"
        );
        // leak-free: beyond the cache pin the drained pool is fully free
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 9);
    }

    #[test]
    fn abort_rows_clears_engine_and_pool() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        assert!(e.submit(req(1, 40), 0.0).unwrap());
        assert!(e.submit(req(2, 40), 0.0).unwrap());
        for _ in 0..5 {
            e.step().unwrap();
        }
        let mut ids = e.abort_rows();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(e.active(), 0);
        // aborted rows returned their blocks; nothing was re-queued. Only
        // the prefix cache's pin on the shared prompt block remains.
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
        assert!(e.take_preempted().is_empty());
        assert!(e.abort_rows().is_empty());
    }

    // 19-token prompt: private admission needs blocks_for(20) = 3 free blocks
    fn big(id: u64) -> Request {
        Request {
            id,
            prompt: "#A=3;B=7;C=2;D=5;\n>".into(),
            template: String::new(),
            max_new: 50,
            resume: None,
        }
    }

    #[test]
    fn pool_admission_defers_when_free_blocks_short() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        // prefix sharing off: this is the private-allocation admission path
        let mut cfg = sim_cfg(2, Some(pool));
        cfg.prefix_cache = None;
        let mut e = Engine::new_sim(cfg).unwrap();
        assert!(e.submit(big(1), 0.0).unwrap());
        // 25 decode steps: row 1 is at live = 19 + 25 = 44 tokens = 6 of the
        // 8 blocks (first lazy eviction only lands at pos 48), so free = 2
        for _ in 0..25 {
            e.step().unwrap();
            assert!(e.take_preempted().is_empty(), "solo row must never preempt");
        }
        assert!(
            !e.submit(big(2), 0.0).unwrap(),
            "admission must defer while the pool cannot cover the prompt"
        );
        assert!(e.has_free_row(), "the decline must come from the pool, not rows");
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 2);
    }

    #[test]
    fn prefix_sharing_admits_where_private_allocation_cannot() {
        // Same shape as pool_admission_defers_when_free_blocks_short, but
        // with the prefix cache on: the identical prompt's two whole blocks
        // are forked from the first row, so the second admission only needs
        // one private block — and 2 are free.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        assert!(e.submit(big(1), 0.0).unwrap());
        for _ in 0..25 {
            e.step().unwrap();
        }
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 1);
        assert_eq!(g.prefix_misses, 1);
        assert!(
            e.submit(big(2), 0.0).unwrap(),
            "an identical prompt must be admitted through block sharing"
        );
        assert_eq!(e.active(), 2);
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_hits, 1);
        assert!(g.shared_blocks >= 2, "prompt blocks shared: {g:?}");
        // both requests complete (one may preempt and retry under this
        // tight pool) and the pool drains once the cache pin is released
        let mut done: Vec<u64> = Vec::new();
        let mut pending: Vec<Request> = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap().into_iter().map(|r| r.id));
            pending.extend(e.take_preempted());
            while let Some(r) = pending.pop() {
                if !e.submit(r.clone(), 0.0).unwrap() {
                    pending.push(r);
                    break;
                }
            }
            if e.active() == 0 && pending.is_empty() {
                break;
            }
        }
        done.sort_unstable();
        assert_eq!(done, vec![1, 2]);
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 8);
    }

    #[test]
    fn prefix_hit_skips_prefill_entirely() {
        // The physical-paging acceptance test: an identical prompt's second
        // admission runs ZERO prefill executions — the cached blocks are the
        // data and the seed supplies tail rows + tracker + first logits —
        // and the generated text is byte-identical to the cold run.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        let r1 = e.run_all(vec![req(1, 24)]).unwrap();
        assert_eq!(e.exec_counts().prefill, 1);
        assert_eq!(e.pool_gauges().unwrap().prefix_prefill_skips, 0);
        let r2 = e.run_all(vec![req(2, 24)]).unwrap();
        assert_eq!(
            e.exec_counts().prefill,
            1,
            "identical prompt must not prefill again"
        );
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_prefill_skips, 1);
        assert_eq!(g.prefix_hits, 1);
        assert_eq!(r1[0].text, r2[0].text, "seeded admission changed output");
        // a prompt with the same whole-block header but a divergent tail
        // gets the block sharing — and MUST still run its own prefill
        let r3 = e
            .run_all(vec![Request {
                id: 3,
                prompt: "#A=3;B=7;\n?".into(), // last char differs (slot 10)
                template: String::new(),
                max_new: 24,
                resume: None,
            }])
            .unwrap();
        assert_eq!(r3.len(), 1);
        assert_eq!(e.exec_counts().prefill, 2, "divergent tail must prefill");
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_hits, 2, "the shared header still counts as a hit");
        assert_eq!(g.prefix_prefill_skips, 1, "but not as a prefill skip");
    }

    #[test]
    fn arena_rows_track_records_through_eviction() {
        // End-to-end physical consistency: after admissions, CoW and several
        // eviction compactions, every live slot's stored K bytes must still
        // encode the token the records say lives there (the sim writes the
        // birth position into k_row[0]).
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        assert!(e.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..45 {
            e.step().unwrap();
        }
        let row = e.rows[0].as_ref().expect("row still decoding");
        assert!(row.evictions > 0, "test must cross an eviction pass");
        let t = row.seq.block_table().unwrap();
        for (slot, rec) in row.seq.records().iter().enumerate() {
            let (blk, off) = t.locate(slot).unwrap();
            let (k, _) = e.backend_kv_row(blk, off).expect("sim arena readable");
            assert_eq!(
                k[0] as u32, rec.pos,
                "slot {slot}: stored bytes diverged from records after compaction"
            );
        }
    }

    #[test]
    fn stale_pins_shed_to_reopen_admission() {
        // Five distinct prompts each leave a one-block cache pin behind.
        // With the engine drained, those pins are the only pool pressure;
        // the relief valve must restore free blocks to the high watermark
        // so the serve loop's admission latch can reopen.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 2,
            high_watermark: 6,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        for (i, p) in ["#A=1;B=2;\n>", "#A=2;B=3;\n>", "#A=3;B=4;\n>", "#A=4;B=5;\n>", "#A=5;B=6;\n>"]
            .iter()
            .enumerate()
        {
            let r = e
                .run_all(vec![Request {
                    id: i as u64,
                    prompt: (*p).into(),
                    template: String::new(),
                    max_new: 8,
                    resume: None,
                }])
                .unwrap();
            assert_eq!(r.len(), 1);
        }
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 5);
        assert_eq!(g.prefix_pinned_blocks, 5);
        assert_eq!(g.free_blocks, 3); // below the high watermark of 6
        e.shed_prefix_to_high_watermark();
        let g = e.pool_gauges().unwrap();
        assert!(g.free_blocks >= 6, "valve must reach the high watermark");
        assert_eq!(g.prefix_entries, 2);
    }

    #[test]
    fn divergent_tails_copy_on_write_without_corruption() {
        // Prompts share their first whole block (8 identical chars) then
        // diverge. Under sharing, each row's output must match the output
        // of a solo, sharing-free run of the same prompt — byte for byte.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let prompts = ["#A=3;B=7;C=2;\n>", "#A=3;B=7;D=9;\n>", "#A=3;B=7;E=1;\n>"];
        let solo: Vec<String> = prompts
            .iter()
            .map(|p| {
                let mut cfg = sim_cfg(1, None);
                cfg.prefix_cache = None;
                let mut e = Engine::new_sim(cfg).unwrap();
                let r = e
                    .run_all(vec![Request {
                        id: 0,
                        prompt: (*p).into(),
                        template: String::new(),
                        max_new: 40,
                        resume: None,
                    }])
                    .unwrap();
                r[0].text.clone()
            })
            .collect();

        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: i as u64,
                prompt: (*p).into(),
                template: String::new(),
                max_new: 40,
                resume: None,
            })
            .collect();
        let mut rs = e.run_all(reqs).unwrap();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.len(), 3);
        for (r, want) in rs.iter().zip(solo.iter()) {
            assert_eq!(&r.text, want, "request {} corrupted under sharing", r.id);
        }
        let g = e.pool_gauges().unwrap();
        assert!(g.prefix_hits >= 2, "later prompts must hit the shared block");
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
    }

    fn policy_cfg(policy: &str) -> EngineConfig {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut cfg = sim_cfg(1, Some(pool));
        cfg.policy = policy.into();
        cfg
    }

    #[test]
    fn resume_preserves_tracker_and_output_across_policies() {
        // The acceptance property: a preempted-and-resumed row is
        // byte-identical to a never-preempted run — same output, same
        // eviction keep-sets — because the tracker records (TS/MRI/H1/H2
        // observation history) survive the round trip instead of being
        // re-initialized. Checked for the lagged policy and three greedy
        // baselines whose scores all read different record fields.
        for policy in ["lazy", "h2o", "tova", "streaming"] {
            let mut a = Engine::new_sim(policy_cfg(policy)).unwrap(); // never preempted
            let mut b = Engine::new_sim(policy_cfg(policy)).unwrap(); // preempted at step 35
            assert!(a.submit(req(1, 45), 0.0).unwrap());
            assert!(b.submit(req(1, 45), 0.0).unwrap());
            for _ in 0..35 {
                a.step().unwrap();
                b.step().unwrap();
            }
            b.preempt_row(0);
            assert_eq!(b.active(), 0);
            let mut pre = b.take_preempted();
            assert_eq!(pre.len(), 1);
            {
                let st = pre[0].resume.as_ref().expect("snapshot attached");
                assert!(st.finish.is_none());
                assert!(st.produced > 1);
                assert!(!st.records.is_empty());
            }
            assert!(b.submit(pre.pop().unwrap(), 0.0).unwrap());
            assert_eq!(b.metrics.resumes, 1, "{policy}");
            assert_eq!(
                b.metrics.resume_fallbacks, 0,
                "{policy}: must recompute, not restart"
            );
            assert!(b.metrics.recomputed_tokens > 0, "{policy}");
            let same_records = |a: &Engine, b: &Engine, at: &str| {
                let ra = a.rows[0].as_ref().unwrap().seq.records();
                let rb = b.rows[0].as_ref().unwrap().seq.records();
                assert_eq!(ra.len(), rb.len(), "{policy} ({at}): keep-set size");
                for (x, y) in ra.iter().zip(rb.iter()) {
                    assert_eq!(x.pos, y.pos, "{policy} ({at}): keep-set identity");
                    assert_eq!(x.ts, y.ts, "{policy} ({at}): TS");
                    assert_eq!(x.mri, y.mri, "{policy} ({at}): MRI must survive");
                    assert_eq!(x.hits, y.hits, "{policy} ({at})");
                    assert_eq!(x.last_attn, y.last_attn, "{policy} ({at})");
                    assert_eq!(x.cum_attn, y.cum_attn, "{policy} ({at})");
                }
            };
            // restored, not re-initialized: records match the control engine
            // immediately after resume, and eviction decisions stay in
            // lockstep over the following steps
            same_records(&a, &b, "post-resume");
            for _ in 0..5 {
                a.step().unwrap();
                b.step().unwrap();
            }
            same_records(&a, &b, "post-resume + 5 steps");
            let finish = |e: &mut Engine| -> Vec<Response> {
                let mut out = Vec::new();
                for _ in 0..10_000 {
                    out.extend(e.step().unwrap());
                    if e.active() == 0 {
                        break;
                    }
                }
                out
            };
            let ra = finish(&mut a);
            let rb = finish(&mut b);
            assert_eq!(ra.len(), 1);
            assert_eq!(rb.len(), 1);
            assert_eq!(ra[0].text, rb[0].text, "{policy}: output diverged");
            assert_eq!(
                ra[0].metrics.evictions, rb[0].metrics.evictions,
                "{policy}: eviction history diverged"
            );
            assert_eq!(ra[0].metrics.tokens_out, rb[0].metrics.tokens_out);
            assert_eq!(ra[0].live_curve, rb[0].live_curve, "{policy}: live curves");
        }
    }

    #[test]
    fn same_step_preemption_victims_requeue_oldest_first() {
        // Four rows in an 8-block pool: one long private row, one donor row
        // and two pure prefix forks. When all three 16-token rows hit a
        // block boundary in the same step with one free block, the two
        // forks (whose releases free nothing — every block they hold is
        // shared) are both preempted in ONE ensure_block_headroom pass.
        // take_preempted must hand them back oldest victim first.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(4, Some(pool))).unwrap();
        let mk = |id: u64, prompt: &str| Request {
            id,
            prompt: prompt.into(),
            template: String::new(),
            max_new: 24,
            resume: None,
        };
        let prompt_a = format!("#{}\n>", "A=1;".repeat(8)); // 35 chars → 5 blocks
        let p16 = "#A=3;B=7;C=25;\n>"; // exactly 2 whole blocks
        assert_eq!(p16.chars().count(), 16);
        assert!(e.submit(mk(0, &prompt_a), 0.0).unwrap());
        assert!(e.submit(mk(1, p16), 0.0).unwrap()); // donor: allocates 2
        assert!(e.submit(mk(2, p16), 0.0).unwrap()); // fork: allocates 0
        assert!(e.submit(mk(3, p16), 0.0).unwrap()); // fork: allocates 0
        assert_eq!(e.active(), 4);
        e.step().unwrap();
        let pre = e.take_preempted();
        assert_eq!(pre.len(), 2, "both forks must be preempted in one step");
        assert_eq!(pre[0].id, 2, "oldest victim must drain first");
        assert_eq!(pre[1].id, 3);
        for r in &pre {
            let st = r.resume.as_ref().expect("victims carry resume state");
            assert_eq!(st.records.len(), 16);
            assert!(st.finish.is_none());
        }
        // resubmit oldest-first and drive everything to completion: the
        // resumed rows recompute (no fallback) and identical prompts still
        // produce identical outputs
        let mut pending: std::collections::VecDeque<Request> = pre.into_iter().collect();
        let mut done: Vec<Response> = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap());
            for r in e.take_preempted().into_iter().rev() {
                pending.push_front(r);
            }
            while e.has_free_row() {
                let Some(r) = pending.pop_front() else { break };
                if !e.submit(r.clone(), 0.0).unwrap() {
                    pending.push_front(r);
                    break;
                }
            }
            if e.active() == 0 && pending.is_empty() {
                break;
            }
        }
        let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(e.metrics.resumes >= 2, "forks must resume, not restart");
        assert_eq!(e.metrics.resume_fallbacks, 0);
        // the victims' live sets were pure cached-prefix forks when first
        // preempted, so their resumes re-fork the entry (counted as prefix
        // hits) — and a resume whose fork covers the whole live set skips
        // the recompute prefill outright
        assert!(e.pool_gauges().unwrap().prefix_hits >= 2);
        done.sort_by_key(|r| r.id);
        assert_eq!(done[1].text, done[2].text, "resumed fork diverged");
        assert_eq!(done[1].text, done[3].text, "resumed fork diverged");
    }

    #[test]
    fn resume_accumulates_queue_wait_and_preserves_timing() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        assert!(e.submit(req(1, 40), 0.25).unwrap());
        for _ in 0..10 {
            e.step().unwrap();
        }
        e.preempt_row(0);
        let mut pre = e.take_preempted();
        std::thread::sleep(std::time::Duration::from_millis(40));
        // the 0.0 here is ignored: the resumed wait is the snapshot's
        // accumulated 0.25 s plus the measured re-queue time
        assert!(e.submit(pre.pop().unwrap(), 0.0).unwrap());
        let mut resp = None;
        for _ in 0..10_000 {
            let done = e.step().unwrap();
            if let Some(r) = done.into_iter().next() {
                resp = Some(r);
                break;
            }
        }
        let r = resp.expect("resumed row completes");
        assert!(
            r.metrics.queued_s >= 0.28,
            "queue wait must accumulate across preemption: {}",
            r.metrics.queued_s
        );
        // TTFT is a first-admission property — it predates the preemption,
        // so the 40 ms re-queue sleep must separate it from completion
        // (a relative bound: an absolute one would flake on slow runners)
        assert!(
            r.metrics.total_s - r.metrics.ttft_s >= 0.035,
            "ttft {} must not absorb the re-queue wait (total {})",
            r.metrics.ttft_s,
            r.metrics.total_s
        );
        assert!(r.metrics.total_s >= 0.04, "total {}", r.metrics.total_s);
        assert_eq!(r.metrics.tokens_out, 40);
        assert_eq!(e.metrics.resumes, 1);
    }

    fn tier_cfg(policy: &str, mode: crate::coordinator::PreemptMode) -> EngineConfig {
        use crate::kvtier::HostTierConfig;
        let mut cfg = policy_cfg(policy);
        cfg.host_tier = Some(HostTierConfig { max_bytes: 1 << 20 });
        cfg.preempt_mode = mode;
        cfg
    }

    #[test]
    fn tier_demotes_and_promotes_recurring_tokens() {
        use std::collections::HashMap;
        // lazy + host tier: eviction passes park their evicted blocks, and
        // tokens whose importance re-crosses the keep threshold come back.
        let mut e = Engine::new_sim(tier_cfg("lazy", PreemptMode::Recompute)).unwrap();
        assert!(e.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..52 {
            e.step().unwrap();
        }
        assert!(e.metrics.demoted_blocks > 0, "evictions must park blocks");
        assert!(
            e.metrics.promotions > 0,
            "recurring tokens must promote back from the tier"
        );
        assert!(e.metrics.false_evictions_avoided > 0);
        assert_eq!(e.metrics.tier_rejects, 0, "1 MiB budget must suffice here");
        let g = e.pool_gauges().unwrap();
        assert!(g.swap_out_bytes > 0 && g.swap_in_bytes > 0);
        // byte fidelity: every live slot — including every promoted one —
        // must hold exactly the K/V a never-evicted FullKV control holds
        // for the same position (the round trip preserved the bytes).
        let mut c = Engine::new_sim(EngineConfig {
            batch: 1,
            cache: 128,
            budget: 120,
            policy: "full".into(),
            pool: Some(PoolConfig {
                block_size: 8,
                n_blocks: 16,
                low_watermark: 0,
                high_watermark: 0,
            }),
            ..Default::default()
        })
        .unwrap();
        assert!(c.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..52 {
            c.step().unwrap();
        }
        let control: HashMap<u32, (u32, usize)> = c
            .debug_row_slots(0)
            .unwrap()
            .into_iter()
            .map(|(pos, b, o)| (pos, (b, o)))
            .collect();
        let slots = e.debug_row_slots(0).unwrap();
        assert!(!slots.is_empty());
        for (pos, blk, off) in slots {
            let (k, v) = e.backend_kv_row(blk, off).unwrap();
            let &(cb, co) = control.get(&pos).expect("control keeps everything");
            let (ck, cv) = c.backend_kv_row(cb, co).unwrap();
            assert_eq!(k, ck, "pos {pos}: K bytes diverged across the tier");
            assert_eq!(v, cv, "pos {pos}: V bytes diverged across the tier");
        }
        // and the generated text matches a tier-free run of the same config
        let finish = |e: &mut Engine| -> String {
            for _ in 0..10_000 {
                let done = e.step().unwrap();
                if let Some(r) = done.into_iter().next() {
                    return r.text;
                }
            }
            panic!("row never finished");
        };
        let tier_text = finish(&mut e);
        let mut plain = Engine::new_sim(policy_cfg("lazy")).unwrap();
        let plain_text = plain.run_all(vec![req(1, 60)]).unwrap()[0].text.clone();
        assert_eq!(tier_text, plain_text, "the tier must not change outputs");
    }

    #[test]
    fn swap_preemption_resumes_byte_identical_past_the_prefill_bucket() {
        // Preempt at a fed-stream length past the prefill bucket: recompute
        // mode would fall back to a restart here, swap mode must not — the
        // parked bytes need no re-prefill. Control and victim run the same
        // tiered config, so demotions/promotions stay in lockstep too.
        let mut a = Engine::new_sim(tier_cfg("lazy", PreemptMode::Swap)).unwrap();
        let mut b = Engine::new_sim(tier_cfg("lazy", PreemptMode::Swap)).unwrap();
        assert!(a.submit(req(1, 70), 0.0).unwrap());
        assert!(b.submit(req(1, 70), 0.0).unwrap());
        for _ in 0..60 {
            a.step().unwrap();
            b.step().unwrap();
        }
        b.preempt_row(0);
        assert_eq!(b.metrics.swap_preempts, 1, "swap mode must park the table");
        let mut pre = b.take_preempted();
        assert_eq!(pre.len(), 1);
        {
            let st = pre[0].resume.as_ref().expect("snapshot attached");
            assert!(st.swapped.is_some(), "snapshot must carry the parked table");
            assert!(
                st.pos as usize > 64,
                "the scenario must cross the prefill bucket (pos {})",
                st.pos
            );
        }
        assert!(b.submit(pre.pop().unwrap(), 0.0).unwrap());
        assert_eq!(b.metrics.resumes, 1);
        assert_eq!(
            b.metrics.resume_fallbacks, 0,
            "swap resume has no bucket cliff"
        );
        assert_eq!(
            b.metrics.recomputed_tokens, 0,
            "swap resume must not re-prefill"
        );
        assert!(b.metrics.swap_in_bytes > 0);
        // records restored verbatim and in lockstep with the control
        let same_records = |a: &Engine, b: &Engine, at: &str| {
            let ra = a.rows[0].as_ref().unwrap().seq.records();
            let rb = b.rows[0].as_ref().unwrap().seq.records();
            assert_eq!(ra.len(), rb.len(), "({at}) keep-set size");
            for (x, y) in ra.iter().zip(rb.iter()) {
                assert_eq!(x.pos, y.pos, "({at}) keep-set identity");
                assert_eq!(x.ts, y.ts, "({at}) TS");
                assert_eq!(x.mri, y.mri, "({at}) MRI");
            }
        };
        same_records(&a, &b, "post-resume");
        let finish = |e: &mut Engine| -> Response {
            for _ in 0..10_000 {
                let done = e.step().unwrap();
                if let Some(r) = done.into_iter().next() {
                    return r;
                }
            }
            panic!("row never finished");
        };
        let ra = finish(&mut a);
        let rb = finish(&mut b);
        assert_eq!(ra.text, rb.text, "swap resume diverged from the control");
        assert_eq!(ra.metrics.tokens_out, rb.metrics.tokens_out);
        assert_eq!(ra.live_curve, rb.live_curve);
        // the pinned entries were consumed: nothing left but (possibly)
        // demotion parks, which died with their rows
        assert_eq!(b.pool_gauges().unwrap().parked_blocks, 0);
    }

    #[test]
    fn resume_reforks_a_still_cached_prompt_prefix() {
        // ROADMAP PR-4 refinement: a row preempted before its first
        // eviction still has the prompt prefix as its leading slots, so its
        // recompute resume re-forks the cached entry instead of allocating
        // privately — counted under prefix_hits.
        let solo = {
            let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
            e.run_all(vec![req(1, 45)]).unwrap()[0].text.clone()
        };
        let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
        assert!(e.submit(req(1, 45), 0.0).unwrap());
        for _ in 0..10 {
            e.step().unwrap(); // well before the first eviction at pos 48
        }
        assert_eq!(e.pool_gauges().unwrap().prefix_hits, 0);
        e.preempt_row(0);
        let mut pre = e.take_preempted();
        assert!(pre[0].resume.as_ref().unwrap().swapped.is_none());
        assert!(e.submit(pre.pop().unwrap(), 0.0).unwrap());
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_hits, 1, "the resume must re-fork the cached prefix");
        assert!(
            g.shared_blocks >= 1,
            "the resumed row shares the entry's whole block: {g:?}"
        );
        assert!(e.metrics.recomputed_tokens > 0, "the tail still recomputes");
        let mut out = None;
        for _ in 0..10_000 {
            let done = e.step().unwrap();
            if let Some(r) = done.into_iter().next() {
                out = Some(r);
                break;
            }
        }
        assert_eq!(out.expect("finishes").text, solo, "re-fork changed output");
    }

    #[test]
    fn resume_falls_back_to_restart_when_stream_outgrows_bucket() {
        // 11-token prompt + 56 generated tokens = a 67-token fed stream,
        // past the sim's 64-token prefill bucket: recompute is impossible
        // in one pass, so the resume restarts from the prompt (counted).
        let solo = {
            let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
            e.run_all(vec![req(1, 60)]).unwrap()[0].text.clone()
        };
        let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
        assert!(e.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..55 {
            e.step().unwrap();
        }
        e.preempt_row(0);
        let mut pre = e.take_preempted();
        assert!(pre[0].resume.as_ref().unwrap().pos > 64);
        assert!(e.submit(pre.pop().unwrap(), 0.0).unwrap());
        assert_eq!(e.metrics.resume_fallbacks, 1);
        assert_eq!(e.metrics.resumes, 0);
        let mut done = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap());
            if e.active() == 0 {
                break;
            }
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].metrics.tokens_out, 60, "restart regenerates fully");
        assert_eq!(done[0].text, solo, "restart output must still match");
    }
}
