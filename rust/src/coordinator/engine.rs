//! The decode-loop engine: continuous batching over a fixed-row executable,
//! TS/MRI tracking from the step's exported attention, and lagged/greedy KV
//! eviction compiled down to device-side gathers. This is the request path —
//! no Python, no model code, just PJRT executions orchestrated from Rust.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::attention::{observe, TrackerConfig};
use crate::coordinator::row::RowState;
use crate::coordinator::{EngineConfig, Request, Response};
use crate::eviction::{self, Policy};
use crate::kvcache::TokenRecord;
use crate::metrics::{EngineMetrics, RequestMetrics};
use crate::runtime::{Client, Manifest, ModelExecutor};
use crate::tokenizer::Tokenizer;

pub struct Engine {
    pub cfg: EngineConfig,
    exec: ModelExecutor,
    pub tokenizer: Tokenizer,
    policy: Box<dyn Policy>,
    rows: Vec<Option<RowState>>,
    pub metrics: EngineMetrics,
    vocab: usize,
    // staging buffers reused across steps (no per-step allocation)
    mask_buf: Vec<f32>,
    tok_buf: Vec<i32>,
    pos_buf: Vec<i32>,
    idx_buf: Vec<i32>,
    gather_buf: Vec<i32>,
}

impl Engine {
    pub fn new(client: &Client, manifest: &Manifest, cfg: EngineConfig) -> Result<Engine> {
        cfg.validate()?;
        let exec = ModelExecutor::new(client, manifest, cfg.batch, cfg.cache)
            .context("building executor")?;
        let tokenizer = Tokenizer::new(&manifest.charset);
        let policy = eviction::build(&cfg.policy, &cfg.params)?;
        let (b, s) = (cfg.batch, cfg.cache);
        Ok(Engine {
            vocab: manifest.model.vocab,
            tokenizer,
            policy,
            rows: (0..b).map(|_| None).collect(),
            metrics: EngineMetrics::default(),
            mask_buf: vec![0.0; b * s],
            tok_buf: vec![0; b],
            pos_buf: vec![0; b],
            idx_buf: vec![0; b],
            gather_buf: vec![0; b * s],
            exec,
            cfg,
        })
    }

    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    pub fn active(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }

    pub fn has_free_row(&self) -> bool {
        self.rows.iter().any(|r| r.is_none())
    }

    pub fn exec_counts(&self) -> crate::runtime::executor::ExecCounts {
        self.exec.exec_counts
    }

    /// Extract the layer-0 concat-heads key vector for slot data laid out
    /// as [L, H, ..., dh] — the R-KV similarity sketch.
    fn sketch_from(&self, data: &[f32], h_stride: usize, slot: usize) -> Vec<f32> {
        let d = self.exec.dims();
        let (h, dh) = (d.n_heads, d.d_head);
        let mut out = Vec::with_capacity(h * dh);
        for head in 0..h {
            let base = (head * h_stride + slot) * dh;
            out.extend_from_slice(&data[base..base + dh]);
        }
        out
    }

    /// Admit a request into a free row: prefill, insert, initialize records.
    /// Returns false (request untouched) when no row is free.
    pub fn submit(&mut self, req: Request, queued_s: f64) -> Result<bool> {
        let Some(row_idx) = self.rows.iter().position(|r| r.is_none()) else {
            return Ok(false);
        };
        let p_bucket = self.exec.prefill_bucket;
        let ids = self
            .tokenizer
            .encode(&req.prompt)
            .map_err(|e| anyhow::anyhow!("prompt: {e}"))?;
        anyhow::ensure!(!ids.is_empty(), "empty prompt");
        anyhow::ensure!(
            ids.len() <= p_bucket,
            "prompt len {} exceeds prefill bucket {}",
            ids.len(),
            p_bucket
        );
        anyhow::ensure!(
            ids.len() < self.cfg.budget,
            "prompt len {} must be < budget {}",
            ids.len(),
            self.cfg.budget
        );

        let t0 = Instant::now();
        let mut toks = vec![0i32; p_bucket];
        let mut valid = vec![0f32; p_bucket];
        for (i, &id) in ids.iter().enumerate() {
            toks[i] = id as i32;
            valid[i] = 1.0;
        }
        let out = self.exec.prefill(&toks, &valid)?;
        self.exec.insert(&out.k_seq, &out.v_seq, row_idx)?;
        self.metrics.record_prefill(t0.elapsed());

        let mut row = RowState::new(req, self.cfg.cache, queued_s);
        let p = ids.len();
        let d = self.exec.dims();
        let h_stride = self.cfg.cache; // k_seq is [L, H, S, dh]
        for (i, _) in ids.iter().enumerate() {
            let mut rec = TokenRecord::new(i as u32, i as u32);
            rec.last_attn = 1.0;
            if self.cfg.collect_sketches {
                rec.key_sketch = self.sketch_from(&out.k_seq[..d.n_heads * h_stride * d.d_head], h_stride, i);
            }
            row.seq.push(rec);
        }
        // one observation from the last prompt row's attention
        observe(
            row.seq.records_mut(),
            &out.attn_last[..p],
            (p - 1) as u32,
            TrackerConfig {
                alpha: self.cfg.alpha,
            },
        );
        row.pos = p as u32;

        // first prediction comes from the prefill logits
        let pred_id = argmax(&out.logits_last);
        let pred = self.tokenizer.char_of(pred_id as u32).unwrap_or(' ');
        match row.advance_with_prediction(pred, self.cfg.stop_char) {
            Some(c) => {
                row.next_token = self.tokenizer.id(c).unwrap_or(0);
                self.rows[row_idx] = Some(row);
            }
            None => {
                // degenerate: finished without a single decode step
                self.rows[row_idx] = Some(row);
            }
        }
        Ok(true)
    }

    /// One decode iteration over all active rows. Returns finished responses.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let (b, s) = (self.cfg.batch, self.cfg.cache);
        // collect immediately-finished rows (prefill-finished), and
        // force-finish rows whose cache is physically full and whose policy
        // cannot shed tokens (FullKV hitting capacity)
        let mut finished = Vec::new();
        for i in 0..b {
            if let Some(row) = self.rows[i].as_mut() {
                if row.finish.is_none() && row.seq.len() >= self.cfg.cache {
                    row.finish = Some(crate::coordinator::FinishReason::MaxTokens);
                }
            }
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        if self.rows.iter().all(|r| r.is_none()) {
            return Ok(finished);
        }

        let t0 = Instant::now();
        // stage inputs
        self.mask_buf.fill(0.0);
        self.tok_buf.fill(0);
        self.pos_buf.fill(0);
        self.idx_buf.fill(0);
        let mut active = 0u64;
        for i in 0..b {
            if let Some(row) = &self.rows[i] {
                row.seq.slot_mask(&mut self.mask_buf[i * s..(i + 1) * s]);
                self.tok_buf[i] = row.next_token as i32;
                self.pos_buf[i] = row.pos as i32;
                self.idx_buf[i] = row.seq.len() as i32;
                active += 1;
            }
        }

        let out = self.exec.step(&self.mask_buf, &self.tok_buf, &self.pos_buf)?;
        self.exec.append(&out.k_new, &out.v_new, &self.idx_buf)?;

        let d = self.exec.dims().clone();
        let (nh, dh, nl) = (d.n_heads, d.d_head, d.n_layers);
        let per_row_new = nl * nh * dh;
        let alpha_cfg = TrackerConfig {
            alpha: self.cfg.alpha,
        };

        // per-row: observe attention, record the new token, pick next input
        for i in 0..b {
            let Some(row) = self.rows[i].as_mut() else {
                continue;
            };
            let step_t = row.pos;
            let live = row.seq.len();
            let attn_row = &out.attn[i * s..i * s + live];
            observe(row.seq.records_mut(), attn_row, step_t, alpha_cfg);

            let mut rec = TokenRecord::new(step_t, step_t);
            rec.last_attn = 1.0; // self-attention at birth; overwritten next step
            if self.cfg.collect_sketches {
                // k_new row layout: [L, H, dh] for this batch row
                let base = i * per_row_new;
                let mut sk = Vec::with_capacity(nh * dh);
                for head in 0..nh {
                    let off = base + head * dh; // layer 0
                    sk.extend_from_slice(&out.k_new[off..off + dh]);
                }
                rec.key_sketch = sk;
            }
            row.seq.push(rec);
            if self.cfg.record_live {
                row.live_curve.push(row.seq.len());
            }
            row.pos += 1;

            let logits = &out.logits[i * self.vocab..(i + 1) * self.vocab];
            let pred = self
                .tokenizer
                .char_of(argmax(logits) as u32)
                .unwrap_or(' ');
            if let Some(c) = row.advance_with_prediction(pred, self.cfg.stop_char) {
                row.next_token = self.tokenizer.id(c).unwrap_or(0);
            }
        }
        self.metrics.record_step(t0.elapsed(), active);

        // eviction pass (lagged or greedy per policy; forced at capacity)
        let te = Instant::now();
        let mut any_evict = false;
        for i in 0..b {
            let wants = match &self.rows[i] {
                Some(row) => {
                    let live = row.seq.len();
                    let step_t = row.pos;
                    (self
                        .policy
                        .should_evict(live, self.cfg.budget, step_t)
                        || live >= self.cfg.cache)
                        && live > self.cfg.budget
                }
                None => false,
            };
            let range = i * s..(i + 1) * s;
            if wants {
                let row = self.rows[i].as_mut().unwrap();
                let keep =
                    self.policy
                        .select_keep(row.seq.records(), self.cfg.budget, row.pos);
                row.evictions += row.seq.len() - keep.len();
                row.seq.apply_keep(&keep, row.pos);
                let idx = row.seq.gather_indices(&keep);
                self.gather_buf[range].copy_from_slice(&idx);
                any_evict = true;
            } else {
                for (j, v) in self.gather_buf[range].iter_mut().enumerate() {
                    *v = j as i32;
                }
            }
        }
        if any_evict {
            self.exec.gather(&self.gather_buf)?;
            self.metrics.record_eviction(te.elapsed());
        }

        // collect rows that finished this step
        for i in 0..b {
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        Ok(finished)
    }

    fn finish_row(&mut self, i: usize) -> Response {
        let row = self.rows[i].take().expect("finish_row on empty row");
        let total = row.admitted_at.elapsed().as_secs_f64();
        let ttft = row
            .first_token_at
            .map(|t| t.duration_since(row.admitted_at).as_secs_f64())
            .unwrap_or(total);
        Response {
            id: row.req.id,
            text: row.out_text,
            hole_predictions: row.hole_predictions,
            finish: row.finish.unwrap(),
            metrics: RequestMetrics {
                queued_s: row.queued_s,
                ttft_s: ttft,
                total_s: total,
                tokens_out: row.produced,
                evictions: row.evictions,
            },
            live_curve: row.live_curve,
        }
    }

    /// Convenience driver: run a whole list of requests to completion with
    /// continuous batching. Returns responses in completion order.
    pub fn run_all(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let mut pending: std::collections::VecDeque<Request> = reqs.into();
        let mut done = Vec::new();
        self.metrics.start();
        loop {
            while self.has_free_row() {
                let Some(r) = pending.pop_front() else {
                    break;
                };
                self.submit(r, 0.0)?;
            }
            if self.active() == 0 && pending.is_empty() {
                break;
            }
            done.extend(self.step()?);
        }
        self.metrics.stop();
        Ok(done)
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }
}
