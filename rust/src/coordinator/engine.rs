//! The decode-loop engine: continuous batching over a fixed-row executable,
//! TS/MRI tracking from the step's exported attention, and lagged/greedy KV
//! eviction compiled down to device-side gathers. This is the request path —
//! no Python, no model code, just backend executions orchestrated from Rust.
//!
//! The engine drives any [`DecodeBackend`] (the PJRT `ModelExecutor`, or the
//! artifact-free `SimBackend` via [`Engine::new_sim`]). With a
//! `kvpool::PoolConfig` in the engine config, rows stop assuming dedicated
//! capacity and instead allocate KV blocks from a shared pool:
//!
//! * `submit` consults the prompt-prefix cache first: an identical prompt
//!   header forks the donor's whole blocks for free, and admission only has
//!   to cover the *private* remainder (+1 headroom block) — stale cache
//!   pins are shed LRU-first before a request is declined;
//! * before each decode step the engine ensures every active row can map
//!   one more token; if the pool is dry it sheds cache pins, then
//!   **preempts the youngest row** (highest admission ticket): blocks are
//!   returned and the request is handed back via [`Engine::take_preempted`]
//!   (oldest victim first) carrying a full decode-state snapshot, so its
//!   re-admission **resumes** the row — one batched recompute prefill of
//!   prompt + generated tokens, tracker records restored verbatim —
//!   byte-identical to a never-preempted run (vLLM-style recompute mode);
//! * the eviction pass privatizes a row's shared blocks (copy-on-write)
//!   before compacting, so a donor's mapping is never mutated, and
//!   (`apply_keep_pooled_moves`) returns whole freed blocks to the pool —
//!   lagged eviction becomes cross-sequence capacity.
//!
//! With a pool the paging is *physical*: `init_paged` swaps the backend's
//! per-row worst-case `[B, L, H, S, dh]` caches for pool-shaped block
//! arenas, prefill/decode K/V rows are written through each row's block
//! table, the decode step gathers context via `step_paged`, CoW duplicates
//! real bytes (`copy_block`) and compaction relocates them
//! (`gather_kv_rows`). A full-prompt prefix-cache hit therefore skips the
//! prefill executable entirely: the donor's blocks *are* the prompt K/V,
//! and the entry's [`PrefillSeed`] supplies the tail rows, tracker seed and
//! first prediction (disabled under `collect_sketches`, which needs the
//! prompt keys host-side). Ordering contract with the backend: CoW copies
//! are applied before the next row write, compaction moves before the next
//! pool allocation.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::attention::{observe, TrackerConfig};
use crate::coordinator::row::RowState;
use crate::coordinator::{EngineConfig, PreemptedState, Request, Response};
use crate::eviction::{self, Policy};
use crate::kvcache::TokenRecord;
use crate::kvpool::{
    BlockCopy, BlockPool, BlockTable, PoolPressure, PrefillSeed, PrefixCache, RowMove,
};
use crate::metrics::{EngineMetrics, PoolGauges, RequestMetrics};
use crate::runtime::{Client, DecodeBackend, Manifest, ModelExecutor, SimBackend};
use crate::tokenizer::Tokenizer;

pub struct Engine {
    pub cfg: EngineConfig,
    exec: Box<dyn DecodeBackend>,
    pub tokenizer: Tokenizer,
    policy: Box<dyn Policy>,
    rows: Vec<Option<RowState>>,
    /// Shared block pool (present iff cfg.pool is set).
    pool: Option<BlockPool>,
    /// Prompt-prefix cache (present iff pool + cfg.prefix_cache are set).
    prefix_cache: Option<PrefixCache>,
    /// Requests preempted since the last `take_preempted` drain, each
    /// tagged with the victim row's admission ticket so the drain can hand
    /// them back oldest-first.
    preempted: Vec<(u64, Request)>,
    /// Next admission ticket (monotone; youngest row = max ticket).
    admit_seq: u64,
    pub metrics: EngineMetrics,
    vocab: usize,
    /// Max blocks a row's table can hold (paged staging width).
    blocks_per_row: usize,
    // staging buffers reused across steps (no per-step allocation)
    mask_buf: Vec<f32>,
    tok_buf: Vec<i32>,
    pos_buf: Vec<i32>,
    idx_buf: Vec<i32>,
    gather_buf: Vec<i32>,
    /// Paged staging: flattened `[B, blocks_per_row]` block tables + lens.
    tbl_buf: Vec<i32>,
    len_buf: Vec<i32>,
    /// Pending physical CoW copies / compaction moves (drained to the
    /// backend immediately after the logical op that produced them).
    copy_buf: Vec<BlockCopy>,
    move_buf: Vec<RowMove>,
}

impl Engine {
    /// Real-model engine over compiled PJRT artifacts.
    pub fn new(client: &Client, manifest: &Manifest, cfg: EngineConfig) -> Result<Engine> {
        let exec = ModelExecutor::new(client, manifest, cfg.batch, cfg.cache)
            .context("building executor")?;
        Engine::with_backend(Box::new(exec), &manifest.charset, cfg)
    }

    /// Artifact-free engine over the deterministic sim backend — the same
    /// decode loop, eviction policies, pool and server, no PJRT required.
    pub fn new_sim(cfg: EngineConfig) -> Result<Engine> {
        let exec = SimBackend::new(cfg.batch, cfg.cache);
        let charset = exec.charset();
        Engine::with_backend(Box::new(exec), charset, cfg)
    }

    /// Engine over any backend (the two constructors above delegate here).
    /// With a pool configured, the backend is switched to physical paging
    /// here — before any request touches it.
    pub fn with_backend(
        mut exec: Box<dyn DecodeBackend>,
        charset: &str,
        cfg: EngineConfig,
    ) -> Result<Engine> {
        cfg.validate()?;
        let tokenizer = Tokenizer::new(charset);
        let policy = eviction::build(&cfg.policy, &cfg.params)?;
        let pool = match &cfg.pool {
            Some(pc) => Some(BlockPool::new(pc.clone())?),
            None => None,
        };
        let mut blocks_per_row = 0;
        if let Some(p) = &pool {
            exec.init_paged(p.total_blocks(), p.block_size())
                .context("switching backend to paged KV")?;
            blocks_per_row = p.blocks_for(cfg.cache);
        }
        let prefix_cache = match (&pool, &cfg.prefix_cache) {
            (Some(_), Some(pc)) => Some(PrefixCache::new(pc.clone())),
            _ => None,
        };
        let (b, s) = (cfg.batch, cfg.cache);
        Ok(Engine {
            vocab: exec.dims().vocab,
            tokenizer,
            policy,
            rows: (0..b).map(|_| None).collect(),
            pool,
            prefix_cache,
            preempted: Vec::new(),
            admit_seq: 0,
            metrics: EngineMetrics::default(),
            blocks_per_row,
            mask_buf: vec![0.0; b * s],
            tok_buf: vec![0; b],
            pos_buf: vec![0; b],
            idx_buf: vec![0; b],
            gather_buf: vec![0; b * s],
            tbl_buf: vec![-1; b * blocks_per_row],
            len_buf: vec![0; b],
            copy_buf: Vec::new(),
            move_buf: Vec::new(),
            exec,
            cfg,
        })
    }

    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    pub fn active(&self) -> usize {
        self.rows.iter().filter(|r| r.is_some()).count()
    }

    pub fn has_free_row(&self) -> bool {
        self.rows.iter().any(|r| r.is_none())
    }

    pub fn exec_counts(&self) -> crate::runtime::executor::ExecCounts {
        self.exec.exec_counts()
    }

    /// Pool watermark signal for the scheduler's admission controller.
    pub fn pool_pressure(&self) -> Option<PoolPressure> {
        self.pool.as_ref().map(|p| p.pressure())
    }

    /// Pool gauges for metrics export / server responses.
    pub fn pool_gauges(&self) -> Option<PoolGauges> {
        self.pool.as_ref().map(|p| {
            // physical bytes: the whole arena, and the live-block share
            let kv_arena_bytes = self.exec.device_cache_bytes();
            let block_bytes = if p.total_blocks() == 0 {
                0
            } else {
                kv_arena_bytes / p.total_blocks()
            };
            let mut g = PoolGauges {
                free_blocks: p.free_blocks(),
                total_blocks: p.total_blocks(),
                utilization: p.utilization(),
                preemptions: self.metrics.preemptions,
                resumes: self.metrics.resumes,
                recomputed_tokens: self.metrics.recomputed_tokens,
                shared_blocks: p.shared_blocks(),
                kv_arena_bytes,
                kv_bytes_in_use: p.used_blocks() * block_bytes,
                ..PoolGauges::default()
            };
            if let Some(pc) = &self.prefix_cache {
                g.prefix_hits = pc.hits;
                g.prefix_misses = pc.misses;
                g.prefix_entries = pc.len();
                g.prefix_pinned_blocks = pc.pinned_blocks();
                g.prefix_prefill_skips = self.metrics.prefill_skips;
            }
            g
        })
    }

    /// Test/debug passthrough: the K/V bytes the backend stores at an arena
    /// location (paged mode, host-readable backends only).
    pub fn backend_kv_row(&self, block: u32, offset: usize) -> Option<(Vec<f32>, Vec<f32>)> {
        self.exec.debug_kv_row(block, offset)
    }

    /// Drain pending physical CoW copies to the backend. Must run after any
    /// logical op that may have pushed into `copy_buf`, before the next
    /// K/V row write. A single copy (the common shared-tail case) goes
    /// through `copy_block`; several (multi-block privatization) are merged
    /// into one row-relocation pass — on the device backend that is one
    /// arena permute instead of one whole-arena pass per copied block.
    fn flush_block_copies(&mut self) -> Result<()> {
        match self.copy_buf.len() {
            0 => Ok(()),
            1 => {
                let c = self.copy_buf.pop().expect("len checked");
                self.exec.copy_block(c)
            }
            _ => {
                let copies = std::mem::take(&mut self.copy_buf);
                let moves: Vec<RowMove> = copies
                    .iter()
                    .flat_map(|c| {
                        (0..c.rows).map(move |r| RowMove {
                            src_block: c.src,
                            src_off: r,
                            dst_block: c.dst,
                            dst_off: r,
                        })
                    })
                    .collect();
                self.exec.gather_kv_rows(&moves)?;
                // keep the buffer's allocation across steps
                self.copy_buf = copies;
                self.copy_buf.clear();
                Ok(())
            }
        }
    }

    /// Drop every prompt-prefix cache entry, releasing its block pins
    /// (admin reset; also lets tests assert the pool drains to fully free).
    pub fn clear_prefix_cache(&mut self) {
        if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
            pc.clear(pool);
        }
    }

    /// Shed prefix-cache pins (LRU-first) until free blocks reach the
    /// pool's high watermark or the cache is empty. The serve loop calls
    /// this when admission is gated but *nothing is decoding*: with no row
    /// left to finish and free more blocks, stale pins are the only thing
    /// keeping the latch closed, and without this valve the queue would
    /// hang forever.
    pub fn shed_prefix_to_high_watermark(&mut self) {
        let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) else {
            return;
        };
        while pool.free_blocks() < pool.config().high_watermark {
            if !pc.shed_lru_reclaimable(pool) {
                break;
            }
        }
    }

    /// Drain the requests preempted since the last call, **oldest victim
    /// first** (ascending admission ticket). Each carries its
    /// [`PreemptedState`] in `Request::resume`, so re-submitting it makes
    /// the engine *resume* the row (recompute mode) rather than restart it.
    /// Callers must keep this order when re-queuing — put the whole batch
    /// at the queue front in slice order (`RequestQueue::push_front_all`);
    /// a per-request `push_front` loop would reverse it and let the
    /// youngest victim resume ahead of rows preempted before it.
    pub fn take_preempted(&mut self) -> Vec<Request> {
        let mut v = std::mem::take(&mut self.preempted);
        v.sort_by_key(|&(ticket, _)| ticket);
        v.into_iter().map(|(_, r)| r).collect()
    }

    /// Error recovery: drop every active row, returning blocks to the pool
    /// and reporting the owning request ids so the caller can fail their
    /// replies. Unlike preemption, aborted requests are NOT re-queued — the
    /// engine state behind them is unrecoverable and the client must be
    /// told, not silently retried.
    pub fn abort_rows(&mut self) -> Vec<u64> {
        let mut ids = Vec::new();
        for slot in self.rows.iter_mut() {
            if let Some(mut row) = slot.take() {
                if let Some(pool) = self.pool.as_mut() {
                    row.seq.release_blocks(pool);
                }
                ids.push(row.req.id);
            }
        }
        ids
    }

    /// Extract the layer-0 concat-heads key vector for slot data laid out
    /// as [L, H, ..., dh] — the R-KV similarity sketch.
    fn sketch_from(&self, data: &[f32], h_stride: usize, slot: usize) -> Vec<f32> {
        let d = self.exec.dims();
        let (h, dh) = (d.n_heads, d.d_head);
        let mut out = Vec::with_capacity(h * dh);
        for head in 0..h {
            let base = (head * h_stride + slot) * dh;
            out.extend_from_slice(&data[base..base + dh]);
        }
        out
    }

    /// Admit a request into a free row: prefill, insert, initialize records.
    /// Returns false (caller's request untouched) when no row is free, or
    /// when the block pool cannot cover the prompt — the scheduler holds it
    /// queued. A request carrying a [`PreemptedState`] snapshot is *resumed*
    /// instead (recompute mode — see [`Engine::submit_resumed`]); its
    /// effective queue wait is computed from the snapshot, so `queued_s` is
    /// ignored for it.
    pub fn submit(&mut self, mut req: Request, queued_s: f64) -> Result<bool> {
        if let Some(st) = req.resume.take() {
            return self.submit_resumed(req, st);
        }
        let Some(row_idx) = self.rows.iter().position(|r| r.is_none()) else {
            return Ok(false);
        };
        let p_bucket = self.exec.prefill_bucket();
        let ids = self
            .tokenizer
            .encode(&req.prompt)
            .map_err(|e| anyhow::anyhow!("prompt: {e}"))?;
        anyhow::ensure!(!ids.is_empty(), "empty prompt");
        anyhow::ensure!(
            ids.len() <= p_bucket,
            "prompt len {} exceeds prefill bucket {}",
            ids.len(),
            p_bucket
        );
        anyhow::ensure!(
            ids.len() < self.cfg.budget,
            "prompt len {} must be < budget {}",
            ids.len(),
            self.cfg.budget
        );
        // pressure-driven admission. With a prefix-cache hit the row's
        // leading whole blocks are forked from the donor for free, so only
        // the *private* remainder (plus one headroom block for the first
        // decode token) must fit in the free part of the pool. Stale cache
        // pins are shed LRU-first before declining, so a cache-heavy pool
        // can never starve admissions.
        let mut fork: Option<BlockTable> = None;
        let mut full_hit = false;
        if self.pool.is_some() {
            let needed = {
                let pool = self.pool.as_mut().expect("checked");
                if let Some(pc) = self.prefix_cache.as_mut() {
                    if let Some(hit) = pc.lookup(&ids, pool.block_size()) {
                        // a seed for this exact prompt lets prefill be
                        // skipped — unless sketches are collected (rkv needs
                        // the prompt keys host-side, which only a real
                        // prefill produces)
                        full_hit = hit.seed.is_some() && !self.cfg.collect_sketches;
                        fork = Some(BlockTable::fork_prefix(hit.table, ids.len(), pool));
                    }
                }
                let shared = fork.as_ref().map_or(0, |t| t.n_blocks());
                pool.blocks_for(ids.len() + 1).saturating_sub(shared)
            };
            if !self.shed_pins_to_cover(needed) {
                if let (Some(pool), Some(mut t)) = (self.pool.as_mut(), fork.take()) {
                    t.release_all(pool);
                }
                return Ok(false);
            }
        }
        let prefix_hit = fork.is_some();
        let premapped = fork.as_ref().map_or(0, |t| t.len());
        let p = ids.len();
        let d = self.exec.dims().clone();
        let row_elems = d.n_layers * d.n_heads * d.d_head;

        // a backend error must not leak the fork's block references
        let release_fork = |slf: &mut Engine, fork: &mut Option<BlockTable>| {
            if let (Some(pool), Some(mut t)) = (slf.pool.as_mut(), fork.take()) {
                t.release_all(pool);
            }
        };

        // Where the prompt's K/V, tracker seed and first logits came from:
        // Seeded  — full-prompt prefix hit under physical paging: the
        //           donor's blocks hold the prompt K/V, zero model compute;
        // Rows    — paged prefill (token-major rows, no worst-case buffer);
        // Dense   — dense prefill + device insert (no pool configured).
        enum Prefilled {
            Seeded(PrefillSeed),
            Rows(crate::runtime::PrefillRows),
            Dense(crate::runtime::PrefillOut),
        }
        // the seed can only have vanished if admission shedding destroyed
        // the entry — impossible while our fork pins its blocks, but a
        // prefill fallback is cheaper than an invariant panic
        let seed_opt = if full_hit {
            self.prefix_cache
                .as_ref()
                .and_then(|pc| pc.seed_for(&ids))
                .cloned()
        } else {
            None
        };
        let pre = if let Some(seed) = seed_opt {
            self.metrics.prefill_skips += 1;
            Prefilled::Seeded(seed)
        } else {
            let t0 = Instant::now();
            let (toks, valid) = padded_tokens(&ids, p_bucket);
            let prefilled = if self.pool.is_some() {
                self.exec.prefill_rows(&toks, &valid).map(Prefilled::Rows)
            } else {
                self.exec.prefill(&toks, &valid).map(Prefilled::Dense)
            };
            let out = match prefilled {
                Ok(o) => o,
                Err(e) => {
                    release_fork(self, &mut fork);
                    return Err(e);
                }
            };
            if let Prefilled::Dense(o) = &out {
                if let Err(e) = self.exec.insert(&o.k_seq, &o.v_seq, row_idx) {
                    release_fork(self, &mut fork);
                    return Err(e);
                }
            }
            self.metrics.record_prefill(t0.elapsed());
            out
        };

        let mut row = RowState::new(req, self.cfg.cache, queued_s);
        row.admit_seq = self.admit_seq;
        self.admit_seq += 1;
        if let Some(pool) = self.pool.as_ref() {
            let table = fork
                .take()
                .unwrap_or_else(|| BlockTable::new(pool.block_size()));
            row.seq.attach_block_table(table);
        }
        let h_stride = self.cfg.cache; // dense k_seq is [L, H, S, dh]
        let sketch_span = d.n_heads * h_stride * d.d_head;
        for i in 0..p {
            let mut rec = TokenRecord::new(i as u32, i as u32);
            rec.last_attn = 1.0;
            if self.cfg.collect_sketches {
                rec.key_sketch = match &pre {
                    Prefilled::Dense(o) => {
                        self.sketch_from(&o.k_seq[..sketch_span], h_stride, i)
                    }
                    // token-major row i, layer 0 = leading H·dh lanes
                    Prefilled::Rows(r) => {
                        r.k_rows[i * row_elems..i * row_elems + d.n_heads * d.d_head].to_vec()
                    }
                    Prefilled::Seeded(_) => unreachable!("skip disabled under sketches"),
                };
            }
            match self.pool.as_mut() {
                Some(pool) => {
                    if row.seq.push_pooled_cow(rec, pool, &mut self.copy_buf).is_none() {
                        // Free-count was checked above; this is unreachable
                        // in the single-threaded loop, but stay safe: give
                        // the blocks back and leave the request queued.
                        row.seq.release_blocks(pool);
                        return Ok(false);
                    }
                }
                None => {
                    row.seq.push(rec);
                }
            }
        }
        debug_assert!(
            self.copy_buf.is_empty(),
            "admission pushes premap or allocate at boundaries — never CoW"
        );

        // physical paging: scatter the prompt's K/V rows into the row's
        // private blocks. Slots below `premapped` already hold the donor's
        // bytes (and writing into those shared blocks would corrupt it).
        if self.pool.is_some() {
            let (k_rows, v_rows, src_base): (&[f32], &[f32], usize) = match &pre {
                Prefilled::Rows(r) => (&r.k_rows, &r.v_rows, 0),
                // seed tail rows start exactly at the entry's coverage
                Prefilled::Seeded(s) => (&s.tail_k, &s.tail_v, premapped),
                Prefilled::Dense(_) => unreachable!("pooled engines prefill rows"),
            };
            let mut i = premapped;
            while i < p {
                let (blk, off, run) = {
                    let t = row.seq.block_table().expect("pooled row has a table");
                    let (blk, off) = t.locate(i).expect("prompt slot mapped");
                    (blk, off, (t.block_size() - off).min(p - i))
                };
                let a = (i - src_base) * row_elems;
                let b = a + run * row_elems;
                if let Err(e) = self.exec.write_kv_rows(blk, off, &k_rows[a..b], &v_rows[a..b]) {
                    if let Some(pool) = self.pool.as_mut() {
                        row.seq.release_blocks(pool);
                    }
                    return Err(e);
                }
                i += run;
            }
        }

        // the admission actually went through: settle the hit/miss counters
        // (a lookup whose admission was declined counts as neither), and
        // register this prompt's whole-block prefix so later identical
        // headers fork it (no-op if an entry already covers it). Under
        // physical paging a fresh prefill also leaves its seed behind, so
        // the *next* identical prompt skips prefill entirely.
        if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
            if prefix_hit {
                pc.hits += 1;
            } else {
                pc.misses += 1;
            }
            if let Some(t) = row.seq.block_table() {
                let seed = match &pre {
                    Prefilled::Rows(r) => {
                        let covered = (p.min(t.len()) / pool.block_size()) * pool.block_size();
                        Some(PrefillSeed {
                            prompt: ids.clone(),
                            tail_k: r.k_rows[covered * row_elems..p * row_elems].to_vec(),
                            tail_v: r.v_rows[covered * row_elems..p * row_elems].to_vec(),
                            attn_last: r.attn_last.clone(),
                            logits_last: r.logits_last.clone(),
                        })
                    }
                    _ => None,
                };
                pc.insert(&ids, t, seed, pool);
            }
        }
        // one observation from the last prompt row's attention
        let (attn_seed, logits_seed): (&[f32], &[f32]) = match &pre {
            Prefilled::Seeded(s) => (&s.attn_last, &s.logits_last),
            Prefilled::Rows(r) => (&r.attn_last, &r.logits_last),
            Prefilled::Dense(o) => (&o.attn_last, &o.logits_last),
        };
        observe(
            row.seq.records_mut(),
            &attn_seed[..p],
            (p - 1) as u32,
            TrackerConfig {
                alpha: self.cfg.alpha,
            },
        );
        row.pos = p as u32;

        // first prediction comes from the prefill (or seeded) logits
        let pred_id = argmax(logits_seed);
        let pred = self.tokenizer.char_of(pred_id as u32).unwrap_or(' ');
        match row.advance_with_prediction(pred, self.cfg.stop_char) {
            Some(c) => {
                row.next_token = self.tokenizer.id(c).unwrap_or(0);
                self.rows[row_idx] = Some(row);
            }
            None => {
                // degenerate: finished without a single decode step
                self.rows[row_idx] = Some(row);
            }
        }
        Ok(true)
    }

    /// Admission-side pool check shared by fresh and resumed submits: shed
    /// reclaimable prefix-cache pins LRU-first — but only when the total
    /// reclaimable pins can actually cover the shortfall, so a hopeless
    /// demand never wipes the cache (and every later identical-prompt
    /// admission's sharing) for nothing — then report whether `needed`
    /// free blocks are available. Always true without a pool.
    fn shed_pins_to_cover(&mut self, needed: usize) -> bool {
        let Some(pool) = self.pool.as_mut() else {
            return true;
        };
        if let Some(pc) = self.prefix_cache.as_mut() {
            if pool.free_blocks() + pc.reclaimable_blocks(pool) >= needed {
                while pool.free_blocks() < needed {
                    if !pc.shed_lru_reclaimable(pool) {
                        break;
                    }
                }
            }
        }
        pool.free_blocks() >= needed
    }

    /// Resume a preempted row from its snapshot (vLLM-style recompute
    /// mode). The fed-token stream the row had consumed — prompt plus every
    /// emitted char except the pending one — is re-prefilled in **one
    /// batched `prefill_rows` pass**; only the K/V rows the live keep-set
    /// still references are written back through a fresh block table (the
    /// recompute covers every position, so evicted slots simply are not
    /// written). The tracker records are restored verbatim — the row's
    /// observation history (TS/MRI) and therefore its future eviction
    /// decisions are identical to a never-preempted run's. The recompute
    /// pass's attention/logits are discarded: the snapshot already holds
    /// the pending input token, so no `observe`/advance runs here.
    ///
    /// Falls back to a restart from the prompt (counted in
    /// `resume_fallbacks`) when the stream has outgrown the prefill bucket
    /// or the engine has no pool (preemption never produces the latter; the
    /// guard keeps a hand-crafted request from wedging a dense engine).
    /// Returns Ok(false) without consuming pool capacity when no row is
    /// free or the pool cannot cover the live set — the caller still holds
    /// its copy of the request (snapshot included) and retries later.
    fn submit_resumed(&mut self, req: Request, st: std::sync::Arc<PreemptedState>) -> Result<bool> {
        if self.rows.iter().all(|r| r.is_some()) {
            return Ok(false);
        }
        // cumulative wait: everything queued before earlier admissions plus
        // the wait since this preemption (re-queue happens at preemption)
        let queued_s = st.queued_s + st.preempted_at.elapsed().as_secs_f64();
        // finished-but-preempted (a mid-step privatization victim): nothing
        // to recompute — restore the outputs and let step() collect it
        if st.finish.is_some() {
            let row_idx = self.rows.iter().position(|r| r.is_none()).expect("checked");
            let mut row = RowState::resume(req, self.cfg.cache, queued_s, &st);
            row.admit_seq = self.admit_seq;
            self.admit_seq += 1;
            self.metrics.resumes += 1;
            self.rows[row_idx] = Some(row);
            return Ok(true);
        }
        // the fed-token stream: prompt, then every emitted char except the
        // last (that one is `next_token`, still pending its decode step)
        let mut ids = self
            .tokenizer
            .encode(&req.prompt)
            .map_err(|e| anyhow::anyhow!("prompt: {e}"))?;
        for c in st.out_text.chars().take(st.produced.saturating_sub(1)) {
            ids.push(self.tokenizer.id(c).unwrap_or(0));
        }
        anyhow::ensure!(
            ids.len() == st.pos as usize,
            "resume stream length {} != snapshot pos {}",
            ids.len(),
            st.pos
        );
        let p_bucket = self.exec.prefill_bucket();
        if self.pool.is_none() || ids.len() > p_bucket {
            // cannot recompute in one pass: restart from the prompt (the
            // pre-resume behavior). Counted only when the restart actually
            // admits — a decline leaves the snapshot with the caller, and
            // its retries must not inflate the fallback metric.
            let admitted = self.submit(req, queued_s)?;
            if admitted {
                self.metrics.resume_fallbacks += 1;
                // the restart regenerates tokens, but the request's
                // timeline is still the original one: keep the
                // first-admission timestamps so ttft_s/total_s honor the
                // documented "original admission" metrics contract
                let ticket = self.admit_seq - 1;
                if let Some(row) = self
                    .rows
                    .iter_mut()
                    .flatten()
                    .find(|r| r.admit_seq == ticket)
                {
                    row.admitted_at = st.admitted_at;
                    row.first_token_at = st.first_token_at.or(row.first_token_at);
                }
            }
            return Ok(admitted);
        }
        let n_live = st.records.len();
        anyhow::ensure!(n_live > 0, "resume snapshot has an empty live set");
        anyhow::ensure!(
            st.records.iter().all(|r| (r.pos as usize) < ids.len()),
            "resume record position outside the recompute stream"
        );
        // admission: the resumed row needs blocks for its live set plus one
        // headroom block; stale prefix-cache pins are shed like any other
        // admission, but the prefix cache is otherwise not consulted — a
        // mid-sequence keep-set is not a shareable prompt prefix.
        let needed = self
            .pool
            .as_ref()
            .expect("checked above")
            .blocks_for(n_live + 1);
        if !self.shed_pins_to_cover(needed) {
            return Ok(false);
        }
        // one batched recompute prefill over the whole fed stream — K/V for
        // every position the keep-set might reference, no worst-case buffer
        let t0 = Instant::now();
        let (toks, valid) = padded_tokens(&ids, p_bucket);
        let pre = self.exec.prefill_rows(&toks, &valid)?;
        self.metrics.record_prefill(t0.elapsed());

        let row_idx = self.rows.iter().position(|r| r.is_none()).expect("checked");
        let mut row = RowState::resume(req, self.cfg.cache, queued_s, &st);
        row.admit_seq = self.admit_seq;
        self.admit_seq += 1;
        {
            let pool = self.pool.as_mut().expect("checked above");
            row.seq
                .attach_block_table(BlockTable::new(pool.block_size()));
            if !row.seq.restore_pooled(&st.records, pool) {
                // free count was checked above; unreachable single-threaded,
                // but roll back safely and leave the request queued
                row.seq.release_blocks(pool);
                return Ok(false);
            }
        }
        // scatter the surviving rows: slot j holds the token born at
        // records[j].pos, whose recomputed K/V is row `pos` of the prefill
        // output. Runs of consecutive positions within a block batch up.
        let re = {
            let d = self.exec.dims();
            d.n_layers * d.n_heads * d.d_head
        };
        let positions: Vec<u32> = st.records.iter().map(|r| r.pos).collect();
        let mut j = 0;
        while j < n_live {
            let (blk, off, run) = {
                let t = row.seq.block_table().expect("pooled row has a table");
                let (blk, off) = t.locate(j).expect("restored slot mapped");
                let max_run = (t.block_size() - off).min(n_live - j);
                let mut run = 1;
                while run < max_run && positions[j + run] == positions[j] + run as u32 {
                    run += 1;
                }
                (blk, off, run)
            };
            let a = positions[j] as usize * re;
            let b = a + run * re;
            if let Err(e) =
                self.exec
                    .write_kv_rows(blk, off, &pre.k_rows[a..b], &pre.v_rows[a..b])
            {
                if let Some(pool) = self.pool.as_mut() {
                    row.seq.release_blocks(pool);
                }
                return Err(e);
            }
            j += run;
        }
        self.metrics.resumes += 1;
        self.metrics.recomputed_tokens += ids.len() as u64;
        self.rows[row_idx] = Some(row);
        Ok(true)
    }

    /// Preempt row `i`: return its blocks to the pool and queue its request
    /// for re-admission with a full decode-state snapshot attached
    /// (recompute mode). The snapshot carries the generated text, template
    /// cursor, pending input token, the tracker records (TS/MRI observation
    /// history — restored verbatim on resume, never re-initialized) and the
    /// original admission timing, so the resumed row continues
    /// byte-identically to a never-preempted run instead of regenerating
    /// from the prompt.
    fn preempt_row(&mut self, i: usize) {
        let Some(mut row) = self.rows[i].take() else {
            return;
        };
        if let Some(pool) = self.pool.as_mut() {
            row.seq.release_blocks(pool);
        }
        self.metrics.preemptions += 1;
        let records = row.seq.take_records();
        let mut req = row.req;
        // a row preempted twice carries the freshest snapshot only
        req.resume = Some(std::sync::Arc::new(PreemptedState {
            records,
            pos: row.pos,
            next_token: row.next_token,
            next_forced: row.next_forced,
            template_cursor: row.template_cursor,
            out_text: row.out_text,
            hole_predictions: row.hole_predictions,
            produced: row.produced,
            finish: row.finish,
            evictions: row.evictions,
            live_curve: row.live_curve,
            queued_s: row.queued_s,
            admitted_at: row.admitted_at,
            first_token_at: row.first_token_at,
            preempted_at: Instant::now(),
        }));
        self.preempted.push((row.admit_seq, req));
    }

    /// Make sure every active row can map one more token this step. When
    /// the pool cannot cover the demand, shed prefix-cache pins LRU-first,
    /// then preempt youngest rows. Terminates: each round either satisfies
    /// the demand, sheds a (finite) cache entry, or removes a row, and
    /// config validation guarantees a solo row with no stale pins always
    /// fits (`n_blocks * block_size >= cache`).
    fn ensure_block_headroom(&mut self) {
        loop {
            let Some(pool) = self.pool.as_ref() else { return };
            let free = pool.free_blocks();
            let needed = self
                .rows
                .iter()
                .flatten()
                .filter(|r| r.seq.needs_block_for_next(pool))
                .count();
            if needed <= free {
                return;
            }
            // stale cache pins go before live rows — but only pins whose
            // shedding actually frees blocks; still-shared entries would
            // relieve nothing and are kept for future admissions
            if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
                if pc.shed_lru_reclaimable(pool) {
                    continue;
                }
            }
            let victim = self
                .rows
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.as_ref().map(|row| (row.admit_seq, i)))
                .max_by_key(|&(seq, _)| seq)
                .map(|(_, i)| i);
            match victim {
                Some(i) => self.preempt_row(i),
                None => return,
            }
        }
    }

    /// Copy-on-write row `i`'s shared blocks so an eviction pass can mutate
    /// its mapping. Allocation pressure is resolved by shedding prefix-cache
    /// pins LRU-first, then preempting the youngest *other* row (whose
    /// released references often privatize `i`'s blocks with no allocation
    /// at all). The physical byte duplications every logical swap implies
    /// are applied to the backend immediately — including on the partial
    /// progress of a failed attempt, whose swapped blocks are already live.
    /// Returns Ok(false) only when the row still shares blocks and nothing
    /// is left to shed or preempt — the caller skips the eviction pass for
    /// that row this step and retries next step.
    fn make_row_private(&mut self, i: usize) -> Result<bool> {
        loop {
            let (done, shared_ids) = {
                let Some(pool) = self.pool.as_mut() else { return Ok(true) };
                let Some(row) = self.rows[i].as_mut() else { return Ok(true) };
                if row.seq.make_private_cow(pool, &mut self.copy_buf) {
                    (true, Vec::new())
                } else {
                    let ids = row
                        .seq
                        .block_table()
                        .map(|t| t.shared_block_ids(pool))
                        .unwrap_or_default();
                    (false, ids)
                }
            };
            self.flush_block_copies()?;
            if done {
                return Ok(true);
            }
            if let (Some(pool), Some(pc)) = (self.pool.as_mut(), self.prefix_cache.as_mut()) {
                // first drop cache entries holding *this row's* shared
                // blocks — that lowers their refcount directly, often
                // privatizing the row with no allocation at all...
                if pc.shed_lru_overlapping(&shared_ids, pool) {
                    continue;
                }
                // ...then entries whose shedding frees blocks for the copy
                if pc.shed_lru_reclaimable(pool) {
                    continue;
                }
            }
            let victim = self
                .rows
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .filter_map(|(j, r)| r.as_ref().map(|row| (row.admit_seq, j)))
                .max_by_key(|&(seq, _)| seq)
                .map(|(_, j)| j);
            match victim {
                Some(j) => self.preempt_row(j),
                None => return Ok(false),
            }
        }
    }

    /// One decode iteration over all active rows. Returns finished responses
    /// (preempted requests are reported via `take_preempted`, not here).
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let (b, s) = (self.cfg.batch, self.cfg.cache);
        // collect immediately-finished rows (prefill-finished), and
        // force-finish rows whose cache is physically full and whose policy
        // cannot shed tokens (FullKV hitting capacity)
        let mut finished = Vec::new();
        for i in 0..b {
            if let Some(row) = self.rows[i].as_mut() {
                if row.finish.is_none() && row.seq.len() >= self.cfg.cache {
                    row.finish = Some(crate::coordinator::FinishReason::MaxTokens);
                }
            }
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        // paged mode: every surviving row must be able to map one more token
        if self.pool.is_some() {
            self.ensure_block_headroom();
        }
        if self.rows.iter().all(|r| r.is_none()) {
            return Ok(finished);
        }

        let t0 = Instant::now();
        let paged = self.pool.is_some();
        // stage inputs: block tables + lens (paged) or slot masks (dense)
        self.tok_buf.fill(0);
        self.pos_buf.fill(0);
        if paged {
            self.tbl_buf.fill(-1);
            self.len_buf.fill(0);
        } else {
            self.mask_buf.fill(0.0);
            self.idx_buf.fill(0);
        }
        let mut active = 0u64;
        for i in 0..b {
            if let Some(row) = &self.rows[i] {
                if paged {
                    let t = row.seq.block_table().expect("pooled row has a table");
                    let bpr = self.blocks_per_row;
                    for (j, &blk) in t.blocks().iter().enumerate() {
                        self.tbl_buf[i * bpr + j] = blk as i32;
                    }
                    self.len_buf[i] = row.seq.len() as i32;
                } else {
                    row.seq.slot_mask(&mut self.mask_buf[i * s..(i + 1) * s]);
                    self.idx_buf[i] = row.seq.len() as i32;
                }
                self.tok_buf[i] = row.next_token as i32;
                self.pos_buf[i] = row.pos as i32;
                active += 1;
            }
        }

        let out = if paged {
            // K/V context is gathered through the block tables on the
            // backend; the new rows come back for table-routed appends
            self.exec.step_paged(
                &self.tbl_buf,
                self.blocks_per_row,
                &self.len_buf,
                &self.tok_buf,
                &self.pos_buf,
            )?
        } else {
            let o = self.exec.step(&self.mask_buf, &self.tok_buf, &self.pos_buf)?;
            self.exec.append(&o.k_new, &o.v_new, &self.idx_buf)?;
            o
        };

        let d = self.exec.dims().clone();
        let (nh, dh, nl) = (d.n_heads, d.d_head, d.n_layers);
        let per_row_new = nl * nh * dh;
        let alpha_cfg = TrackerConfig {
            alpha: self.cfg.alpha,
        };

        // per-row: observe attention, record the new token, pick next input
        for i in 0..b {
            // phase 1 (row borrow): tracker update + logical push + output
            let write_at = {
                let Some(row) = self.rows[i].as_mut() else {
                    continue;
                };
                let step_t = row.pos;
                let live = row.seq.len();
                let attn_row = &out.attn[i * s..i * s + live];
                observe(row.seq.records_mut(), attn_row, step_t, alpha_cfg);

                let mut rec = TokenRecord::new(step_t, step_t);
                rec.last_attn = 1.0; // self-attention at birth; overwritten next step
                if self.cfg.collect_sketches {
                    // k_new row layout: [L, H, dh] for this batch row
                    let base = i * per_row_new;
                    let mut sk = Vec::with_capacity(nh * dh);
                    for head in 0..nh {
                        let off = base + head * dh; // layer 0
                        sk.extend_from_slice(&out.k_new[off..off + dh]);
                    }
                    rec.key_sketch = sk;
                }
                match self.pool.as_mut() {
                    Some(pool) => {
                        row.seq
                            .push_pooled_cow(rec, pool, &mut self.copy_buf)
                            .expect("block headroom ensured before step");
                    }
                    None => {
                        row.seq.push(rec);
                    }
                }
                if self.cfg.record_live {
                    row.live_curve.push(row.seq.len());
                }
                row.pos += 1;

                let logits = &out.logits[i * self.vocab..(i + 1) * self.vocab];
                let pred = self
                    .tokenizer
                    .char_of(argmax(logits) as u32)
                    .unwrap_or(' ');
                if let Some(c) = row.advance_with_prediction(pred, self.cfg.stop_char) {
                    row.next_token = self.tokenizer.id(c).unwrap_or(0);
                }
                if paged {
                    let slot = row.seq.len() - 1;
                    let t = row.seq.block_table().expect("pooled row has a table");
                    Some(t.locate(slot).expect("just pushed ⇒ mapped"))
                } else {
                    None
                }
            };
            // phase 2 (backend): any shared-tail CoW copy lands first, then
            // the new token's K/V row goes to its table-mapped location
            if let Some((blk, off)) = write_at {
                self.flush_block_copies()?;
                let base = i * per_row_new;
                self.exec.write_kv_rows(
                    blk,
                    off,
                    &out.k_new[base..base + per_row_new],
                    &out.v_new[base..base + per_row_new],
                )?;
            }
        }
        self.metrics.record_step(t0.elapsed(), active);

        // eviction pass (lagged or greedy per policy; forced at capacity).
        // In paged mode compaction also returns whole freed blocks, and the
        // surviving rows' bytes are relocated between blocks immediately —
        // before any later row's CoW could reuse the freed blocks.
        let te = Instant::now();
        let mut any_evict = false;
        for i in 0..b {
            let wants = match &self.rows[i] {
                Some(row) => {
                    let live = row.seq.len();
                    let step_t = row.pos;
                    (self
                        .policy
                        .should_evict(live, self.cfg.budget, step_t)
                        || live >= self.cfg.cache)
                        && live > self.cfg.budget
                }
                None => false,
            };
            let range = i * s..(i + 1) * s;
            // CoW before compaction: eviction reorders slot contents, so a
            // row still sharing prefix blocks must detach them first. If
            // privatization is impossible right now, defer this row's pass.
            let wants = wants && (self.pool.is_none() || self.make_row_private(i)?);
            if wants {
                {
                    let row = self.rows[i].as_mut().unwrap();
                    let keep =
                        self.policy
                            .select_keep(row.seq.records(), self.cfg.budget, row.pos);
                    row.evictions += row.seq.len() - keep.len();
                    match self.pool.as_mut() {
                        Some(pool) => {
                            self.move_buf.clear();
                            row.seq.apply_keep_pooled_moves(
                                &keep,
                                row.pos,
                                pool,
                                &mut self.move_buf,
                            );
                        }
                        None => {
                            row.seq.apply_keep(&keep, row.pos);
                            let idx = row.seq.gather_indices(&keep);
                            self.gather_buf[range].copy_from_slice(&idx);
                        }
                    }
                }
                if paged && !self.move_buf.is_empty() {
                    // keep the buffer's allocation across steps
                    let moves = std::mem::take(&mut self.move_buf);
                    self.exec.gather_kv_rows(&moves)?;
                    self.move_buf = moves;
                    self.move_buf.clear();
                }
                any_evict = true;
            } else if !paged {
                for (j, v) in self.gather_buf[range].iter_mut().enumerate() {
                    *v = j as i32;
                }
            }
        }
        if any_evict {
            if !paged {
                self.exec.gather(&self.gather_buf)?;
            }
            self.metrics.record_eviction(te.elapsed());
        }

        // collect rows that finished this step
        for i in 0..b {
            if self.rows[i].as_ref().map(|r| r.finish.is_some()) == Some(true) {
                finished.push(self.finish_row(i));
            }
        }
        Ok(finished)
    }

    fn finish_row(&mut self, i: usize) -> Response {
        let mut row = self.rows[i].take().expect("finish_row on empty row");
        if let Some(pool) = self.pool.as_mut() {
            row.seq.release_blocks(pool);
        }
        let total = row.admitted_at.elapsed().as_secs_f64();
        let ttft = row
            .first_token_at
            .map(|t| t.duration_since(row.admitted_at).as_secs_f64())
            .unwrap_or(total);
        Response {
            id: row.req.id,
            text: row.out_text,
            hole_predictions: row.hole_predictions,
            finish: row.finish.unwrap(),
            metrics: RequestMetrics {
                queued_s: row.queued_s,
                ttft_s: ttft,
                total_s: total,
                tokens_out: row.produced,
                evictions: row.evictions,
            },
            live_curve: row.live_curve,
        }
    }

    /// Convenience driver: run a whole list of requests to completion with
    /// continuous batching. Preempted requests rejoin the front of the
    /// pending queue oldest-victim-first and *resume* (recompute mode).
    /// Returns responses in completion order. Queue waits are measured from
    /// each request's enqueue, so `Response::metrics.queued_s` reports real
    /// hold time under pool pressure rather than a hard-coded zero.
    pub fn run_all(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        let t0 = Instant::now();
        let mut pending: std::collections::VecDeque<(Request, Instant)> =
            reqs.into_iter().map(|r| (r, t0)).collect();
        let mut done = Vec::new();
        self.metrics.start();
        loop {
            while self.has_free_row() {
                let Some((r, enq)) = pending.pop_front() else {
                    break;
                };
                if !self.submit(r.clone(), enq.elapsed().as_secs_f64())? {
                    // pool pressure: hold it until blocks free up
                    pending.push_front((r, enq));
                    break;
                }
            }
            if self.active() == 0 && pending.is_empty() {
                break;
            }
            done.extend(self.step()?);
            // oldest victim first: reverse-push so slice order survives the
            // front insertion (resumed waits are tracked in the snapshot)
            let now = Instant::now();
            for r in self.take_preempted().into_iter().rev() {
                pending.push_front((r, now));
            }
        }
        self.metrics.stop();
        Ok(done)
    }
}

/// Stage a token stream into the prefill executable's padded bucket:
/// tokens at [0, n), zero padding and a matching validity mask beyond.
/// Shared by fresh prefill and recompute-mode resume.
fn padded_tokens(ids: &[u32], bucket: usize) -> (Vec<i32>, Vec<f32>) {
    debug_assert!(ids.len() <= bucket);
    let mut toks = vec![0i32; bucket];
    let mut valid = vec![0f32; bucket];
    for (i, &id) in ids.iter().enumerate() {
        toks[i] = id as i32;
        valid[i] = 1.0;
    }
    (toks, valid)
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FinishReason;
    use crate::kvpool::PoolConfig;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3]), 1);
        assert_eq!(argmax(&[-1.0, -2.0]), 0);
    }

    fn sim_cfg(batch: usize, pool: Option<PoolConfig>) -> EngineConfig {
        let mut cfg = EngineConfig {
            batch,
            cache: 64,
            budget: 40,
            policy: "lazy".into(),
            record_live: true,
            pool,
            ..Default::default()
        };
        cfg.params.window = 8;
        cfg.params.recent = 8;
        cfg
    }

    fn req(id: u64, max_new: usize) -> Request {
        Request {
            id,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            resume: None,
        }
    }

    #[test]
    fn sim_engine_generates_deterministically() {
        let mut e1 = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let mut e2 = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r1 = e1.run_all(vec![req(1, 32)]).unwrap();
        let r2 = e2.run_all(vec![req(1, 32)]).unwrap();
        assert_eq!(r1.len(), 1);
        assert_eq!(r1[0].text, r2[0].text);
        assert_eq!(r1[0].metrics.tokens_out, 32);
        assert_eq!(r1[0].finish, FinishReason::MaxTokens);
    }

    #[test]
    fn sim_engine_evicts_under_tight_budget() {
        let mut e = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r = e.run_all(vec![req(1, 60)]).unwrap();
        assert!(r[0].metrics.evictions > 0, "no evictions at budget 40");
        assert!(r[0].live_curve.iter().all(|&l| l <= 64));
    }

    #[test]
    fn sim_engine_fills_template_holes() {
        let mut e = Engine::new_sim(sim_cfg(1, None)).unwrap();
        let r = e
            .run_all(vec![Request {
                id: 9,
                prompt: "#A=3;\n>".into(),
                template: "A=?;".into(),
                max_new: 32,
                resume: None,
            }])
            .unwrap();
        assert_eq!(r[0].finish, FinishReason::TemplateDone);
        assert_eq!(r[0].hole_predictions.len(), 1);
        assert!(r[0].text.starts_with("A="));
    }

    #[test]
    fn pooled_engine_tracks_block_usage() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 1,
            high_watermark: 2,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        let g0 = e.pool_gauges().unwrap();
        assert_eq!(g0.free_blocks, 16);
        let r = e.run_all(vec![req(1, 40)]).unwrap();
        assert_eq!(r[0].metrics.tokens_out, 40);
        // drained up to the prefix cache's pin on the prompt's whole block
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 1);
        assert_eq!(g.prefix_pinned_blocks, 1); // 11-token prompt, 8-block
        assert_eq!(g.free_blocks, 15);
        assert_eq!(g.preemptions, 0);
        // clearing the cache releases the pin: fully free again
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
    }

    #[test]
    fn pool_preemption_round_trip() {
        // 9 blocks x 8 tokens: one row needs ~6 blocks near its 40-token
        // budget (+window), so two concurrent rows must collide and the
        // youngest must be preempted, re-queued, and still complete.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 9,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        let reqs = (0..3).map(|i| req(i, 50)).collect();
        let rs = e.run_all(reqs).unwrap();
        assert_eq!(rs.len(), 3);
        let mut ids: Vec<u64> = rs.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2]);
        for r in &rs {
            assert_eq!(r.metrics.tokens_out, 50, "request {} cut short", r.id);
        }
        assert!(
            e.metrics.preemptions >= 1,
            "two 6-block rows in a 9-block pool must preempt"
        );
        assert!(
            e.metrics.resumes >= 1 && e.metrics.resume_fallbacks == 0,
            "preempted rows must resume via recompute, not restart"
        );
        // leak-free: beyond the cache pin the drained pool is fully free
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 9);
    }

    #[test]
    fn abort_rows_clears_engine_and_pool() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        assert!(e.submit(req(1, 40), 0.0).unwrap());
        assert!(e.submit(req(2, 40), 0.0).unwrap());
        for _ in 0..5 {
            e.step().unwrap();
        }
        let mut ids = e.abort_rows();
        ids.sort_unstable();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(e.active(), 0);
        // aborted rows returned their blocks; nothing was re-queued. Only
        // the prefix cache's pin on the shared prompt block remains.
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
        assert!(e.take_preempted().is_empty());
        assert!(e.abort_rows().is_empty());
    }

    // 19-token prompt: private admission needs blocks_for(20) = 3 free blocks
    fn big(id: u64) -> Request {
        Request {
            id,
            prompt: "#A=3;B=7;C=2;D=5;\n>".into(),
            template: String::new(),
            max_new: 50,
            resume: None,
        }
    }

    #[test]
    fn pool_admission_defers_when_free_blocks_short() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        // prefix sharing off: this is the private-allocation admission path
        let mut cfg = sim_cfg(2, Some(pool));
        cfg.prefix_cache = None;
        let mut e = Engine::new_sim(cfg).unwrap();
        assert!(e.submit(big(1), 0.0).unwrap());
        // 25 decode steps: row 1 is at live = 19 + 25 = 44 tokens = 6 of the
        // 8 blocks (first lazy eviction only lands at pos 48), so free = 2
        for _ in 0..25 {
            e.step().unwrap();
            assert!(e.take_preempted().is_empty(), "solo row must never preempt");
        }
        assert!(
            !e.submit(big(2), 0.0).unwrap(),
            "admission must defer while the pool cannot cover the prompt"
        );
        assert!(e.has_free_row(), "the decline must come from the pool, not rows");
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 2);
    }

    #[test]
    fn prefix_sharing_admits_where_private_allocation_cannot() {
        // Same shape as pool_admission_defers_when_free_blocks_short, but
        // with the prefix cache on: the identical prompt's two whole blocks
        // are forked from the first row, so the second admission only needs
        // one private block — and 2 are free.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        assert!(e.submit(big(1), 0.0).unwrap());
        for _ in 0..25 {
            e.step().unwrap();
        }
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 1);
        assert_eq!(g.prefix_misses, 1);
        assert!(
            e.submit(big(2), 0.0).unwrap(),
            "an identical prompt must be admitted through block sharing"
        );
        assert_eq!(e.active(), 2);
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_hits, 1);
        assert!(g.shared_blocks >= 2, "prompt blocks shared: {g:?}");
        // both requests complete (one may preempt and retry under this
        // tight pool) and the pool drains once the cache pin is released
        let mut done: Vec<u64> = Vec::new();
        let mut pending: Vec<Request> = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap().into_iter().map(|r| r.id));
            pending.extend(e.take_preempted());
            while let Some(r) = pending.pop() {
                if !e.submit(r.clone(), 0.0).unwrap() {
                    pending.push(r);
                    break;
                }
            }
            if e.active() == 0 && pending.is_empty() {
                break;
            }
        }
        done.sort_unstable();
        assert_eq!(done, vec![1, 2]);
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 8);
    }

    #[test]
    fn prefix_hit_skips_prefill_entirely() {
        // The physical-paging acceptance test: an identical prompt's second
        // admission runs ZERO prefill executions — the cached blocks are the
        // data and the seed supplies tail rows + tracker + first logits —
        // and the generated text is byte-identical to the cold run.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        let r1 = e.run_all(vec![req(1, 24)]).unwrap();
        assert_eq!(e.exec_counts().prefill, 1);
        assert_eq!(e.pool_gauges().unwrap().prefix_prefill_skips, 0);
        let r2 = e.run_all(vec![req(2, 24)]).unwrap();
        assert_eq!(
            e.exec_counts().prefill,
            1,
            "identical prompt must not prefill again"
        );
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_prefill_skips, 1);
        assert_eq!(g.prefix_hits, 1);
        assert_eq!(r1[0].text, r2[0].text, "seeded admission changed output");
        // a prompt with the same whole-block header but a divergent tail
        // gets the block sharing — and MUST still run its own prefill
        let r3 = e
            .run_all(vec![Request {
                id: 3,
                prompt: "#A=3;B=7;\n?".into(), // last char differs (slot 10)
                template: String::new(),
                max_new: 24,
                resume: None,
            }])
            .unwrap();
        assert_eq!(r3.len(), 1);
        assert_eq!(e.exec_counts().prefill, 2, "divergent tail must prefill");
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_hits, 2, "the shared header still counts as a hit");
        assert_eq!(g.prefix_prefill_skips, 1, "but not as a prefill skip");
    }

    #[test]
    fn arena_rows_track_records_through_eviction() {
        // End-to-end physical consistency: after admissions, CoW and several
        // eviction compactions, every live slot's stored K bytes must still
        // encode the token the records say lives there (the sim writes the
        // birth position into k_row[0]).
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        assert!(e.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..45 {
            e.step().unwrap();
        }
        let row = e.rows[0].as_ref().expect("row still decoding");
        assert!(row.evictions > 0, "test must cross an eviction pass");
        let t = row.seq.block_table().unwrap();
        for (slot, rec) in row.seq.records().iter().enumerate() {
            let (blk, off) = t.locate(slot).unwrap();
            let (k, _) = e.backend_kv_row(blk, off).expect("sim arena readable");
            assert_eq!(
                k[0] as u32, rec.pos,
                "slot {slot}: stored bytes diverged from records after compaction"
            );
        }
    }

    #[test]
    fn stale_pins_shed_to_reopen_admission() {
        // Five distinct prompts each leave a one-block cache pin behind.
        // With the engine drained, those pins are the only pool pressure;
        // the relief valve must restore free blocks to the high watermark
        // so the serve loop's admission latch can reopen.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 2,
            high_watermark: 6,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        for (i, p) in ["#A=1;B=2;\n>", "#A=2;B=3;\n>", "#A=3;B=4;\n>", "#A=4;B=5;\n>", "#A=5;B=6;\n>"]
            .iter()
            .enumerate()
        {
            let r = e
                .run_all(vec![Request {
                    id: i as u64,
                    prompt: (*p).into(),
                    template: String::new(),
                    max_new: 8,
                    resume: None,
                }])
                .unwrap();
            assert_eq!(r.len(), 1);
        }
        let g = e.pool_gauges().unwrap();
        assert_eq!(g.prefix_entries, 5);
        assert_eq!(g.prefix_pinned_blocks, 5);
        assert_eq!(g.free_blocks, 3); // below the high watermark of 6
        e.shed_prefix_to_high_watermark();
        let g = e.pool_gauges().unwrap();
        assert!(g.free_blocks >= 6, "valve must reach the high watermark");
        assert_eq!(g.prefix_entries, 2);
    }

    #[test]
    fn divergent_tails_copy_on_write_without_corruption() {
        // Prompts share their first whole block (8 identical chars) then
        // diverge. Under sharing, each row's output must match the output
        // of a solo, sharing-free run of the same prompt — byte for byte.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let prompts = ["#A=3;B=7;C=2;\n>", "#A=3;B=7;D=9;\n>", "#A=3;B=7;E=1;\n>"];
        let solo: Vec<String> = prompts
            .iter()
            .map(|p| {
                let mut cfg = sim_cfg(1, None);
                cfg.prefix_cache = None;
                let mut e = Engine::new_sim(cfg).unwrap();
                let r = e
                    .run_all(vec![Request {
                        id: 0,
                        prompt: (*p).into(),
                        template: String::new(),
                        max_new: 40,
                        resume: None,
                    }])
                    .unwrap();
                r[0].text.clone()
            })
            .collect();

        let mut e = Engine::new_sim(sim_cfg(2, Some(pool))).unwrap();
        let reqs: Vec<Request> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| Request {
                id: i as u64,
                prompt: (*p).into(),
                template: String::new(),
                max_new: 40,
                resume: None,
            })
            .collect();
        let mut rs = e.run_all(reqs).unwrap();
        rs.sort_by_key(|r| r.id);
        assert_eq!(rs.len(), 3);
        for (r, want) in rs.iter().zip(solo.iter()) {
            assert_eq!(&r.text, want, "request {} corrupted under sharing", r.id);
        }
        let g = e.pool_gauges().unwrap();
        assert!(g.prefix_hits >= 2, "later prompts must hit the shared block");
        e.clear_prefix_cache();
        assert_eq!(e.pool_gauges().unwrap().free_blocks, 16);
    }

    fn policy_cfg(policy: &str) -> EngineConfig {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut cfg = sim_cfg(1, Some(pool));
        cfg.policy = policy.into();
        cfg
    }

    #[test]
    fn resume_preserves_tracker_and_output_across_policies() {
        // The acceptance property: a preempted-and-resumed row is
        // byte-identical to a never-preempted run — same output, same
        // eviction keep-sets — because the tracker records (TS/MRI/H1/H2
        // observation history) survive the round trip instead of being
        // re-initialized. Checked for the lagged policy and three greedy
        // baselines whose scores all read different record fields.
        for policy in ["lazy", "h2o", "tova", "streaming"] {
            let mut a = Engine::new_sim(policy_cfg(policy)).unwrap(); // never preempted
            let mut b = Engine::new_sim(policy_cfg(policy)).unwrap(); // preempted at step 35
            assert!(a.submit(req(1, 45), 0.0).unwrap());
            assert!(b.submit(req(1, 45), 0.0).unwrap());
            for _ in 0..35 {
                a.step().unwrap();
                b.step().unwrap();
            }
            b.preempt_row(0);
            assert_eq!(b.active(), 0);
            let mut pre = b.take_preempted();
            assert_eq!(pre.len(), 1);
            {
                let st = pre[0].resume.as_ref().expect("snapshot attached");
                assert!(st.finish.is_none());
                assert!(st.produced > 1);
                assert!(!st.records.is_empty());
            }
            assert!(b.submit(pre.pop().unwrap(), 0.0).unwrap());
            assert_eq!(b.metrics.resumes, 1, "{policy}");
            assert_eq!(
                b.metrics.resume_fallbacks, 0,
                "{policy}: must recompute, not restart"
            );
            assert!(b.metrics.recomputed_tokens > 0, "{policy}");
            let same_records = |a: &Engine, b: &Engine, at: &str| {
                let ra = a.rows[0].as_ref().unwrap().seq.records();
                let rb = b.rows[0].as_ref().unwrap().seq.records();
                assert_eq!(ra.len(), rb.len(), "{policy} ({at}): keep-set size");
                for (x, y) in ra.iter().zip(rb.iter()) {
                    assert_eq!(x.pos, y.pos, "{policy} ({at}): keep-set identity");
                    assert_eq!(x.ts, y.ts, "{policy} ({at}): TS");
                    assert_eq!(x.mri, y.mri, "{policy} ({at}): MRI must survive");
                    assert_eq!(x.hits, y.hits, "{policy} ({at})");
                    assert_eq!(x.last_attn, y.last_attn, "{policy} ({at})");
                    assert_eq!(x.cum_attn, y.cum_attn, "{policy} ({at})");
                }
            };
            // restored, not re-initialized: records match the control engine
            // immediately after resume, and eviction decisions stay in
            // lockstep over the following steps
            same_records(&a, &b, "post-resume");
            for _ in 0..5 {
                a.step().unwrap();
                b.step().unwrap();
            }
            same_records(&a, &b, "post-resume + 5 steps");
            let finish = |e: &mut Engine| -> Vec<Response> {
                let mut out = Vec::new();
                for _ in 0..10_000 {
                    out.extend(e.step().unwrap());
                    if e.active() == 0 {
                        break;
                    }
                }
                out
            };
            let ra = finish(&mut a);
            let rb = finish(&mut b);
            assert_eq!(ra.len(), 1);
            assert_eq!(rb.len(), 1);
            assert_eq!(ra[0].text, rb[0].text, "{policy}: output diverged");
            assert_eq!(
                ra[0].metrics.evictions, rb[0].metrics.evictions,
                "{policy}: eviction history diverged"
            );
            assert_eq!(ra[0].metrics.tokens_out, rb[0].metrics.tokens_out);
            assert_eq!(ra[0].live_curve, rb[0].live_curve, "{policy}: live curves");
        }
    }

    #[test]
    fn same_step_preemption_victims_requeue_oldest_first() {
        // Four rows in an 8-block pool: one long private row, one donor row
        // and two pure prefix forks. When all three 16-token rows hit a
        // block boundary in the same step with one free block, the two
        // forks (whose releases free nothing — every block they hold is
        // shared) are both preempted in ONE ensure_block_headroom pass.
        // take_preempted must hand them back oldest victim first.
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 8,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(4, Some(pool))).unwrap();
        let mk = |id: u64, prompt: &str| Request {
            id,
            prompt: prompt.into(),
            template: String::new(),
            max_new: 24,
            resume: None,
        };
        let prompt_a = format!("#{}\n>", "A=1;".repeat(8)); // 35 chars → 5 blocks
        let p16 = "#A=3;B=7;C=25;\n>"; // exactly 2 whole blocks
        assert_eq!(p16.chars().count(), 16);
        assert!(e.submit(mk(0, &prompt_a), 0.0).unwrap());
        assert!(e.submit(mk(1, p16), 0.0).unwrap()); // donor: allocates 2
        assert!(e.submit(mk(2, p16), 0.0).unwrap()); // fork: allocates 0
        assert!(e.submit(mk(3, p16), 0.0).unwrap()); // fork: allocates 0
        assert_eq!(e.active(), 4);
        e.step().unwrap();
        let pre = e.take_preempted();
        assert_eq!(pre.len(), 2, "both forks must be preempted in one step");
        assert_eq!(pre[0].id, 2, "oldest victim must drain first");
        assert_eq!(pre[1].id, 3);
        for r in &pre {
            let st = r.resume.as_ref().expect("victims carry resume state");
            assert_eq!(st.records.len(), 16);
            assert!(st.finish.is_none());
        }
        // resubmit oldest-first and drive everything to completion: the
        // resumed rows recompute (no fallback) and identical prompts still
        // produce identical outputs
        let mut pending: std::collections::VecDeque<Request> = pre.into_iter().collect();
        let mut done: Vec<Response> = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap());
            for r in e.take_preempted().into_iter().rev() {
                pending.push_front(r);
            }
            while e.has_free_row() {
                let Some(r) = pending.pop_front() else { break };
                if !e.submit(r.clone(), 0.0).unwrap() {
                    pending.push_front(r);
                    break;
                }
            }
            if e.active() == 0 && pending.is_empty() {
                break;
            }
        }
        let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 3]);
        assert!(e.metrics.resumes >= 2, "forks must resume, not restart");
        assert_eq!(e.metrics.resume_fallbacks, 0);
        assert!(e.metrics.recomputed_tokens >= 32);
        done.sort_by_key(|r| r.id);
        assert_eq!(done[1].text, done[2].text, "resumed fork diverged");
        assert_eq!(done[1].text, done[3].text, "resumed fork diverged");
    }

    #[test]
    fn resume_accumulates_queue_wait_and_preserves_timing() {
        let pool = PoolConfig {
            block_size: 8,
            n_blocks: 16,
            low_watermark: 0,
            high_watermark: 0,
        };
        let mut e = Engine::new_sim(sim_cfg(1, Some(pool))).unwrap();
        assert!(e.submit(req(1, 40), 0.25).unwrap());
        for _ in 0..10 {
            e.step().unwrap();
        }
        e.preempt_row(0);
        let mut pre = e.take_preempted();
        std::thread::sleep(std::time::Duration::from_millis(40));
        // the 0.0 here is ignored: the resumed wait is the snapshot's
        // accumulated 0.25 s plus the measured re-queue time
        assert!(e.submit(pre.pop().unwrap(), 0.0).unwrap());
        let mut resp = None;
        for _ in 0..10_000 {
            let done = e.step().unwrap();
            if let Some(r) = done.into_iter().next() {
                resp = Some(r);
                break;
            }
        }
        let r = resp.expect("resumed row completes");
        assert!(
            r.metrics.queued_s >= 0.28,
            "queue wait must accumulate across preemption: {}",
            r.metrics.queued_s
        );
        // TTFT is a first-admission property — it predates the preemption,
        // so the 40 ms re-queue sleep must separate it from completion
        // (a relative bound: an absolute one would flake on slow runners)
        assert!(
            r.metrics.total_s - r.metrics.ttft_s >= 0.035,
            "ttft {} must not absorb the re-queue wait (total {})",
            r.metrics.ttft_s,
            r.metrics.total_s
        );
        assert!(r.metrics.total_s >= 0.04, "total {}", r.metrics.total_s);
        assert_eq!(r.metrics.tokens_out, 40);
        assert_eq!(e.metrics.resumes, 1);
    }

    #[test]
    fn resume_falls_back_to_restart_when_stream_outgrows_bucket() {
        // 11-token prompt + 56 generated tokens = a 67-token fed stream,
        // past the sim's 64-token prefill bucket: recompute is impossible
        // in one pass, so the resume restarts from the prompt (counted).
        let solo = {
            let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
            e.run_all(vec![req(1, 60)]).unwrap()[0].text.clone()
        };
        let mut e = Engine::new_sim(policy_cfg("lazy")).unwrap();
        assert!(e.submit(req(1, 60), 0.0).unwrap());
        for _ in 0..55 {
            e.step().unwrap();
        }
        e.preempt_row(0);
        let mut pre = e.take_preempted();
        assert!(pre[0].resume.as_ref().unwrap().pos > 64);
        assert!(e.submit(pre.pop().unwrap(), 0.0).unwrap());
        assert_eq!(e.metrics.resume_fallbacks, 1);
        assert_eq!(e.metrics.resumes, 0);
        let mut done = Vec::new();
        for _ in 0..10_000 {
            done.extend(e.step().unwrap());
            if e.active() == 0 {
                break;
            }
        }
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].metrics.tokens_out, 60, "restart regenerates fully");
        assert_eq!(done[0].text, solo, "restart output must still match");
    }
}
