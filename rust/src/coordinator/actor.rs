//! Engine-as-actor: one replica of the fleet, owned by its own thread.
//!
//! PRs 1–7 drove the engine as a borrowed-in-a-loop struct — the serve
//! loop called `submit`/`step`/`take_preempted` directly on the calling
//! thread. That shape cannot replicate: the fleet needs N engines running
//! *concurrently*, each with its own `BlockPool`, `PrefixCache`, and
//! `HostTier`. This module makes the engine a library-owned actor:
//! [`spawn_engine_actor`] moves an [`Engine`] onto a dedicated thread that
//! runs exactly the single-engine serve iteration (cancel sweep →
//! admission → step → preemption re-queue → telemetry publish) in a loop,
//! and the only way in or out is messages:
//!
//! * inbound ([`EngineMsg`], per-replica channel): `Submit` a parsed
//!   request, `Cancel` an id, request a telemetry `Snapshot`, or `Drain`
//!   (finish everything, then exit cleanly);
//! * outbound ([`ActorEvent`], one channel shared by the whole fleet):
//!   per-token events, terminal `Done`/`Failed` replies, `Orphaned`
//!   requests (see below), and a final `Exited`.
//!
//! Each actor owns a private [`RequestQueue`]: preemption victims re-enter
//! *their own replica's* front lane oldest-first — never another
//! replica's — because their `resume` snapshot references blocks that only
//! exist in this engine's pool. The router can only influence placement at
//! submit time; after that, a request's home is fixed.
//!
//! **Kill semantics** (the fleet's failure contract, extending PR 1's
//! deterministic failure routing): dropping the inbound sender is the
//! fault model for a dead replica. The actor detects the disconnect,
//! aborts its active rows (each emits a deterministic `Failed`), releases
//! tier state for queued *preempted* requests and fails them too (their
//! snapshots are meaningless off this replica), and hands queued *fresh*
//! requests back as `Orphaned` — the router re-places those on surviving
//! replicas, so a replica death costs at most the work that was already
//! decoding on it, and no connection ever hangs.
//!
//! Lock-free visibility: the actor publishes [`ReplicaStatus`] atomics
//! (free blocks, parked bytes, queue depth, liveness) plus its prefix
//! digest every iteration; the router reads them without ever blocking on
//! an engine.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::metrics::PoolGauges;
use crate::scheduler::{AdmissionController, QueuedRequest, ReplicaView, RequestQueue, SloClass};
use crate::telemetry::{event, span, SpanContext};
use crate::util::sync::lock_unpoisoned;

use super::{Engine, Request, Response, TokenEvent};

/// Inbound control messages for one engine actor.
pub enum EngineMsg {
    /// Place a request on this replica (router decision already made).
    Submit(QueuedRequest),
    /// Client gone: release whatever state the replica holds for this id.
    Cancel(u64),
    /// Reply with a point-in-time [`ReplicaSnapshot`] on the given sender.
    Snapshot(mpsc::Sender<ReplicaSnapshot>),
    /// Finish all queued + active work, then exit cleanly.
    Drain,
}

/// Outbound events, multiplexed onto the fleet-wide channel. Every event
/// carries its replica index so the pump can attribute it.
pub enum ActorEvent {
    /// One decoded token (streaming pump forwards or drops it).
    Token { replica: usize, ev: TokenEvent },
    /// Terminal success + this replica's pool gauges at completion.
    Done {
        replica: usize,
        resp: Response,
        gauges: Option<PoolGauges>,
    },
    /// Terminal deterministic failure for a request this replica owned.
    Failed {
        replica: usize,
        req: u64,
        error: String,
    },
    /// A fresh (never-admitted) request this replica can no longer serve
    /// (kill teardown). No state was lost — the router re-places it.
    Orphaned { replica: usize, req: QueuedRequest },
    /// The actor thread is gone. `clean` distinguishes drain from kill.
    Exited { replica: usize, clean: bool },
}

/// Point-in-time replica introspection (the `Snapshot` reply).
#[derive(Clone, Debug)]
pub struct ReplicaSnapshot {
    pub replica: usize,
    pub policy: String,
    pub active: usize,
    pub queue_len: usize,
    pub digest: Vec<u64>,
    pub pool: Option<PoolGauges>,
}

/// Lock-free routing view, published by the actor every iteration and read
/// by the router on every placement. The digest sits behind a mutex (it is
/// a `Vec`), swapped wholesale and only when it changed.
#[derive(Default)]
pub struct ReplicaStatus {
    pub alive: AtomicBool,
    pub free_blocks: AtomicUsize,
    pub total_blocks: AtomicUsize,
    pub parked_bytes: AtomicUsize,
    pub queue_len: AtomicUsize,
    pub active: AtomicUsize,
    pub pressure_floor: AtomicUsize,
    digest: Mutex<Vec<u64>>,
}

impl ReplicaStatus {
    /// Sample everything into the router's [`ReplicaView`].
    pub fn view(&self) -> ReplicaView {
        ReplicaView {
            alive: self.alive.load(Ordering::Acquire),
            free_blocks: self.free_blocks.load(Ordering::Relaxed),
            total_blocks: self.total_blocks.load(Ordering::Relaxed),
            parked_bytes: self.parked_bytes.load(Ordering::Relaxed),
            queue_len: self.queue_len.load(Ordering::Relaxed),
            active: self.active.load(Ordering::Relaxed),
            pressure_floor: self.pressure_floor.load(Ordering::Relaxed),
            digest: lock_unpoisoned(&self.digest).clone(),
        }
    }

    fn set_digest(&self, d: Vec<u64>) {
        let mut g = lock_unpoisoned(&self.digest);
        if *g != d {
            *g = d;
        }
    }
}

/// The fleet's grip on one replica. `kill` drops the sender — the actor
/// observes the disconnect and runs its teardown protocol (doc above).
pub struct ActorHandle {
    pub replica: usize,
    pub status: Arc<ReplicaStatus>,
    tx: Mutex<Option<mpsc::Sender<EngineMsg>>>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl ActorHandle {
    /// True if the message was delivered to a live actor.
    fn send(&self, msg: EngineMsg) -> bool {
        match &*lock_unpoisoned(&self.tx) {
            Some(tx) => tx.send(msg).is_ok(),
            None => false,
        }
    }

    /// Deliver a request to a live actor; a dead one hands the request
    /// back so the router can place it somewhere else.
    pub fn submit(&self, q: QueuedRequest) -> Result<(), QueuedRequest> {
        match &*lock_unpoisoned(&self.tx) {
            Some(tx) => match tx.send(EngineMsg::Submit(q)) {
                Ok(()) => Ok(()),
                Err(mpsc::SendError(EngineMsg::Submit(q))) => Err(q),
                Err(_) => unreachable!("submit sends only Submit"),
            },
            None => Err(q),
        }
    }

    pub fn cancel(&self, id: u64) -> bool {
        self.send(EngineMsg::Cancel(id))
    }

    /// Synchronous snapshot round-trip (None if the actor is gone).
    pub fn snapshot(&self) -> Option<ReplicaSnapshot> {
        let (tx, rx) = mpsc::channel();
        if !self.send(EngineMsg::Snapshot(tx)) {
            return None;
        }
        rx.recv_timeout(Duration::from_secs(5)).ok()
    }

    /// Ask the actor to finish everything and exit cleanly.
    pub fn drain(&self) -> bool {
        self.send(EngineMsg::Drain)
    }

    /// Fault injection / shutdown: drop the inbound sender. The actor sees
    /// `Disconnected` on its next receive and tears down deterministically.
    pub fn kill(&self) {
        lock_unpoisoned(&self.tx).take();
    }

    pub fn is_alive(&self) -> bool {
        self.status.alive.load(Ordering::Acquire)
    }

    /// Wait for the actor thread to exit (after `drain` or `kill`).
    pub fn join(&self) {
        if let Some(j) = lock_unpoisoned(&self.join).take() {
            let _ = j.join();
        }
    }
}

/// Move `engine` onto its own thread as replica `replica`, emitting
/// [`ActorEvent`]s on `events`. The engine's metrics are labeled with the
/// replica index iff it was marked via [`Engine::set_replica_label`] —
/// callers running a single-replica fleet skip the label to keep the
/// established unlabeled metric names.
pub fn spawn_engine_actor(
    engine: Engine,
    replica: usize,
    events: mpsc::Sender<ActorEvent>,
) -> ActorHandle {
    let (tx, rx) = mpsc::channel::<EngineMsg>();
    let status = Arc::new(ReplicaStatus::default());
    status.alive.store(true, Ordering::Release);
    if let Some(pc) = &engine.cfg.pool {
        status.pressure_floor.store(pc.low_watermark, Ordering::Relaxed);
        status.total_blocks.store(pc.n_blocks, Ordering::Relaxed);
        status.free_blocks.store(pc.n_blocks, Ordering::Relaxed);
    }
    let st = status.clone();
    let join = std::thread::spawn(move || actor_loop(engine, replica, rx, events, st));
    ActorHandle {
        replica,
        status,
        tx: Mutex::new(Some(tx)),
        join: Mutex::new(Some(join)),
    }
}

/// The replica thread: the single-engine serve iteration, message-driven.
fn actor_loop(
    mut engine: Engine,
    replica: usize,
    rx: mpsc::Receiver<EngineMsg>,
    events: mpsc::Sender<ActorEvent>,
    status: Arc<ReplicaStatus>,
) {
    let queue = RequestQueue::new();
    let mut admission = AdmissionController::new();
    let mut classes: HashMap<u64, SloClass> = HashMap::new();
    // per-request trace contexts (kept across the preempt/resume round
    // trip, forwarded to the engine before every submit) and the currently
    // open queue-wait span per queued request
    let mut spans: HashMap<u64, SpanContext> = HashMap::new();
    let mut qwaits: HashMap<u64, u64> = HashMap::new();
    let mut cancels: Vec<u64> = Vec::new();
    let mut pending: Vec<EngineMsg> = Vec::new();
    let mut draining = false;
    let mut killed = false;

    'life: loop {
        let mut idle = true;

        // ---- inbound: pending (from the idle wait) first, then drain the
        // channel without blocking. A disconnect here is the kill signal.
        let mut inbox = std::mem::take(&mut pending);
        loop {
            match rx.try_recv() {
                Ok(m) => inbox.push(m),
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    killed = true;
                    break;
                }
            }
        }
        for msg in inbox {
            match msg {
                EngineMsg::Submit(q) => {
                    classes.insert(q.id, q.class);
                    if !q.span.is_off() {
                        spans.insert(q.id, q.span);
                        if let Some(t) = engine.telemetry() {
                            let sid = t.span_open(
                                q.id,
                                span::name::QUEUE_WAIT,
                                q.span,
                                Some(replica),
                                0.0,
                                q.class.as_str(),
                            );
                            qwaits.insert(q.id, sid);
                        }
                    }
                    queue.push(q);
                    idle = false;
                }
                EngineMsg::Cancel(id) => cancels.push(id),
                EngineMsg::Snapshot(reply) => {
                    let _ = reply.send(ReplicaSnapshot {
                        replica,
                        policy: engine.policy_name(),
                        active: engine.active(),
                        queue_len: queue.len(),
                        digest: engine.prefix_digest(),
                        pool: engine.pool_gauges(),
                    });
                }
                EngineMsg::Drain => draining = true,
            }
        }
        if killed {
            break 'life;
        }

        // ---- cancellation sweep: same ownership routing as the
        // single-engine loop (queued-fresh / queued-preempted / active).
        for id in std::mem::take(&mut cancels) {
            classes.remove(&id);
            spans.remove(&id);
            if let Some(sid) = qwaits.remove(&id) {
                if let Some(t) = engine.telemetry() {
                    t.span_close_full(sid, None, Some("cancelled"), false);
                }
            }
            if let Some(q) = queue.remove(id) {
                match &q.resume {
                    Some(st) => engine.release_discarded_state(st, id),
                    None => {
                        engine.metrics.cancelled_rows += 1;
                        if let Some(t) = engine.telemetry() {
                            t.record(id, event::ABORT, 0, 0, 0.0, "unadmitted");
                        }
                    }
                }
            } else {
                engine.abort_request(id);
            }
        }

        // ---- admission under pool pressure (verbatim single-engine rules)
        let mut admit_open = match engine.pool_pressure() {
            Some(p) => admission.allow(&p),
            None => true,
        };
        if !admit_open && engine.active() == 0 && !queue.is_empty() {
            engine.shed_prefix_to_high_watermark();
            if let Some(p) = engine.pool_pressure() {
                admit_open = admission.allow(&p);
            }
        }
        while admit_open && engine.has_free_row() {
            let Some(q) = queue.try_pop() else { break };
            let queued_s = q.queued_at.elapsed().as_secs_f64();
            classes.insert(q.id, q.class);
            let req = Request {
                id: q.id,
                prompt: q.prompt.clone(),
                template: q.template.clone(),
                max_new: q.max_new,
                resume: q.resume.clone(),
            };
            engine.note_span(q.id, q.span);
            match engine.submit(req, queued_s) {
                Ok(true) => {
                    if let Some(sid) = qwaits.remove(&q.id) {
                        if let Some(t) = engine.telemetry() {
                            t.span_close_full(sid, Some(queued_s * 1e3), None, false);
                        }
                    }
                    idle = false;
                }
                Ok(false) => {
                    queue.push_front(q);
                    break;
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    eprintln!("replica {replica}: submit error (request {}): {msg}", q.id);
                    classes.remove(&q.id);
                    spans.remove(&q.id);
                    if let Some(sid) = qwaits.remove(&q.id) {
                        if let Some(t) = engine.telemetry() {
                            t.span_close_full(sid, None, Some("error"), false);
                        }
                    }
                    let _ = events.send(ActorEvent::Failed {
                        replica,
                        req: q.id,
                        error: msg,
                    });
                }
            }
        }

        // ---- decode step: tokens first, then terminals, then re-queue
        // preemption victims on *this* replica's front lane.
        if engine.active() > 0 {
            idle = false;
            match engine.step() {
                Ok(done) => {
                    for ev in engine.drain_token_events() {
                        let _ = events.send(ActorEvent::Token { replica, ev });
                    }
                    let gauges = engine.pool_gauges();
                    for resp in done {
                        classes.remove(&resp.id);
                        spans.remove(&resp.id);
                        let _ = events.send(ActorEvent::Done {
                            replica,
                            resp,
                            gauges: gauges.clone(),
                        });
                    }
                }
                Err(e) => {
                    let msg = format!("engine step error: {e:#}");
                    eprintln!("replica {replica}: {msg}");
                    engine.drain_token_events();
                    for id in engine.abort_rows() {
                        classes.remove(&id);
                        spans.remove(&id);
                        let _ = events.send(ActorEvent::Failed {
                            replica,
                            req: id,
                            error: msg.clone(),
                        });
                    }
                }
            }
            let now = Instant::now();
            let requeued: Vec<QueuedRequest> = engine
                .take_preempted()
                .into_iter()
                .map(|r| QueuedRequest {
                    class: classes.get(&r.id).copied().unwrap_or_default(),
                    span: spans.get(&r.id).copied().unwrap_or_default(),
                    id: r.id,
                    prompt: r.prompt,
                    template: r.template,
                    max_new: r.max_new,
                    queued_at: now,
                    resume: r.resume,
                })
                .collect();
            if let Some(t) = engine.telemetry() {
                for q in &requeued {
                    if !q.span.is_off() {
                        let sid = t.span_open(
                            q.id,
                            span::name::QUEUE_WAIT,
                            q.span,
                            Some(replica),
                            0.0,
                            "requeue",
                        );
                        qwaits.insert(q.id, sid);
                    }
                }
            }
            queue.push_front_all(requeued);
        }

        // ---- publish: registry snapshots + the router's lock-free view
        engine.publish_telemetry();
        publish_status(&engine, &queue, &status);

        if draining && queue.is_empty() && engine.active() == 0 {
            break 'life;
        }

        if idle {
            if queue.is_empty() {
                // park on the inbound channel: any message wakes us; the
                // timeout bounds telemetry staleness while fully idle
                match rx.recv_timeout(Duration::from_millis(25)) {
                    Ok(m) => pending.push(m),
                    Err(mpsc::RecvTimeoutError::Timeout) => {}
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        killed = true;
                        break 'life;
                    }
                }
            } else {
                // queued work held by the pressure latch: the wake condition
                // is the engine's own pool state, not a message, so there is
                // nothing to park on
                // lazylint: allow(determinism): 1ms yield while the admission latch waits on pool pressure, which no channel signals
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }

    // ---- teardown. Clean drain has nothing in flight by construction;
    // a kill deterministically disposes of everything this replica owned.
    if killed {
        let msg = format!("replica {replica} killed");
        engine.drain_token_events();
        for id in engine.abort_rows() {
            classes.remove(&id);
            let _ = events.send(ActorEvent::Failed {
                replica,
                req: id,
                error: msg.clone(),
            });
        }
        while let Some(q) = queue.try_pop() {
            classes.remove(&q.id);
            spans.remove(&q.id);
            if let Some(sid) = qwaits.remove(&q.id) {
                if let Some(t) = engine.telemetry() {
                    let note = if q.resume.is_some() { "killed" } else { "orphaned" };
                    t.span_close_full(sid, None, Some(note), false);
                }
            }
            match &q.resume {
                Some(st) => {
                    // the snapshot references this replica's pool/tier —
                    // worthless anywhere else: release + deterministic fail
                    engine.release_discarded_state(st, q.id);
                    let _ = events.send(ActorEvent::Failed {
                        replica,
                        req: q.id,
                        error: msg.clone(),
                    });
                }
                None => {
                    // never admitted here: the router can place it again
                    let _ = events.send(ActorEvent::Orphaned { replica, req: q });
                }
            }
        }
    }
    engine.publish_telemetry();
    status.alive.store(false, Ordering::Release);
    status.queue_len.store(0, Ordering::Relaxed);
    status.active.store(0, Ordering::Relaxed);
    let _ = events.send(ActorEvent::Exited {
        replica,
        clean: !killed,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineConfig;
    use crate::kvpool::PoolConfig;

    fn pooled_cfg(batch: usize, n_blocks: usize) -> EngineConfig {
        let mut cfg = EngineConfig {
            batch,
            cache: 64,
            budget: 40,
            policy: "full".into(),
            record_live: false,
            pool: Some(PoolConfig {
                block_size: 8,
                n_blocks,
                low_watermark: 2,
                high_watermark: 4,
            }),
            ..Default::default()
        };
        cfg.params.window = 8;
        cfg.params.recent = 8;
        cfg
    }

    fn queued(id: u64, max_new: usize) -> QueuedRequest {
        QueuedRequest {
            id,
            prompt: "#A=3;B=7;\n>".into(),
            template: String::new(),
            max_new,
            class: SloClass::Standard,
            queued_at: Instant::now(),
            resume: None,
            span: SpanContext::default(),
        }
    }

    /// Run the same request on a plain (non-actor) engine: the actor's
    /// output must be byte-identical to this.
    fn control_text(max_new: usize) -> String {
        let mut e = Engine::new_sim(pooled_cfg(2, 16)).unwrap();
        e.submit(
            Request {
                id: 1,
                prompt: "#A=3;B=7;\n>".into(),
                template: String::new(),
                max_new,
                resume: None,
            },
            0.0,
        )
        .unwrap();
        loop {
            let done = e.step().unwrap();
            if let Some(r) = done.into_iter().next() {
                return r.text;
            }
        }
    }

    #[test]
    fn actor_round_trip_matches_direct_engine() {
        let (etx, erx) = mpsc::channel();
        let h = spawn_engine_actor(Engine::new_sim(pooled_cfg(2, 16)).unwrap(), 0, etx);
        assert!(h.submit(queued(1, 24)).is_ok());
        let mut tokens = String::new();
        let mut text = None;
        while text.is_none() {
            match erx.recv_timeout(Duration::from_secs(10)).expect("event") {
                ActorEvent::Token { replica, ev } => {
                    assert_eq!(replica, 0);
                    tokens.push_str(&ev.text);
                }
                ActorEvent::Done { resp, gauges, .. } => {
                    assert_eq!(resp.id, 1);
                    assert!(gauges.is_some(), "paged engine attaches gauges");
                    text = Some(resp.text);
                }
                ActorEvent::Failed { error, .. } => panic!("unexpected failure: {error}"),
                _ => {}
            }
        }
        let text = text.unwrap();
        assert_eq!(tokens, text, "token stream concatenates to the summary");
        assert_eq!(text, control_text(24), "actor output == direct engine");
        assert!(h.drain());
        h.join();
        assert!(!h.is_alive());
    }

    #[test]
    fn snapshot_answers_while_idle_and_drain_is_clean() {
        let (etx, erx) = mpsc::channel();
        let h = spawn_engine_actor(Engine::new_sim(pooled_cfg(2, 16)).unwrap(), 3, etx);
        let s = h.snapshot().expect("snapshot");
        assert_eq!(s.replica, 3);
        assert_eq!(s.policy, "full");
        assert_eq!(s.active, 0);
        assert!(s.pool.is_some());
        assert!(h.drain());
        h.join();
        // the final event is a clean exit
        let mut last = None;
        while let Ok(ev) = erx.try_recv() {
            last = Some(ev);
        }
        match last {
            Some(ActorEvent::Exited { replica: 3, clean: true }) => {}
            _ => panic!("expected clean Exited as the final event"),
        }
        // a dead actor rejects everything
        assert!(h.submit(queued(9, 8)).is_err());
        assert!(h.snapshot().is_none());
    }

    /// Kill contract: after dropping the channel mid-serve, every request
    /// the replica owned resolves deterministically — active rows fail,
    /// queued-fresh requests come back as re-routable orphans, and the
    /// actor exits. Nothing hangs.
    #[test]
    fn kill_resolves_every_owned_request() {
        let (etx, erx) = mpsc::channel();
        let h = spawn_engine_actor(Engine::new_sim(pooled_cfg(1, 16)).unwrap(), 0, etx);
        let ids: Vec<u64> = (1..=6).collect();
        for &id in &ids {
            assert!(h.submit(queued(id, 40)).is_ok());
        }
        // wait until the single row is actually decoding, then pull the plug
        loop {
            match erx.recv_timeout(Duration::from_secs(10)).expect("event") {
                ActorEvent::Token { .. } => break,
                ActorEvent::Done { .. } => break, // raced to completion: fine
                _ => {}
            }
        }
        h.kill();
        let mut outcomes: HashMap<u64, &'static str> = HashMap::new();
        let mut orphans = 0;
        loop {
            match erx.recv_timeout(Duration::from_secs(10)).expect("no hang") {
                ActorEvent::Token { .. } => {}
                ActorEvent::Done { resp, .. } => {
                    assert!(outcomes.insert(resp.id, "done").is_none());
                }
                ActorEvent::Failed { req, .. } => {
                    assert!(outcomes.insert(req, "failed").is_none());
                }
                ActorEvent::Orphaned { req, .. } => {
                    assert!(req.resume.is_none(), "orphans are always fresh");
                    assert!(outcomes.insert(req.id, "orphaned").is_none());
                    orphans += 1;
                }
                ActorEvent::Exited { clean, .. } => {
                    assert!(!clean, "kill is not a clean exit");
                    break;
                }
            }
        }
        h.join();
        for id in ids {
            assert!(
                outcomes.contains_key(&id),
                "request {id} vanished without a terminal outcome"
            );
        }
        // batch=1 and the kill lands within a step or two of the first
        // token, so most of the queue was never admitted — but the exact
        // split is a scheduling race; the contract is that orphans exist
        // and every orphan is fresh (asserted above).
        assert!(orphans >= 1, "queued-fresh requests must come back as orphans");
    }

    /// Satellite regression: preemption re-queues must stay on their home
    /// replica's front lane, oldest-first — a resume snapshot references
    /// blocks that only exist in the home engine's pool. Two actors share
    /// the event channel; every request targets replica 0 with a pool too
    /// small for the batch, so rows are preempted and resumed. Replica 1
    /// must see none of that traffic, and completions must come back in
    /// admission order (oldest victim resumed first).
    #[test]
    fn preemption_requeues_stay_home_oldest_first() {
        let (etx, erx) = mpsc::channel();
        let h0 = spawn_engine_actor(Engine::new_sim(pooled_cfg(3, 12)).unwrap(), 0, etx.clone());
        let h1 = spawn_engine_actor(Engine::new_sim(pooled_cfg(3, 12)).unwrap(), 1, etx);
        for id in 1..=3u64 {
            assert!(h0.submit(queued(id, 40)).is_ok());
        }
        let mut done_order = Vec::new();
        let mut preemptions = 0u64;
        while done_order.len() < 3 {
            match erx.recv_timeout(Duration::from_secs(20)).expect("fleet event") {
                ActorEvent::Token { replica, .. } => assert_eq!(replica, 0),
                ActorEvent::Done { replica, resp, gauges } => {
                    assert_eq!(replica, 0, "work must not migrate off its home");
                    done_order.push(resp.id);
                    if let Some(g) = gauges {
                        preemptions = preemptions.max(g.preemptions);
                    }
                }
                ActorEvent::Failed { error, .. } => panic!("unexpected failure: {error}"),
                ActorEvent::Orphaned { .. } => panic!("no kill in this test"),
                ActorEvent::Exited { .. } => panic!("no exit in this test"),
            }
        }
        assert!(
            preemptions > 0,
            "pool must be small enough to force preemption, else this test is vacuous"
        );
        assert_eq!(
            done_order,
            vec![1, 2, 3],
            "re-queued victims must resume oldest-first on their home replica"
        );
        // replica 1 idled throughout: no rows, no queue, still alive
        assert_eq!(h1.status.active.load(Ordering::Relaxed), 0);
        assert_eq!(h1.status.queue_len.load(Ordering::Relaxed), 0);
        assert!(h1.is_alive());
        h0.drain();
        h1.drain();
        h0.join();
        h1.join();
    }

    #[test]
    fn status_view_tracks_pool_and_digest() {
        let (etx, _erx) = mpsc::channel();
        let mut cfg = pooled_cfg(2, 16);
        cfg.prefix_cache = Some(crate::kvpool::PrefixCacheConfig::default());
        let h = spawn_engine_actor(Engine::new_sim(cfg).unwrap(), 0, etx);
        let v = h.status.view();
        assert!(v.alive);
        assert_eq!(v.total_blocks, 16);
        assert_eq!(v.pressure_floor, 2);
        // submit → the served prompt seeds the prefix cache → the digest
        // the actor publishes becomes non-empty
        assert!(h.submit(queued(1, 16)).is_ok());
        let t0 = Instant::now();
        loop {
            let v = h.status.view();
            if !v.digest.is_empty() {
                assert!(v.digest.windows(2).all(|w| w[0] < w[1]));
                break;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "digest never published"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        h.kill();
        h.join();
        assert!(!h.status.view().alive);
    }
}

fn publish_status(engine: &Engine, queue: &RequestQueue, status: &ReplicaStatus) {
    if let Some(p) = engine.pool_pressure() {
        status.free_blocks.store(p.free, Ordering::Relaxed);
        status.total_blocks.store(p.total, Ordering::Relaxed);
        status.pressure_floor.store(p.low_watermark, Ordering::Relaxed);
    }
    if let Some(g) = engine.pool_gauges() {
        status.parked_bytes.store(g.parked_bytes, Ordering::Relaxed);
    }
    status.queue_len.store(queue.len(), Ordering::Relaxed);
    status.active.store(engine.active(), Ordering::Relaxed);
    status.set_digest(engine.prefix_digest());
}
