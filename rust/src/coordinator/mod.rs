//! L3 coordinator: request/response types, engine configuration, and the
//! decode-loop engine that wires runtime ⇄ kvcache ⇄ eviction together.

pub mod engine;
pub mod row;

pub use engine::Engine;

use crate::eviction::PolicyParams;
use crate::kvpool::{PoolConfig, PrefixCacheConfig};
use crate::metrics::RequestMetrics;

/// Engine configuration (one engine = one compiled (batch, cache) shape).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Batch rows of the compiled executables.
    pub batch: usize,
    /// Physical slot capacity S of the device cache.
    pub cache: usize,
    /// KV budget B (paper's B; lagged policies additionally need headroom:
    /// capacity >= budget + window).
    pub budget: usize,
    /// Policy spec: `full`, `tova`, `h2o`, `raas`, `rkv`, `lazy`,
    /// `<base>+window` (see eviction::build).
    pub policy: String,
    pub params: PolicyParams,
    /// Importance threshold α for TS/MRI tracking.
    pub alpha: f32,
    /// Stop generation at this char (in addition to max_new). '\0' ⇒ none.
    pub stop_char: char,
    /// Collect layer-0 key sketches into records (needed by `rkv`).
    pub collect_sketches: bool,
    /// Record live-token counts each step (Fig. 6 memory curves).
    pub record_live: bool,
    /// Shared paged-KV block pool. `None` keeps the seed behavior (each row
    /// owns its full slot capacity); `Some` makes rows allocate blocks from
    /// a global budget, with pressure-driven admission and youngest-row
    /// preemption when it runs dry.
    pub pool: Option<PoolConfig>,
    /// Prompt-prefix block sharing across rows (paged mode only; ignored
    /// without `pool`). On by default: identical prompt headers fork whole
    /// blocks instead of re-allocating them. `None` disables sharing.
    pub prefix_cache: Option<PrefixCacheConfig>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: 1,
            cache: 256,
            budget: 192,
            policy: "lazy".into(),
            params: PolicyParams::default(),
            alpha: 5e-4,
            stop_char: '\0',
            collect_sketches: false,
            record_live: true,
            pool: None,
            prefix_cache: Some(PrefixCacheConfig::default()),
        }
    }
}

impl EngineConfig {
    /// Validate budget/capacity/window interplay.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.budget >= 2, "budget too small");
        anyhow::ensure!(
            self.budget <= self.cache,
            "budget {} > cache capacity {}",
            self.budget,
            self.cache
        );
        let w = self.params.window;
        if self.policy == "lazy" || self.policy.ends_with("+window") {
            anyhow::ensure!(
                self.budget + w <= self.cache,
                "lagged policy needs capacity >= budget+W ({} + {} > {})",
                self.budget,
                w,
                self.cache
            );
            anyhow::ensure!(w < self.budget, "window W must be < budget B (B >> W)");
        }
        if let Some(p) = &self.pool {
            p.validate()?;
            // One row alone must always be able to reach physical capacity,
            // otherwise a solo sequence could preempt itself forever.
            anyhow::ensure!(
                p.n_blocks * p.block_size >= self.cache,
                "pool too small: {} blocks x {} tokens < cache capacity {}",
                p.n_blocks,
                p.block_size,
                self.cache
            );
            if let Some(pc) = &self.prefix_cache {
                anyhow::ensure!(
                    pc.max_entries >= 1,
                    "prefix cache needs max_entries >= 1 (use None to disable)"
                );
            }
        }
        Ok(())
    }
}

/// One generation request. `template` chars are forced as inputs after the
/// prompt; `?` marks holes the model must fill (the E2E accuracy protocol —
/// long teacher-forced reasoning chains with measurable answer slots).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub template: String,
    pub max_new: usize,
}

/// Why a row finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopChar,
    TemplateDone,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopChar => "stop_char",
            FinishReason::TemplateDone => "template_done",
        }
    }
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Everything after the prompt (forced + generated chars).
    pub text: String,
    /// Model predictions at template holes, in order.
    pub hole_predictions: Vec<char>,
    pub finish: FinishReason,
    pub metrics: RequestMetrics,
    /// Live-token count per decode step (memory accounting; empty unless
    /// EngineConfig.record_live).
    pub live_curve: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn lagged_needs_headroom() {
        let cfg = EngineConfig {
            cache: 100,
            budget: 90,
            policy: "lazy".into(),
            ..Default::default()
        };
        assert!(cfg.validate().is_err()); // 90 + 25 > 100
        let cfg2 = EngineConfig {
            cache: 100,
            budget: 90,
            policy: "tova".into(),
            ..Default::default()
        };
        cfg2.validate().unwrap(); // greedy policies need no headroom
    }

    #[test]
    fn window_must_be_under_budget() {
        let mut cfg = EngineConfig::default();
        cfg.params.window = cfg.budget;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn pool_must_cover_one_full_row() {
        let cfg = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 8, // 128 tokens < cache 256
                low_watermark: 2,
                high_watermark: 4,
            }),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        let cfg_ok = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 16,
                low_watermark: 2,
                high_watermark: 4,
            }),
            ..Default::default()
        };
        cfg_ok.validate().unwrap();
    }
}
