//! L3 coordinator: request/response types, engine configuration, and the
//! decode-loop engine that wires runtime ⇄ kvcache ⇄ eviction together.

pub mod actor;
pub mod engine;
pub mod row;

pub use actor::{
    spawn_engine_actor, ActorEvent, ActorHandle, EngineMsg, ReplicaSnapshot, ReplicaStatus,
};
pub use engine::Engine;

use std::sync::Arc;
use std::time::Instant;

use crate::eviction::PolicyParams;
use crate::kvcache::TokenRecord;
use crate::kvpool::{PoolConfig, PrefixCacheConfig};
use crate::kvtier::{HostTierConfig, ParkedBlocks, SwappedBlock};
use crate::metrics::RequestMetrics;

/// How a preempted row comes back (see `kvtier` for the swap machinery).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptMode {
    /// vLLM-style recompute: drop the blocks, re-prefill prompt + generated
    /// on resume (bounded by the prefill bucket — oversize streams restart).
    Recompute,
    /// Demote the row's whole block table to the host tier and resume by
    /// swapping the bytes back in — no re-prefill, no bucket cliff.
    /// Requires a pool and a host tier; falls back to recompute per-row
    /// when the tier cannot hold the table.
    Swap,
    /// Per-row cost model (`scheduler::preempt`): swap when moving the live
    /// set's bytes is cheaper than re-prefilling the fed stream.
    Auto,
}

impl Default for PreemptMode {
    fn default() -> Self {
        PreemptMode::Recompute
    }
}

impl PreemptMode {
    pub fn parse(s: &str) -> Option<PreemptMode> {
        Some(match s {
            "recompute" => PreemptMode::Recompute,
            "swap" => PreemptMode::Swap,
            "auto" => PreemptMode::Auto,
            _ => return None,
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            PreemptMode::Recompute => "recompute",
            PreemptMode::Swap => "swap",
            PreemptMode::Auto => "auto",
        }
    }
}

/// Engine configuration (one engine = one compiled (batch, cache) shape).
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Batch rows of the compiled executables.
    pub batch: usize,
    /// Physical slot capacity S of the device cache.
    pub cache: usize,
    /// KV budget B (paper's B; lagged policies additionally need headroom:
    /// capacity >= budget + window).
    pub budget: usize,
    /// Policy spec: `full`, `tova`, `h2o`, `raas`, `rkv`, `lazy`,
    /// `<base>+window` (see eviction::build).
    pub policy: String,
    pub params: PolicyParams,
    /// Importance threshold α for TS/MRI tracking.
    pub alpha: f32,
    /// Stop generation at this char (in addition to max_new). '\0' ⇒ none.
    pub stop_char: char,
    /// Collect layer-0 key sketches into records (needed by `rkv`).
    pub collect_sketches: bool,
    /// Record live-token counts each step (Fig. 6 memory curves).
    pub record_live: bool,
    /// Shared paged-KV block pool. `None` keeps the seed behavior (each row
    /// owns its full slot capacity); `Some` makes rows allocate blocks from
    /// a global budget, with pressure-driven admission and youngest-row
    /// preemption when it runs dry.
    pub pool: Option<PoolConfig>,
    /// Prompt-prefix block sharing across rows (paged mode only; ignored
    /// without `pool`). On by default: identical prompt headers fork whole
    /// blocks instead of re-allocating them. `None` disables sharing.
    pub prefix_cache: Option<PrefixCacheConfig>,
    /// Host-memory spill tier (requires `pool`). `None` keeps evictions
    /// destructive and preemption recompute-only; `Some` parks evicted
    /// blocks for recurrence-driven promotion and enables swap-mode
    /// preemption (see `kvtier`).
    pub host_tier: Option<HostTierConfig>,
    /// Preemption resume mode. `Swap` requires `host_tier`; `Auto` without
    /// a tier degenerates to recompute.
    pub preempt_mode: PreemptMode,
    /// Attach a recurrence observatory (`eviction::observatory`) recording
    /// per-pass eviction decisions, recurrence-interval histograms and
    /// time-to-promotion for parked tokens. Off by default — decode output
    /// is byte-identical either way; the observatory only *observes*.
    pub observe_recurrence: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            batch: 1,
            cache: 256,
            budget: 192,
            policy: "lazy".into(),
            params: PolicyParams::default(),
            alpha: 5e-4,
            stop_char: '\0',
            collect_sketches: false,
            record_live: true,
            pool: None,
            prefix_cache: Some(PrefixCacheConfig::default()),
            host_tier: None,
            preempt_mode: PreemptMode::Recompute,
            observe_recurrence: false,
        }
    }
}

impl EngineConfig {
    /// Validate budget/capacity/window interplay.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.budget >= 2, "budget too small");
        anyhow::ensure!(
            self.budget <= self.cache,
            "budget {} > cache capacity {}",
            self.budget,
            self.cache
        );
        let w = self.params.window;
        if self.policy == "lazy" || self.policy.ends_with("+window") {
            anyhow::ensure!(
                self.budget + w <= self.cache,
                "lagged policy needs capacity >= budget+W ({} + {} > {})",
                self.budget,
                w,
                self.cache
            );
            anyhow::ensure!(w < self.budget, "window W must be < budget B (B >> W)");
        }
        if let Some(p) = &self.pool {
            p.validate()?;
            // One row alone must always be able to reach physical capacity,
            // otherwise a solo sequence could preempt itself forever.
            anyhow::ensure!(
                p.n_blocks * p.block_size >= self.cache,
                "pool too small: {} blocks x {} tokens < cache capacity {}",
                p.n_blocks,
                p.block_size,
                self.cache
            );
            if let Some(pc) = &self.prefix_cache {
                anyhow::ensure!(
                    pc.max_entries >= 1,
                    "prefix cache needs max_entries >= 1 (use None to disable)"
                );
            }
        }
        if let Some(tc) = &self.host_tier {
            tc.validate()?;
            anyhow::ensure!(
                self.pool.is_some(),
                "host tier requires a block pool (set EngineConfig::pool)"
            );
        }
        if self.preempt_mode == PreemptMode::Swap {
            anyhow::ensure!(
                self.host_tier.is_some(),
                "preempt mode 'swap' requires a host tier (--host-tier-bytes)"
            );
        }
        Ok(())
    }
}

/// One generation request. `template` chars are forced as inputs after the
/// prompt; `?` marks holes the model must fill (the E2E accuracy protocol —
/// long teacher-forced reasoning chains with measurable answer slots).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub template: String,
    pub max_new: usize,
    /// Recompute-mode resume state, present iff this request was preempted
    /// mid-decode. The engine attaches it in `preempt_row`, it rides the
    /// engine → server → queue → engine round trip unchanged, and the next
    /// `Engine::submit` consumes it to *resume* the row (one batched
    /// re-prefill of prompt + generated tokens, tracker records restored
    /// verbatim) instead of restarting from the prompt. Always `None` for
    /// fresh requests. `Arc` because admission under pressure retries:
    /// every declined attempt clones the request, and the snapshot (live
    /// records, sketches, generated text) must not be deep-copied per poll.
    pub resume: Option<Arc<PreemptedState>>,
}

/// Full decode-state snapshot of a preempted row — everything a resumed row
/// needs to continue byte-identically to a never-preempted run. The K/V
/// bytes themselves are NOT snapshotted: they are deterministic functions of
/// the fed-token stream, so resume recomputes them in one batched prefill of
/// prompt + generated tokens and rewrites only the rows the live keep-set
/// still references. The tracker records (TS/MRI/H1/H2 observation history)
/// are restored as-is, never re-initialized — a resumed row's lagged
/// eviction decisions therefore match a never-preempted run exactly.
#[derive(Clone, Debug)]
pub struct PreemptedState {
    /// Live tracker records at preemption (the post-eviction keep-set, in
    /// slot order). Restored verbatim on resume.
    pub records: Vec<TokenRecord>,
    /// Absolute position of the next input token.
    pub pos: u32,
    /// The token to feed at the next decode step.
    pub next_token: u32,
    /// Whether `next_token` was forced by the template.
    pub next_forced: bool,
    /// Chars of `req.template` already consumed.
    pub template_cursor: usize,
    /// Generated/forced chars emitted so far (every one except the last was
    /// already fed back as an input — the recompute stream is
    /// `prompt ++ out_text[..produced-1]`).
    pub out_text: String,
    /// Model predictions at `?` holes so far.
    pub hole_predictions: Vec<char>,
    /// Tokens produced so far.
    pub produced: usize,
    /// Set when the row finished in the same step it was preempted (it was
    /// another row's privatization victim) — nothing left to recompute.
    pub finish: Option<FinishReason>,
    /// Evictions charged to the row so far.
    pub evictions: usize,
    /// Live-count curve so far (continues across the round trip).
    pub live_curve: Vec<usize>,
    /// Queue wait accumulated before (each) earlier admission, seconds.
    /// The resumed admission adds the wait since `preempted_at`, so
    /// wait-latency metrics cover the request's full queued time.
    pub queued_s: f64,
    /// First-admission timestamp — preserved so `total_s` spans the
    /// request's real lifetime, preemptions included.
    pub admitted_at: Instant,
    /// First-token timestamp from the original admission (TTFT is a
    /// first-admission property; resume must not reset it).
    pub first_token_at: Option<Instant>,
    /// When the row was preempted; the re-queue wait is measured from here.
    pub preempted_at: Instant,
    /// Swap-mode preemption: the row's whole block table parked in the host
    /// tier, one pinned entry per block in table order. `None` means
    /// recompute-mode (the K/V is re-prefilled from the fed stream). The
    /// ids reference engine-owned tier state; resume consumes them.
    pub swapped: Option<Vec<SwappedBlock>>,
    /// The row's demotion ledger, carried across the round trip so parked
    /// tokens stay promotable after a resume (entries are unpinned and may
    /// be shed under tier pressure while the request is queued).
    pub parked: ParkedBlocks,
}

/// Why a row finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    MaxTokens,
    StopChar,
    TemplateDone,
}

impl FinishReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            FinishReason::MaxTokens => "max_tokens",
            FinishReason::StopChar => "stop_char",
            FinishReason::TemplateDone => "template_done",
        }
    }
}

/// One decoded token leaving the engine, in production order. The serve
/// loop drains these each iteration (`Engine::drain_token_events`) and
/// forwards them to streaming clients; concatenating `text` over a
/// request's events reproduces `Response::text` byte-identically (the
/// deltas are captured straight off `RowState::out_text`, so forced
/// template chars are included exactly as the final response includes
/// them).
#[derive(Clone, Debug)]
pub struct TokenEvent {
    /// Request id the token belongs to.
    pub req: u64,
    /// The chars appended to the row's output by this decode step.
    pub text: String,
    /// Tokens produced so far, including this one.
    pub produced: usize,
    /// True for the request's first produced token (client-visible TTFT).
    pub first: bool,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// Everything after the prompt (forced + generated chars).
    pub text: String,
    /// Model predictions at template holes, in order.
    pub hole_predictions: Vec<char>,
    pub finish: FinishReason,
    pub metrics: RequestMetrics,
    /// Live-token count per decode step (memory accounting; empty unless
    /// EngineConfig.record_live).
    pub live_curve: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_valid() {
        EngineConfig::default().validate().unwrap();
    }

    #[test]
    fn lagged_needs_headroom() {
        let cfg = EngineConfig {
            cache: 100,
            budget: 90,
            policy: "lazy".into(),
            ..Default::default()
        };
        assert!(cfg.validate().is_err()); // 90 + 25 > 100
        let cfg2 = EngineConfig {
            cache: 100,
            budget: 90,
            policy: "tova".into(),
            ..Default::default()
        };
        cfg2.validate().unwrap(); // greedy policies need no headroom
    }

    #[test]
    fn window_must_be_under_budget() {
        let mut cfg = EngineConfig::default();
        cfg.params.window = cfg.budget;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn tier_requires_pool_and_swap_requires_tier() {
        use crate::kvtier::HostTierConfig;
        let no_pool = EngineConfig {
            host_tier: Some(HostTierConfig::default()),
            ..Default::default()
        };
        assert!(no_pool.validate().is_err(), "tier without a pool");
        let swap_no_tier = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 16,
                low_watermark: 2,
                high_watermark: 4,
            }),
            preempt_mode: PreemptMode::Swap,
            ..Default::default()
        };
        assert!(swap_no_tier.validate().is_err(), "swap without a tier");
        let ok = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 16,
                low_watermark: 2,
                high_watermark: 4,
            }),
            host_tier: Some(HostTierConfig::default()),
            preempt_mode: PreemptMode::Swap,
            ..Default::default()
        };
        ok.validate().unwrap();
        // auto without a tier degenerates to recompute: valid
        let auto = EngineConfig {
            preempt_mode: PreemptMode::Auto,
            ..Default::default()
        };
        auto.validate().unwrap();
        assert_eq!(PreemptMode::parse("swap"), Some(PreemptMode::Swap));
        assert_eq!(PreemptMode::parse("bogus"), None);
        assert_eq!(PreemptMode::Auto.as_str(), "auto");
    }

    #[test]
    fn pool_must_cover_one_full_row() {
        let cfg = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 8, // 128 tokens < cache 256
                low_watermark: 2,
                high_watermark: 4,
            }),
            ..Default::default()
        };
        assert!(cfg.validate().is_err());
        let cfg_ok = EngineConfig {
            pool: Some(PoolConfig {
                block_size: 16,
                n_blocks: 16,
                low_watermark: 2,
                high_watermark: 4,
            }),
            ..Default::default()
        };
        cfg_ok.validate().unwrap();
    }
}
