//! Per-batch-row decode state: one in-flight request bound to a row of the
//! compiled executables, its slot records, template cursor, and timing.

use std::time::Instant;

use crate::coordinator::{FinishReason, PreemptedState, Request};
use crate::kvcache::SeqKv;
use crate::kvtier::ParkedBlocks;
use crate::telemetry::SpanContext;

#[derive(Debug)]
pub struct RowState {
    pub req: Request,
    pub seq: SeqKv,
    /// Absolute position of the *next* input token (== tokens processed).
    pub pos: u32,
    /// The token to feed at the next step.
    pub next_token: u32,
    /// Whether `next_token` was forced by the template (vs model-chosen).
    pub next_forced: bool,
    /// Byte cursor into req.template (chars consumed).
    pub template_cursor: usize,
    /// Generated/forced chars after the prompt.
    pub out_text: String,
    /// Model predictions at `?` holes.
    pub hole_predictions: Vec<char>,
    /// Tokens produced so far (decode steps done for this row).
    pub produced: usize,
    pub finish: Option<FinishReason>,
    pub admitted_at: Instant,
    pub first_token_at: Option<Instant>,
    pub queued_s: f64,
    pub evictions: usize,
    pub live_curve: Vec<usize>,
    /// Monotone admission ticket from the engine; the *highest* ticket is
    /// the youngest row — the preemption victim when the pool runs dry.
    pub admit_seq: u64,
    /// Whether this row's first decode step was already flight-recorded
    /// (one DECODE event per admission, not one per step).
    pub decode_logged: bool,
    /// Demotion ledger: this row's evicted-but-parked blocks in the host
    /// tier, awaiting recurrence-driven promotion (empty without a tier).
    pub parked: ParkedBlocks,
    /// The request's trace context (root-span link). Default = tracing off;
    /// the engine opens every row-scoped span (prefill, decode windows,
    /// eviction passes, demote/promote/swap) as a child of this.
    pub span: SpanContext,
    /// Open `decode_window` span id (0 = none open).
    pub decode_span: u64,
    /// Decode steps folded into the currently open window span.
    pub decode_span_steps: u32,
}

impl RowState {
    pub fn new(req: Request, capacity: usize, queued_s: f64) -> RowState {
        RowState {
            req,
            seq: SeqKv::new(capacity),
            pos: 0,
            next_token: 0,
            next_forced: false,
            template_cursor: 0,
            out_text: String::new(),
            hole_predictions: Vec::new(),
            produced: 0,
            finish: None,
            admitted_at: Instant::now(),
            first_token_at: None,
            queued_s,
            evictions: 0,
            live_curve: Vec::new(),
            admit_seq: 0,
            decode_logged: false,
            parked: ParkedBlocks::default(),
            span: SpanContext::default(),
            decode_span: 0,
            decode_span_steps: 0,
        }
    }

    /// Rebuild a row from a preemption snapshot (recompute-mode resume).
    /// Every decode-facing field — template cursor, outputs, position, the
    /// pending input token, and the original admission/first-token
    /// timestamps — continues exactly where the preempted row stopped. The
    /// sequence records are restored separately by the engine (they must go
    /// through the paged block-mapping path).
    pub fn resume(req: Request, capacity: usize, queued_s: f64, st: &PreemptedState) -> RowState {
        RowState {
            req,
            seq: SeqKv::new(capacity),
            pos: st.pos,
            next_token: st.next_token,
            next_forced: st.next_forced,
            template_cursor: st.template_cursor,
            out_text: st.out_text.clone(),
            hole_predictions: st.hole_predictions.clone(),
            produced: st.produced,
            finish: st.finish,
            admitted_at: st.admitted_at,
            first_token_at: st.first_token_at,
            queued_s,
            evictions: st.evictions,
            live_curve: st.live_curve.clone(),
            admit_seq: 0,
            decode_logged: false,
            parked: st.parked.clone(),
            span: SpanContext::default(),
            decode_span: 0,
            decode_span_steps: 0,
        }
    }

    /// Resolve what the model's prediction `pred` becomes as the next input
    /// token, honoring the template, and record outputs. Returns None when
    /// the row is finished.
    pub fn advance_with_prediction(
        &mut self,
        pred: char,
        stop_char: char,
    ) -> Option<char> {
        if self.first_token_at.is_none() {
            self.first_token_at = Some(Instant::now());
        }
        let tmpl: Vec<char> = self.req.template.chars().collect();
        let (next, forced) = if self.template_cursor < tmpl.len() {
            let t = tmpl[self.template_cursor];
            self.template_cursor += 1;
            if t == '?' {
                self.hole_predictions.push(pred);
                (pred, false)
            } else {
                (t, true)
            }
        } else if self.req.template.is_empty() {
            (pred, false)
        } else {
            self.finish = Some(FinishReason::TemplateDone);
            return None;
        };
        self.out_text.push(next);
        self.produced += 1;
        if !forced && stop_char != '\0' && next == stop_char {
            self.finish = Some(FinishReason::StopChar);
            return None;
        }
        if self.produced >= self.req.max_new {
            self.finish = Some(FinishReason::MaxTokens);
            return None;
        }
        self.next_forced = forced;
        Some(next)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(template: &str, max_new: usize) -> Request {
        Request {
            id: 1,
            prompt: "#A=3;\n>".into(),
            template: template.into(),
            max_new,
            resume: None,
        }
    }

    #[test]
    fn free_running_emits_predictions() {
        let mut r = RowState::new(req("", 3), 16, 0.0);
        assert_eq!(r.advance_with_prediction('x', '\0'), Some('x'));
        assert_eq!(r.advance_with_prediction('y', '\0'), Some('y'));
        assert_eq!(r.advance_with_prediction('z', '\0'), None); // max_new
        assert_eq!(r.finish, Some(FinishReason::MaxTokens));
        assert_eq!(r.out_text, "xyz");
        assert!(r.hole_predictions.is_empty());
    }

    #[test]
    fn template_forces_and_collects_holes() {
        let mut r = RowState::new(req("A+B=?;", 100), 16, 0.0);
        // model predictions are ignored on forced chars
        assert_eq!(r.advance_with_prediction('Q', '\0'), Some('A'));
        assert_eq!(r.advance_with_prediction('Q', '\0'), Some('+'));
        assert_eq!(r.advance_with_prediction('Q', '\0'), Some('B'));
        assert_eq!(r.advance_with_prediction('Q', '\0'), Some('='));
        // hole: model's char is used and recorded
        assert_eq!(r.advance_with_prediction('7', '\0'), Some('7'));
        assert_eq!(r.hole_predictions, vec!['7']);
        assert_eq!(r.advance_with_prediction('Q', '\0'), Some(';'));
        // template exhausted
        assert_eq!(r.advance_with_prediction('Q', '\0'), None);
        assert_eq!(r.finish, Some(FinishReason::TemplateDone));
        assert_eq!(r.out_text, "A+B=7;");
    }

    #[test]
    fn stop_char_only_on_model_tokens() {
        // forced newline must NOT stop; model-emitted newline must
        let mut r = RowState::new(req("\n?", 100), 16, 0.0);
        assert_eq!(r.advance_with_prediction('x', '\n'), Some('\n')); // forced
        assert_eq!(r.advance_with_prediction('\n', '\n'), None); // hole, stop
        assert_eq!(r.finish, Some(FinishReason::StopChar));
    }
}
