//! Tiered KV store: a host-memory spill tier under the device block pool.
//!
//! LazyEviction's core finding is *Token Importance Recurrence* — evicted
//! tokens frequently regain high attention many steps later — yet a plain
//! paged pool still **destroys** K/V bytes the moment a policy's keep-set
//! drops them, and preemption destroys a whole row's worth. This module adds
//! the second memory tier that turns both from lossy restarts into cheap
//! block moves:
//!
//! * **Demotion instead of destruction** — when an eviction pass drops rows,
//!   the engine parks the evicted rows' bytes in the [`HostTier`] (grouped
//!   by source block, at most one device block's worth per entry) instead of
//!   letting the compaction moves overwrite them. Each row carries a
//!   [`ParkedBlocks`] ledger mapping its parked token records to their tier
//!   entries.
//! * **Recurrence-driven promotion** — the lazy policy's observation records
//!   (TS/MRI) travel with each parked token. Every step the engine
//!   re-evaluates the parked records' importance scores (`eviction::score`);
//!   when one re-crosses the keep threshold — the weakest score the last
//!   eviction pass retained — its whole entry is swapped back in and spliced
//!   into the row's block table. The paper's recurrence phenomenon becomes a
//!   *served* behavior, measurable as the `promotions` /
//!   `false_evictions_avoided` gauges.
//! * **Swap-mode preemption** — instead of recompute-resume, `preempt_row`
//!   can demote the row's entire block table to the tier
//!   ([`SwappedBlock`] list in the preemption snapshot) and resume by
//!   swapping the bytes back in: no re-prefill, no prefill-bucket cliff.
//!   A per-row cost model (`scheduler::preempt`) picks swap vs recompute
//!   under `--preempt-mode auto`.
//!
//! ## Ownership & budget
//!
//! The tier is byte-budgeted and owned by the engine (one tier per engine,
//! `&mut`-threaded like the pool — no interior locking). Entries are
//! refcount-lite: **unpinned** entries (demotions) are a best-effort cache,
//! shed LRU-first when the budget overflows — losing one merely makes that
//! eviction permanent, which is the pre-tier behavior. **Pinned** entries
//! (swap-mode preemption state) are never shed; when the budget cannot hold
//! a row's table the preemption falls back to the recompute snapshot
//! instead, so a resume can never find its bytes missing.
//!
//! ## Ordering contract (extends the kvpool CoW/compaction contract)
//!
//! Demotion swap-outs read the evicted rows at their *pre-compaction* arena
//! locations, so they must run after the logical `apply_keep` but **before**
//! the compaction's `RowMove` list is applied to the backend (and before the
//! next pool allocation) — the moves are exactly what overwrites those
//! locations. Promotion swap-ins run like any other row write: after the
//! flush of any pending CoW copies for the slot being written.

pub mod ledger;
pub mod tier;

pub use ledger::{ParkedBlocks, ParkedEntry, SwappedBlock};
pub use tier::{HostTier, HostTierConfig, TierBlockId};
