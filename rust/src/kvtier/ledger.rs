//! Per-row bookkeeping for tier-resident state: which parked entries belong
//! to a row (demotions awaiting promotion), and which tier entries hold a
//! swap-preempted row's whole table.

use crate::kvcache::TokenRecord;

use super::tier::TierBlockId;

/// One demoted group: the evicted rows of one device block, parked together.
/// `records[j]` is the frozen observation record (TS/MRI/attention history)
/// of the token whose K/V occupies row `j` of the tier entry — exactly what
/// the promotion pass scores, and what gets spliced back verbatim on a
/// promotion (no tracker field is re-initialized).
#[derive(Clone, Debug)]
pub struct ParkedEntry {
    pub tier_id: TierBlockId,
    /// Row clock (`RowState::pos`) at the eviction pass that parked this
    /// entry; promotion never fires in the same pass that demoted.
    pub parked_at: u32,
    pub records: Vec<TokenRecord>,
}

/// A row's demotion ledger. Entries reference *unpinned* tier state, so a
/// lookup must tolerate ids the tier shed under byte pressure (the demotion
/// silently became a plain eviction — the pre-tier behavior). The ledger
/// travels with the row through preemption snapshots, so promotions remain
/// possible after a resume.
#[derive(Clone, Debug, Default)]
pub struct ParkedBlocks {
    pub entries: Vec<ParkedEntry>,
}

impl ParkedBlocks {
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Parked tokens across all entries.
    pub fn tokens(&self) -> usize {
        self.entries.iter().map(|e| e.records.len()).sum()
    }
}

/// Swap-mode preemption: one entry per block of the preempted row's table,
/// in table order. These tier entries are *pinned* (never shed), so a
/// resume can always find its bytes; if the tier cannot hold the whole
/// table at preemption time, the engine falls back to the recompute
/// snapshot instead of parking a partial table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SwappedBlock {
    pub tier_id: TierBlockId,
    /// Occupied rows in this block at preemption.
    pub rows: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_counts_tokens_across_entries() {
        let mut l = ParkedBlocks::default();
        assert!(l.is_empty());
        l.entries.push(ParkedEntry {
            tier_id: 1,
            parked_at: 10,
            records: vec![TokenRecord::new(3, 3), TokenRecord::new(5, 5)],
        });
        l.entries.push(ParkedEntry {
            tier_id: 2,
            parked_at: 12,
            records: vec![TokenRecord::new(9, 9)],
        });
        assert_eq!(l.len(), 2);
        assert_eq!(l.tokens(), 3);
    }
}
