//! The host-side arena: byte-budgeted, LRU + pin-refcounted storage for
//! parked K/V rows (see the module docs for the demotion/promotion/swap
//! lifecycle and the shedding rules).
//!
//! An entry holds up to one device block's worth of token-major K and V rows
//! (`[rows, L·H·dh]` each) — the tier mirrors the `kvpool::KvArena` geometry
//! without pinning a fixed `[n_blocks, ...]` slab, because parked entries
//! come and go at block granularity and the budget is the only hard bound.

/// Identity of one parked entry. Monotone per tier; never reused, so a stale
/// ledger reference can only *miss* (entry shed), never alias fresh bytes.
pub type TierBlockId = u64;

/// Host tier sizing.
#[derive(Clone, Debug)]
pub struct HostTierConfig {
    /// Byte budget for parked K+V rows (the only hard bound).
    pub max_bytes: usize,
}

impl Default for HostTierConfig {
    fn default() -> Self {
        HostTierConfig {
            max_bytes: 64 << 20,
        }
    }
}

impl HostTierConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_bytes >= 1, "host tier needs a byte budget");
        Ok(())
    }
}

#[derive(Debug)]
struct Entry {
    k: Vec<f32>,
    v: Vec<f32>,
    rows: usize,
    /// Pinned entries (swap-mode preemption state) are never LRU-shed.
    pinned: bool,
    last_used: u64,
}

impl Entry {
    fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }
}

/// Byte-budgeted host spill tier (module docs).
#[derive(Debug)]
pub struct HostTier {
    max_bytes: usize,
    entries: Vec<(TierBlockId, Entry)>,
    next_id: TierBlockId,
    clock: u64,
    bytes: usize,
    /// Unpinned entries destroyed to make room (the demotion became a plain
    /// eviction after all).
    pub shed_blocks: u64,
}

impl HostTier {
    pub fn new(max_bytes: usize) -> HostTier {
        HostTier {
            max_bytes,
            entries: Vec::new(),
            next_id: 0,
            clock: 0,
            bytes: 0,
            shed_blocks: 0,
        }
    }

    pub fn max_bytes(&self) -> usize {
        self.max_bytes
    }

    pub fn bytes_in_use(&self) -> usize {
        self.bytes
    }

    /// Live parked entries (block-granular).
    pub fn parked_blocks(&self) -> usize {
        self.entries.len()
    }

    pub fn contains(&self, id: TierBlockId) -> bool {
        self.entries.iter().any(|(i, _)| *i == id)
    }

    /// Park one block's worth of token-major K/V rows. Sheds unpinned
    /// entries LRU-first until the budget covers the newcomer; returns
    /// `None` (bytes dropped, caller's eviction stays destructive / caller
    /// falls back to recompute) when pinned entries alone overflow it.
    pub fn park(
        &mut self,
        k: Vec<f32>,
        v: Vec<f32>,
        rows: usize,
        pinned: bool,
    ) -> Option<TierBlockId> {
        debug_assert_eq!(k.len(), v.len(), "K/V row payloads must match");
        debug_assert!(rows >= 1, "parking an empty entry");
        let need = (k.len() + v.len()) * std::mem::size_of::<f32>();
        if need > self.max_bytes {
            return None;
        }
        while self.bytes + need > self.max_bytes {
            if !self.shed_lru_unpinned() {
                return None;
            }
        }
        self.clock += 1;
        let id = self.next_id;
        self.next_id += 1;
        self.bytes += need;
        self.entries.push((
            id,
            Entry {
                k,
                v,
                rows,
                pinned,
                last_used: self.clock,
            },
        ));
        Some(id)
    }

    /// Remove and return an entry's bytes: `(k_rows, v_rows, rows)`.
    pub fn take(&mut self, id: TierBlockId) -> Option<(Vec<f32>, Vec<f32>, usize)> {
        let at = self.entries.iter().position(|(i, _)| *i == id)?;
        let (_, e) = self.entries.swap_remove(at);
        self.bytes -= e.bytes();
        Some((e.k, e.v, e.rows))
    }

    /// Drop an entry without reading it (row finished/aborted, snapshot
    /// fell back). Missing ids are fine — unpinned entries may have been
    /// shed under pressure already.
    pub fn release(&mut self, id: TierBlockId) -> bool {
        self.take(id).is_some()
    }

    /// Bump an entry's recency (a promotion probe found it relevant).
    pub fn touch(&mut self, id: TierBlockId) {
        self.clock += 1;
        let clock = self.clock;
        if let Some((_, e)) = self.entries.iter_mut().find(|(i, _)| *i == id) {
            e.last_used = clock;
        }
    }

    /// Per-entry (id, rows, pinned, bytes) — the tier's side of the runtime
    /// invariant audit ([`crate::kvpool::audit`]): byte-budget conservation
    /// (entry bytes must sum to [`bytes_in_use`](Self::bytes_in_use)) and
    /// pinned-entry accounting (every pin reference must resolve here).
    pub fn entries_for_audit(&self) -> Vec<(TierBlockId, usize, bool, usize)> {
        self.entries
            .iter()
            .map(|(id, e)| (*id, e.rows, e.pinned, e.bytes()))
            .collect()
    }

    fn shed_lru_unpinned(&mut self) -> bool {
        let at = self
            .entries
            .iter()
            .enumerate()
            .filter(|(_, (_, e))| !e.pinned)
            .min_by_key(|(_, (_, e))| e.last_used)
            .map(|(at, _)| at);
        let Some(at) = at else { return false };
        let (_, e) = self.entries.swap_remove(at);
        self.bytes -= e.bytes();
        self.shed_blocks += 1;
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(n: usize, x: f32) -> Vec<f32> {
        vec![x; n * 4] // 4 elems per row
    }

    #[test]
    fn park_take_round_trip() {
        let mut t = HostTier::new(1 << 20);
        let k = rows(3, 1.5);
        let v = rows(3, -2.5);
        let id = t.park(k.clone(), v.clone(), 3, false).unwrap();
        assert!(t.contains(id));
        assert_eq!(t.parked_blocks(), 1);
        assert_eq!(t.bytes_in_use(), 2 * 3 * 4 * 4);
        let (k2, v2, n) = t.take(id).unwrap();
        assert_eq!(k2, k);
        assert_eq!(v2, v);
        assert_eq!(n, 3);
        assert_eq!(t.bytes_in_use(), 0);
        assert!(!t.contains(id));
        assert!(t.take(id).is_none());
    }

    #[test]
    fn ids_are_never_reused() {
        let mut t = HostTier::new(1 << 20);
        let a = t.park(rows(1, 0.0), rows(1, 0.0), 1, false).unwrap();
        t.release(a);
        let b = t.park(rows(1, 0.0), rows(1, 0.0), 1, false).unwrap();
        assert_ne!(a, b, "stale ledger refs must miss, never alias");
    }

    #[test]
    fn budget_sheds_lru_unpinned() {
        // each entry: 2 * 2 rows * 4 elems * 4 bytes = 64 bytes; budget = 2
        let mut t = HostTier::new(128);
        let a = t.park(rows(2, 1.0), rows(2, 1.0), 2, false).unwrap();
        let b = t.park(rows(2, 2.0), rows(2, 2.0), 2, false).unwrap();
        t.touch(a); // b is now LRU
        let c = t.park(rows(2, 3.0), rows(2, 3.0), 2, false).unwrap();
        assert_eq!(t.parked_blocks(), 2);
        assert_eq!(t.shed_blocks, 1);
        assert!(t.contains(a) && t.contains(c));
        assert!(!t.contains(b), "LRU entry must go first");
        assert_eq!(t.bytes_in_use(), 128);
    }

    #[test]
    fn pinned_entries_never_shed_and_can_refuse() {
        let mut t = HostTier::new(128);
        let a = t.park(rows(2, 1.0), rows(2, 1.0), 2, true).unwrap();
        let b = t.park(rows(2, 2.0), rows(2, 2.0), 2, true).unwrap();
        // budget full of pinned state: a third park must be refused, with
        // both pinned entries intact (a resume can never lose its bytes)
        assert!(t.park(rows(2, 3.0), rows(2, 3.0), 2, false).is_none());
        assert!(t.contains(a) && t.contains(b));
        assert_eq!(t.shed_blocks, 0);
        // releasing one pinned entry reopens the budget
        assert!(t.release(a));
        assert!(t.park(rows(2, 3.0), rows(2, 3.0), 2, false).is_some());
    }

    #[test]
    fn oversized_entry_is_refused_outright() {
        let mut t = HostTier::new(16);
        assert!(t.park(rows(2, 0.0), rows(2, 0.0), 2, false).is_none());
        assert_eq!(t.bytes_in_use(), 0);
    }

    #[test]
    fn config_validation() {
        assert!(HostTierConfig::default().validate().is_ok());
        assert!(HostTierConfig { max_bytes: 0 }.validate().is_err());
    }
}
