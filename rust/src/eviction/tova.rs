//! TOVA (Oren et al. 2024): per-step greedy eviction by *current* attention
//! — the paper's representative of Current-Attention-based Eviction
//! (Fig. 1a), which forgets recurring tokens in their low-attention phase.

use super::{top_k_by, Policy};
use crate::kvcache::TokenRecord;

pub struct Tova;

impl Policy for Tova {
    fn name(&self) -> String {
        "tova".into()
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, _step: u32) -> Vec<u32> {
        let exclude = vec![false; records.len()];
        top_k_by(records, &exclude, budget, |r| r.last_attn as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_highest_current_attention() {
        let mut rs: Vec<TokenRecord> =
            (0..6).map(|i| TokenRecord::new(i, i)).collect();
        for (i, a) in [0.1, 0.9, 0.05, 0.8, 0.2, 0.3].iter().enumerate() {
            rs[i].last_attn = *a;
        }
        let p = Tova;
        let keep = p.select_keep(&rs, 3, 10);
        let mut pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        pos.sort_unstable();
        assert_eq!(pos, vec![1, 3, 5]);
    }

    #[test]
    fn forgets_low_attention_recurring_token() {
        // the failure mode the paper illustrates: a token currently quiet
        // is dropped even if it was important before
        let mut rs: Vec<TokenRecord> = (0..3).map(|i| TokenRecord::new(i, i)).collect();
        rs[0].cum_attn = 100.0; // historically dominant…
        rs[0].last_attn = 0.0; // …but quiet now
        rs[1].last_attn = 0.5;
        rs[2].last_attn = 0.4;
        let keep = Tova.select_keep(&rs, 2, 10);
        assert!(!keep.contains(&0));
    }

    #[test]
    fn evicts_only_over_budget() {
        let p = Tova;
        assert!(!p.should_evict(5, 5, 1));
        assert!(p.should_evict(6, 5, 1));
    }
}
