//! RaaS (Hu et al. 2025): timestamp-based eviction for long decoding —
//! tokens with the *newest* "important" timestamps are retained; a token
//! whose timestamp goes stale is evicted. LazyEviction adopts RaaS's
//! timestamp rule (attention >= alpha ⇒ TS := t) but adds MRI on top;
//! RaaS itself cannot distinguish a dead token from one mid-recurrence.

use super::{top_k_by, Policy};
use crate::kvcache::TokenRecord;

pub struct Raas;

impl Policy for Raas {
    fn name(&self) -> String {
        "raas".into()
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, _step: u32) -> Vec<u32> {
        let exclude = vec![false; records.len()];
        top_k_by(records, &exclude, budget, |r| r.ts as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn newest_timestamps_survive() {
        let mut rs: Vec<TokenRecord> = (0..5).map(|i| TokenRecord::new(i, i)).collect();
        rs[0].ts = 50; // reactivated recently
        rs[1].ts = 1;
        rs[2].ts = 40;
        rs[3].ts = 3;
        rs[4].ts = 4;
        let keep = Raas.select_keep(&rs, 3, 60);
        let mut pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        pos.sort_unstable();
        assert_eq!(pos, vec![0, 2, 4]);
    }

    #[test]
    fn stale_recurring_token_is_lost() {
        // the gap LazyEviction fixes: token 0 recurs every 30 steps but its
        // TS is stale right before the next spike → RaaS evicts it
        let mut rs: Vec<TokenRecord> = (0..3).map(|i| TokenRecord::new(i, i)).collect();
        rs[0].ts = 10;
        rs[0].mri = 30; // would recur around step 40
        rs[1].ts = 35;
        rs[2].ts = 36;
        let keep = Raas.select_keep(&rs, 2, 39);
        assert!(!keep.contains(&0));
    }
}
