//! H2O (Zhang et al. 2023): heavy-hitter oracle — keep the tokens with the
//! highest *cumulative* attention plus a recent window. The paper's
//! representative of Cumulative-Attention-based Eviction (Fig. 1b): latent
//! recurring tokens with long quiet phases still starve on cumulative score.

use super::{keep_with_pinned, recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct H2O {
    /// Recent tokens always retained (paper sets this = LazyEviction's W).
    pub recent: usize,
}

impl Policy for H2O {
    fn name(&self) -> String {
        format!("h2o(recent={})", self.recent)
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, _step: u32) -> Vec<u32> {
        let pinned = recent_slots(records, self.recent.min(budget));
        keep_with_pinned(records, pinned, budget, |r| r.cum_attn as f64)
    }

    fn step_cost(&self, live: usize, budget: usize, _step: u32) -> (u64, u64) {
        // score accumulation is O(B) every step; ranking when over budget
        let rank = if live > budget {
            super::ranking_cost(live)
        } else {
            0
        };
        (live as u64, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs_with_cum(cums: &[f32]) -> Vec<TokenRecord> {
        cums.iter()
            .enumerate()
            .map(|(i, &c)| {
                let mut r = TokenRecord::new(i as u32, i as u32);
                r.cum_attn = c;
                r
            })
            .collect()
    }

    #[test]
    fn keeps_heavy_hitters_and_recent() {
        let rs = recs_with_cum(&[5.0, 0.1, 4.0, 0.1, 0.1, 0.1]);
        let p = H2O { recent: 2 };
        let keep = p.select_keep(&rs, 4, 10);
        let mut pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        pos.sort_unstable();
        // recent {4,5} + heavy {0,2}
        assert_eq!(pos, vec![0, 2, 4, 5]);
    }

    #[test]
    fn recent_window_never_dropped() {
        let rs = recs_with_cum(&[9.0, 9.0, 9.0, 0.0, 0.0]);
        let p = H2O { recent: 2 };
        let keep = p.select_keep(&rs, 3, 10);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(pos.contains(&4) && pos.contains(&3));
    }

    #[test]
    fn exact_budget() {
        let rs = recs_with_cum(&[1.0; 20]);
        let keep = H2O { recent: 5 }.select_keep(&rs, 8, 10);
        assert_eq!(keep.len(), 8);
    }
}
