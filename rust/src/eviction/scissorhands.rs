//! Scissorhands (Liu et al. 2023): persistence-of-importance — keep tokens
//! that were important in a large fraction of their lifetime, plus recents.

use super::{keep_with_pinned, recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct Scissorhands {
    pub recent: usize,
}

impl Scissorhands {
    /// Persistence ratio: hits / age (tokens important in many of their
    /// steps persist). Brand-new tokens get 1.0 (not instantly evictable).
    fn persistence(r: &TokenRecord, step: u32) -> f64 {
        let age = step.saturating_sub(r.born);
        if age == 0 {
            1.0
        } else {
            r.hits as f64 / age as f64
        }
    }
}

impl Policy for Scissorhands {
    fn name(&self) -> String {
        format!("scissorhands(recent={})", self.recent)
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, step: u32) -> Vec<u32> {
        let pinned = recent_slots(records, self.recent.min(budget));
        keep_with_pinned(records, pinned, budget, |r| Self::persistence(r, step))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn persistent_tokens_survive() {
        let mut rs: Vec<TokenRecord> = (0..6).map(|i| TokenRecord::new(i, i)).collect();
        rs[1].hits = 9; // almost always important
        rs[2].hits = 1;
        let p = Scissorhands { recent: 1 };
        let keep = p.select_keep(&rs, 3, 10);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(pos.contains(&1));
        assert!(pos.contains(&5)); // recent
    }

    #[test]
    fn new_token_not_instantly_evicted() {
        let r = TokenRecord::new(10, 10);
        assert_eq!(Scissorhands::persistence(&r, 10), 1.0);
    }

    #[test]
    fn persistence_normalizes_by_age() {
        let mut old = TokenRecord::new(0, 0);
        old.hits = 5;
        let mut young = TokenRecord::new(90, 90);
        young.hits = 5;
        // same hits, younger → higher ratio
        assert!(
            Scissorhands::persistence(&young, 100) > Scissorhands::persistence(&old, 100)
        );
    }
}
