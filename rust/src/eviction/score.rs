//! MRI-centric importance score (paper §4 Eq. 2 + Appendix D score forms).
//!
//! H1 reflects the chance a token regains importance within the next window:
//! larger Δt/MRI ⇒ less likely. H2 prioritizes *frequently* recurring tokens
//! (small MRI). Appendix D sweeps five monotone-decreasing forms mapped into
//! [0, 1]; sigmoid is the paper's default.
//!
//! Note on the printed H2: the paper writes H2 = 2σ(−1/(MRI−1)), which
//! *increases* with MRI (0 at MRI=1, →1 as MRI→∞) while the prose says
//! smaller MRI ⇒ more important. The formula — not the prose — is the one
//! that works: tokens picking up incidental *local* attention acquire tiny
//! MRIs (1–4) and would be rewarded forever by a decreasing H2, crowding out
//! genuinely recurring tokens whose MRI equals their recurrence period.
//! We therefore default to the literal formula and keep the prose-faithful
//! monotone-decreasing variant as `H2Mode::Monotonic` for the Table-5
//! extension ablation (benches/table5.rs, DESIGN.md §5).

use crate::kvcache::TokenRecord;

/// Monotone-decreasing squashing g: [0, ∞) → [0, 1], g(0) = 1 (App. D).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScoreForm {
    /// 2σ(−x)
    Sigmoid,
    /// exp(−x)
    Exp,
    /// 1 − tanh(x)
    Tanh,
    /// 1 / (1 + ln(1 + x))
    Log,
    /// 1 / (1 + x)
    Inverse,
}

impl ScoreForm {
    pub fn parse(s: &str) -> Option<ScoreForm> {
        Some(match s {
            "sigmoid" => ScoreForm::Sigmoid,
            "exp" => ScoreForm::Exp,
            "tanh" => ScoreForm::Tanh,
            "log" => ScoreForm::Log,
            "inverse" => ScoreForm::Inverse,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScoreForm::Sigmoid => "sigmoid",
            ScoreForm::Exp => "exp",
            ScoreForm::Tanh => "tanh",
            ScoreForm::Log => "log",
            ScoreForm::Inverse => "inverse",
        }
    }

    /// Evaluate g(x) for x >= 0.
    #[inline]
    pub fn eval(&self, x: f64) -> f64 {
        match self {
            ScoreForm::Sigmoid => 2.0 / (1.0 + x.exp()),
            ScoreForm::Exp => (-x).exp(),
            ScoreForm::Tanh => 1.0 - x.tanh(),
            ScoreForm::Log => 1.0 / (1.0 + (1.0 + x).ln()),
            ScoreForm::Inverse => 1.0 / (1.0 + x),
        }
    }
}

/// H2 interpretation (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum H2Mode {
    /// g(1/(MRI−1)) — the paper's printed formula (default; 0 at MRI<=1).
    Literal,
    /// g((MRI − 1)/κ): decreasing in MRI — the heuristic as *worded*
    /// (rewards small-MRI tokens; measurably worse, see table5).
    Monotonic,
}

#[derive(Clone, Copy, Debug)]
pub struct ScoreConfig {
    pub h1_form: ScoreForm,
    pub h2_form: ScoreForm,
    pub h2_mode: H2Mode,
    /// κ in the monotonic H2 (dynamic-range knob).
    pub h2_kappa: f64,
    pub use_h1: bool,
    pub use_h2: bool,
}

impl Default for ScoreConfig {
    fn default() -> Self {
        ScoreConfig {
            h1_form: ScoreForm::Sigmoid,
            h2_form: ScoreForm::Sigmoid,
            h2_mode: H2Mode::Literal,
            h2_kappa: 8.0,
            use_h1: true,
            use_h2: true,
        }
    }
}

/// H1-score: g(Δt / MRI). For MRI = 0 (never reactivated) the ratio is +∞
/// for Δt > 0 (score → 0) and we define Δt = 0 ⇒ 1 (just-created tokens are
/// not instantly evictable).
#[inline]
pub fn h1(rec: &TokenRecord, step: u32, cfg: &ScoreConfig) -> f64 {
    let dt = step.saturating_sub(rec.ts) as f64;
    if rec.mri == 0 {
        return if dt == 0.0 { 1.0 } else { 0.0 };
    }
    cfg.h1_form.eval(dt / rec.mri as f64)
}

/// H2-score: 0 for MRI = 0 (paper); otherwise per `h2_mode`. The literal
/// mode generalizes 2σ(−1/(MRI−1)) to the Table-5 form family as
/// g(1/(MRI−1)).
#[inline]
pub fn h2(rec: &TokenRecord, cfg: &ScoreConfig) -> f64 {
    if rec.mri == 0 {
        return 0.0;
    }
    match cfg.h2_mode {
        H2Mode::Monotonic => cfg.h2_form.eval((rec.mri as f64 - 1.0) / cfg.h2_kappa),
        H2Mode::Literal => {
            let m = rec.mri as f64;
            if m <= 1.0 {
                0.0
            } else {
                cfg.h2_form.eval(1.0 / (m - 1.0))
            }
        }
    }
}

/// Eq. 2: I_t[i] = H1 + H2 (H1 alone when MRI = 0). The `use_*` switches
/// drive the Table-4 ablation.
#[inline]
pub fn importance(rec: &TokenRecord, step: u32, cfg: &ScoreConfig) -> f64 {
    let mut s = 0.0;
    if cfg.use_h1 {
        s += h1(rec, step, cfg);
    }
    if cfg.use_h2 && rec.mri != 0 {
        s += h2(rec, cfg);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::TokenRecord;

    fn rec(ts: u32, mri: u32) -> TokenRecord {
        let mut r = TokenRecord::new(0, 0);
        r.ts = ts;
        r.mri = mri;
        r
    }

    #[test]
    fn forms_decreasing_and_bounded() {
        for f in [
            ScoreForm::Sigmoid,
            ScoreForm::Exp,
            ScoreForm::Tanh,
            ScoreForm::Log,
            ScoreForm::Inverse,
        ] {
            assert!((f.eval(0.0) - 1.0).abs() < 1e-12, "{f:?} g(0) != 1");
            let mut prev = f.eval(0.0);
            for i in 1..50 {
                let x = i as f64 * 0.5;
                let y = f.eval(x);
                assert!(y <= prev + 1e-12, "{f:?} not decreasing at {x}");
                assert!((0.0..=1.0).contains(&y));
                prev = y;
            }
        }
    }

    #[test]
    fn h1_larger_elapsed_smaller_score() {
        let cfg = ScoreConfig::default();
        let r = rec(10, 5);
        let near = h1(&r, 12, &cfg); // Δt=2, Δt/MRI=0.4
        let far = h1(&r, 40, &cfg); // Δt=30, Δt/MRI=6
        assert!(near > far);
    }

    #[test]
    fn h1_within_mri_stays_high() {
        // paper's H1 intuition: Δt < MRI ⇒ still plausible to recur
        let cfg = ScoreConfig::default();
        let r = rec(100, 50);
        assert!(h1(&r, 120, &cfg) > 0.5); // Δt/MRI = 0.4 ⇒ 2σ(-0.4) ≈ 0.8
    }

    #[test]
    fn h1_mri_zero_cases() {
        let cfg = ScoreConfig::default();
        let r = rec(7, 0);
        assert_eq!(h1(&r, 7, &cfg), 1.0);
        assert_eq!(h1(&r, 8, &cfg), 0.0);
    }

    #[test]
    fn h2_zero_when_never_activated() {
        let cfg = ScoreConfig::default();
        assert_eq!(h2(&rec(0, 0), &cfg), 0.0);
    }

    #[test]
    fn h2_literal_matches_printed_formula() {
        let cfg = ScoreConfig::default(); // literal is the default
        assert_eq!(h2(&rec(0, 1), &cfg), 0.0);
        let m2 = h2(&rec(0, 2), &cfg); // 2σ(-1) ≈ 0.538
        assert!((m2 - 2.0 / (1.0 + 1f64.exp())).abs() < 1e-12);
        assert!(h2(&rec(0, 50), &cfg) > m2); // increases with MRI
    }

    #[test]
    fn h2_monotonic_variant_prefers_small_mri() {
        let cfg = ScoreConfig {
            h2_mode: H2Mode::Monotonic,
            ..ScoreConfig::default()
        };
        assert!(h2(&rec(0, 1), &cfg) > h2(&rec(0, 10), &cfg));
        assert!(h2(&rec(0, 10), &cfg) > h2(&rec(0, 100), &cfg));
    }

    #[test]
    fn importance_eq2_composition() {
        let cfg = ScoreConfig::default();
        let active = rec(90, 10); // recently important, recurs often
        let stale = rec(10, 3); // long past its MRI
        let never = rec(0, 0);
        let step = 100;
        assert!(importance(&active, step, &cfg) > importance(&stale, step, &cfg));
        assert!(importance(&stale, step, &cfg) >= importance(&never, step, &cfg));
    }

    #[test]
    fn ablation_switches() {
        let r = rec(90, 10);
        let full = ScoreConfig::default();
        let no_h1 = ScoreConfig {
            use_h1: false,
            ..full
        };
        let no_h2 = ScoreConfig {
            use_h2: false,
            ..full
        };
        let i_full = importance(&r, 100, &full);
        assert!((importance(&r, 100, &no_h1) + importance(&r, 100, &no_h2) - i_full).abs() < 1e-12);
    }

    #[test]
    fn parse_forms() {
        assert_eq!(ScoreForm::parse("tanh"), Some(ScoreForm::Tanh));
        assert_eq!(ScoreForm::parse("nope"), None);
    }
}
