//! StreamingLLM (Xiao et al. 2023): static retention of attention-sink
//! (initial) tokens plus the most recent tokens. No attention needed —
//! the paper's example of a rigid policy that cannot see recurring tokens.

use super::{recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct StreamingLlm {
    /// Number of initial "sink" tokens pinned forever.
    pub sink: usize,
}

impl Policy for StreamingLlm {
    fn name(&self) -> String {
        format!("streaming(sink={})", self.sink)
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, _step: u32) -> Vec<u32> {
        let budget = budget.min(records.len());
        // sink = lowest positions
        let mut by_pos: Vec<u32> = (0..records.len() as u32).collect();
        by_pos.sort_unstable_by_key(|&i| records[i as usize].pos);
        let sink_n = self.sink.min(budget);
        let mut keep: Vec<u32> = by_pos[..sink_n].to_vec();
        let recent = recent_slots(records, budget - sink_n + sink_n); // oversample
        for slot in recent {
            if keep.len() >= budget {
                break;
            }
            if !keep.contains(&slot) {
                keep.push(slot);
            }
        }
        keep
    }

    fn step_cost(&self, live: usize, budget: usize, _step: u32) -> (u64, u64) {
        // no scoring; ranking = position sort when over budget
        if live > budget {
            (0, super::ranking_cost(live))
        } else {
            (0, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(n: usize) -> Vec<TokenRecord> {
        (0..n).map(|i| TokenRecord::new(i as u32, i as u32)).collect()
    }

    #[test]
    fn keeps_sink_and_recent() {
        let p = StreamingLlm { sink: 2 };
        let rs = recs(10);
        let keep = p.select_keep(&rs, 5, 10);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(pos.contains(&0) && pos.contains(&1), "sinks kept: {pos:?}");
        assert!(pos.contains(&9) && pos.contains(&8) && pos.contains(&7));
        assert_eq!(keep.len(), 5);
    }

    #[test]
    fn budget_one_keeps_one() {
        let p = StreamingLlm { sink: 4 };
        let rs = recs(10);
        assert_eq!(p.select_keep(&rs, 1, 10).len(), 1);
    }

    #[test]
    fn middle_tokens_evicted() {
        let p = StreamingLlm { sink: 1 };
        let rs = recs(100);
        let keep = p.select_keep(&rs, 10, 100);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(!pos.contains(&50));
    }
}
