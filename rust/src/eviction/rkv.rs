//! R-KV (Cai et al. 2025): redundancy-aware compression for reasoning —
//! rank by importance (cumulative attention) but suppress tokens that are
//! near-duplicates of already-kept ones. Strong on math traces (huge
//! redundancy), weak where similar tokens are rare (paper Table 2).
//!
//! Similarity source: key sketches (cosine) when the engine provides them,
//! else trace-provided `sim_group` ids (same group ⇒ similarity 1).

use super::{recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct RKv {
    pub recent: usize,
    /// Importance weight λ (1 ⇒ pure H2O-like, 0 ⇒ pure diversity).
    pub lambda: f64,
    /// Similarity above which a candidate is deferred as redundant.
    pub tau: f64,
}

fn cosine(a: &[f32], b: &[f32]) -> f64 {
    if a.is_empty() || a.len() != b.len() {
        return 0.0;
    }
    let (mut dot, mut na, mut nb) = (0f64, 0f64, 0f64);
    for (x, y) in a.iter().zip(b.iter()) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        0.0
    } else {
        dot / (na.sqrt() * nb.sqrt())
    }
}

fn similarity(a: &TokenRecord, b: &TokenRecord) -> f64 {
    if a.sim_group != u32::MAX && a.sim_group == b.sim_group {
        return 1.0;
    }
    cosine(&a.key_sketch, &b.key_sketch)
}

impl Policy for RKv {
    fn name(&self) -> String {
        format!("rkv(λ={},τ={})", self.lambda, self.tau)
    }

    fn should_evict(&self, live: usize, budget: usize, _step: u32) -> bool {
        live > budget
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, _step: u32) -> Vec<u32> {
        let budget = budget.min(records.len());
        let mut keep: Vec<u32> = Vec::with_capacity(budget);
        let mut taken = vec![false; records.len()];

        // recent window pinned first
        for slot in recent_slots(records, self.recent.min(budget)) {
            taken[slot as usize] = true;
            keep.push(slot);
        }

        // candidates by importance, greedy accept unless redundant
        let mut cand: Vec<u32> = (0..records.len() as u32)
            .filter(|&i| !taken[i as usize])
            .collect();
        cand.sort_unstable_by(|&a, &b| {
            let (ra, rb) = (&records[a as usize], &records[b as usize]);
            rb.cum_attn
                .partial_cmp(&ra.cum_attn)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(rb.pos.cmp(&ra.pos))
        });
        let mut deferred: Vec<u32> = Vec::new();
        for &c in &cand {
            if keep.len() >= budget {
                break;
            }
            let max_sim = keep
                .iter()
                .map(|&k| similarity(&records[c as usize], &records[k as usize]))
                .fold(0.0, f64::max);
            // score blends importance and novelty; redundant ⇒ defer
            if max_sim >= self.tau && self.lambda < 1.0 {
                deferred.push(c);
            } else {
                keep.push(c);
            }
        }
        // fill any remaining budget from deferred (still importance order)
        for &c in &deferred {
            if keep.len() >= budget {
                break;
            }
            keep.push(c);
        }
        keep
    }

    fn step_cost(&self, live: usize, budget: usize, _step: u32) -> (u64, u64) {
        if live > budget {
            // pairwise similarity dominates: O(B * kept)
            ((live * budget) as u64, super::ranking_cost(live))
        } else {
            (live as u64, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pos: u32, cum: f32, group: u32) -> TokenRecord {
        let mut r = TokenRecord::new(pos, pos);
        r.cum_attn = cum;
        r.sim_group = group;
        r
    }

    #[test]
    fn redundant_tokens_deferred() {
        // three high-importance tokens in the same group: only one kept
        // until budget forces more
        let rs = vec![
            rec(0, 10.0, 1),
            rec(1, 9.0, 1),
            rec(2, 8.0, 1),
            rec(3, 1.0, u32::MAX),
            rec(4, 0.5, u32::MAX),
        ];
        let p = RKv { recent: 1, lambda: 0.6, tau: 0.9 };
        let keep = p.select_keep(&rs, 3, 10);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        // recent(4) + best-of-group(0) + novel(3)
        assert!(pos.contains(&4) && pos.contains(&0) && pos.contains(&3), "{pos:?}");
    }

    #[test]
    fn fills_budget_from_deferred() {
        let rs = vec![rec(0, 5.0, 1), rec(1, 4.0, 1), rec(2, 3.0, 1)];
        let p = RKv { recent: 0, lambda: 0.6, tau: 0.9 };
        let keep = p.select_keep(&rs, 3, 10);
        assert_eq!(keep.len(), 3);
    }

    #[test]
    fn cosine_similarity_path() {
        let mut a = TokenRecord::new(0, 0);
        a.key_sketch = vec![1.0, 0.0];
        let mut b = TokenRecord::new(1, 1);
        b.key_sketch = vec![1.0, 0.001];
        assert!(similarity(&a, &b) > 0.99);
        let mut c = TokenRecord::new(2, 2);
        c.key_sketch = vec![0.0, 1.0];
        assert!(similarity(&a, &c) < 0.01);
    }

    #[test]
    fn no_sketch_no_group_means_novel() {
        let a = TokenRecord::new(0, 0);
        let b = TokenRecord::new(1, 1);
        assert_eq!(similarity(&a, &b), 0.0);
    }
}
