//! Recurrence observatory: records *why* each eviction decision was made.
//!
//! LazyEviction's whole bet is that Token Importance Recurrence is
//! observable — a token that mattered once will matter again within a
//! bounded interval (its MRI). The observatory instruments the eviction
//! pass so that bet can be audited after the fact:
//!
//! * **per-pass decision records** — for every pass: the keep threshold
//!   (minimum importance among kept tokens), the minimum importance among
//!   kept *non-recent* tokens (the same cut `promote_parked` uses as its
//!   promotion bar), and a per-token verdict (keep / evict / demote) with
//!   the token's TS, MRI and importance score at decision time;
//! * **recurrence-interval histogram** — the MRI distribution over every
//!   token the pass examined, i.e. what the policy actually saw;
//! * **time-to-promotion histogram** — for each parked token promoted back,
//!   how many steps it sat in the host tier first;
//! * **false-eviction postmortem counters** — promotions bucketed by parked
//!   duration: a promotion after 2 steps means the pass evicted a token the
//!   very next window proved it needed (an observably wrong call the tier
//!   absorbed), while a promotion after 500 steps is genuine long-range
//!   recurrence no greedy policy could have kept.
//!
//! The observatory is strictly *read-only over engine state*: it is handed
//! the same records and keep-set the pass computed and never influences
//! them, so `--observe-recurrence` on vs off produces byte-identical decode
//! output (asserted by an engine test and the pool bench). It is bounded:
//! a ring of [`RecurrenceObservatory::PASS_CAP`] pass records plus four
//! fixed-bucket histograms/counter families.

use std::collections::VecDeque;

use crate::eviction::recent_slots;
use crate::eviction::score::{importance, ScoreConfig};
use crate::kvcache::TokenRecord;
use crate::telemetry::StreamingHistogram;
use crate::util::json::Json;

/// What the pass decided for one token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Token stays in the device cache.
    Keep,
    /// Token dropped destructively (no host tier configured).
    Evict,
    /// Token evicted from the device but parked in the host tier.
    Demote,
}

impl Verdict {
    pub fn as_str(self) -> &'static str {
        match self {
            Verdict::Keep => "keep",
            Verdict::Evict => "evict",
            Verdict::Demote => "demote",
        }
    }
}

/// One token's decision inside a pass, with the signals the policy saw.
#[derive(Clone, Copy, Debug)]
pub struct PassDecision {
    /// Absolute token position.
    pub pos: u32,
    /// Last activation step (TS) at decision time.
    pub ts: u32,
    /// Maximal recurrence interval at decision time.
    pub mri: u32,
    /// Importance score I_t (Eq. 2) at decision time.
    pub score: f64,
    pub verdict: Verdict,
}

/// One eviction pass: the thresholds that shaped it plus every per-token
/// verdict.
#[derive(Clone, Debug)]
pub struct PassRecord {
    pub req: u64,
    pub step: u32,
    /// Minimum importance among *kept* tokens — the bar a token had to
    /// clear to stay (infinity when the pass kept nothing).
    pub keep_threshold: f64,
    /// Minimum importance among kept tokens *older than the recent window*
    /// — the same bar `promote_parked` holds parked tokens to, so
    /// comparing an evicted token's score against this predicts whether a
    /// later recurrence would win promotion.
    pub min_nonrecent: f64,
    pub decisions: Vec<PassDecision>,
}

/// Postmortem bucket upper bounds (parked steps); the last is open-ended.
pub const POSTMORTEM_BOUNDS: [u32; 3] = [8, 32, 128];
/// Label per postmortem bucket, aligned with [`POSTMORTEM_BOUNDS`] + the
/// open tail.
pub const POSTMORTEM_LABELS: [&str; 4] = ["le8", "le32", "le128", "gt128"];

/// Bounded recorder for eviction-pass decisions and recurrence outcomes.
#[derive(Debug)]
pub struct RecurrenceObservatory {
    /// Most recent pass records (ring, oldest dropped).
    passes: VecDeque<PassRecord>,
    /// Passes observed since creation (including ones pushed off the ring).
    pub passes_total: u64,
    /// Per-token verdicts observed since creation.
    pub decisions_total: u64,
    /// MRI distribution over every token an eviction pass examined.
    pub mri_hist: StreamingHistogram,
    /// Steps parked before promotion, per promoted token.
    pub promotion_hist: StreamingHistogram,
    /// Promotions by parked duration, [`POSTMORTEM_LABELS`] order.
    pub postmortem: [u64; 4],
}

impl Default for RecurrenceObservatory {
    fn default() -> Self {
        Self::new()
    }
}

impl RecurrenceObservatory {
    /// Pass records retained (each holds one decision per examined token,
    /// so the ring is the dominant memory cost — bound it tightly).
    pub const PASS_CAP: usize = 256;

    pub fn new() -> RecurrenceObservatory {
        RecurrenceObservatory {
            passes: VecDeque::new(),
            passes_total: 0,
            decisions_total: 0,
            mri_hist: StreamingHistogram::counts(),
            promotion_hist: StreamingHistogram::counts(),
            postmortem: [0; 4],
        }
    }

    /// Record one eviction pass. `records` and `keep` are exactly what the
    /// policy saw and returned; `tiered` says whether evicted tokens are
    /// parked (verdict demote) or destroyed (verdict evict). `window` is
    /// the recent-set size used for the non-recent threshold (the same
    /// `w.min(budget)` the lazy policy pins).
    pub fn observe_pass(
        &mut self,
        req: u64,
        step: u32,
        records: &[TokenRecord],
        keep: &[u32],
        tiered: bool,
        window: usize,
        score: &ScoreConfig,
    ) {
        let mut kept = vec![false; records.len()];
        for &k in keep {
            if let Some(slot) = kept.get_mut(k as usize) {
                *slot = true;
            }
        }
        let mut recent = vec![false; records.len()];
        for r in recent_slots(records, window.min(records.len())) {
            recent[r as usize] = true;
        }
        let mut keep_threshold = f64::INFINITY;
        let mut min_nonrecent = f64::INFINITY;
        let mut decisions = Vec::with_capacity(records.len());
        for (i, rec) in records.iter().enumerate() {
            let s = importance(rec, step, score);
            self.mri_hist.observe(rec.mri as f64);
            let verdict = if kept[i] {
                keep_threshold = keep_threshold.min(s);
                if !recent[i] {
                    min_nonrecent = min_nonrecent.min(s);
                }
                Verdict::Keep
            } else if tiered {
                Verdict::Demote
            } else {
                Verdict::Evict
            };
            decisions.push(PassDecision {
                pos: rec.pos,
                ts: rec.ts,
                mri: rec.mri,
                score: s,
                verdict,
            });
        }
        self.passes_total += 1;
        self.decisions_total += decisions.len() as u64;
        if self.passes.len() == Self::PASS_CAP {
            self.passes.pop_front();
        }
        self.passes.push_back(PassRecord {
            req,
            step,
            keep_threshold,
            min_nonrecent,
            decisions,
        });
    }

    /// Record one parked token winning promotion after `parked_steps` in
    /// the host tier.
    pub fn observe_promotion(&mut self, parked_steps: u32) {
        self.promotion_hist.observe(parked_steps as f64);
        let b = POSTMORTEM_BOUNDS
            .iter()
            .position(|&ub| parked_steps <= ub)
            .unwrap_or(POSTMORTEM_BOUNDS.len());
        self.postmortem[b] += 1;
    }

    /// Retained pass records, oldest first.
    pub fn passes(&self) -> impl Iterator<Item = &PassRecord> {
        self.passes.iter()
    }

    /// JSON summary (the shape the bench report's recurrence section and
    /// the `observe` wire command embed).
    pub fn to_json(&self) -> Json {
        let mut post = Json::obj();
        for (label, &n) in POSTMORTEM_LABELS.iter().zip(self.postmortem.iter()) {
            post = post.set(*label, n as f64);
        }
        Json::obj()
            .set("passes_total", self.passes_total as f64)
            .set("decisions_total", self.decisions_total as f64)
            .set("passes_retained", self.passes.len())
            .set("mri_n", self.mri_hist.n() as f64)
            .set("mri_p50", self.mri_hist.quantile(0.5))
            .set("mri_p99", self.mri_hist.quantile(0.99))
            .set("time_to_promotion_n", self.promotion_hist.n() as f64)
            .set("time_to_promotion_p50", self.promotion_hist.quantile(0.5))
            .set("time_to_promotion_max", self.promotion_hist.max())
            .set("false_eviction_postmortem", post)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(pos: u32, ts: u32, mri: u32) -> TokenRecord {
        let mut r = TokenRecord::new(pos, 0);
        r.ts = ts;
        r.mri = mri;
        r
    }

    fn cfg() -> ScoreConfig {
        ScoreConfig::default()
    }

    #[test]
    fn pass_records_verdicts_and_thresholds() {
        let mut obs = RecurrenceObservatory::new();
        let records = vec![rec(0, 90, 10), rec(1, 10, 3), rec(2, 99, 1), rec(3, 100, 0)];
        // keep slots 0 and 3; window=1 pins only the newest pos (3)
        obs.observe_pass(7, 100, &records, &[0, 3], false, 1, &cfg());
        assert_eq!(obs.passes_total, 1);
        assert_eq!(obs.decisions_total, 4);
        let p = obs.passes().next().unwrap();
        assert_eq!(p.req, 7);
        assert_eq!(p.step, 100);
        let verdicts: Vec<Verdict> = p.decisions.iter().map(|d| d.verdict).collect();
        assert_eq!(
            verdicts,
            vec![Verdict::Keep, Verdict::Evict, Verdict::Evict, Verdict::Keep]
        );
        // keep_threshold = min importance over kept {0, 3}; slot 0 is the
        // only kept non-recent token, so min_nonrecent is its score exactly
        let s0 = importance(&records[0], 100, &cfg());
        assert!(p.keep_threshold <= s0 + 1e-12);
        assert!((p.min_nonrecent - s0).abs() < 1e-12);
        // every examined token's MRI landed in the histogram
        assert_eq!(obs.mri_hist.n(), 4);
    }

    #[test]
    fn tiered_passes_mark_demote_not_evict() {
        let mut obs = RecurrenceObservatory::new();
        let records = vec![rec(0, 5, 2), rec(1, 6, 0)];
        obs.observe_pass(1, 10, &records, &[1], true, 1, &cfg());
        let p = obs.passes().next().unwrap();
        assert_eq!(p.decisions[0].verdict, Verdict::Demote);
        assert_eq!(p.decisions[1].verdict, Verdict::Keep);
        assert_eq!(p.decisions[0].verdict.as_str(), "demote");
    }

    #[test]
    fn promotion_buckets_split_by_parked_duration() {
        let mut obs = RecurrenceObservatory::new();
        for steps in [1, 8, 9, 32, 33, 128, 129, 5000] {
            obs.observe_promotion(steps);
        }
        assert_eq!(obs.postmortem, [2, 2, 2, 2]);
        assert_eq!(obs.promotion_hist.n(), 8);
        assert_eq!(obs.promotion_hist.max(), 5000.0);
    }

    #[test]
    fn pass_ring_is_bounded() {
        let mut obs = RecurrenceObservatory::new();
        let records = vec![rec(0, 1, 1)];
        for i in 0..(RecurrenceObservatory::PASS_CAP as u64 + 10) {
            obs.observe_pass(i, 2, &records, &[0], false, 1, &cfg());
        }
        assert_eq!(obs.passes().count(), RecurrenceObservatory::PASS_CAP);
        assert_eq!(
            obs.passes_total,
            RecurrenceObservatory::PASS_CAP as u64 + 10
        );
        // oldest dropped: the first retained pass is req 10
        assert_eq!(obs.passes().next().unwrap().req, 10);
    }

    #[test]
    fn json_summary_carries_all_sections() {
        let mut obs = RecurrenceObservatory::new();
        obs.observe_pass(1, 4, &[rec(0, 1, 2), rec(1, 2, 0)], &[1], true, 1, &cfg());
        obs.observe_promotion(3);
        let j = obs.to_json();
        assert_eq!(j.f64_at("passes_total").unwrap(), 1.0);
        assert_eq!(j.f64_at("decisions_total").unwrap(), 2.0);
        assert_eq!(j.f64_at("time_to_promotion_n").unwrap(), 1.0);
        let post = j.get("false_eviction_postmortem").unwrap();
        assert_eq!(post.f64_at("le8").unwrap(), 1.0);
        assert_eq!(post.f64_at("gt128").unwrap(), 0.0);
    }
}
