//! Observation-window wrapper (Table 3 ablation): give *any* baseline the
//! lagged-eviction mechanics — decisions every W steps, recent-W pinned —
//! while its own score ranks the rest. Isolates how much of LazyEviction's
//! gain comes from the window versus from the MRI-centric score.

use super::{recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct Windowed {
    pub inner: Box<dyn Policy>,
    pub window: usize,
}

impl Policy for Windowed {
    fn name(&self) -> String {
        format!("{}+window(W={})", self.inner.name(), self.window)
    }

    fn should_evict(&self, live: usize, budget: usize, step: u32) -> bool {
        live > budget && step as usize % self.window.max(1) == 0
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, step: u32) -> Vec<u32> {
        let budget = budget.min(records.len());
        let pinned = recent_slots(records, self.window.min(budget));
        let mut taken = vec![false; records.len()];
        let mut keep = Vec::with_capacity(budget);
        for &p in &pinned {
            taken[p as usize] = true;
            keep.push(p);
        }
        if keep.len() >= budget {
            keep.truncate(budget);
            return keep;
        }
        // let the inner policy rank everything, then take its picks that
        // are not already pinned until the budget is filled
        let inner_keep = self.inner.select_keep(records, records.len(), step);
        let inner_ranked = {
            // inner returns its keep-set in rank order; fall back to the
            // returned order
            inner_keep
        };
        for slot in inner_ranked {
            if keep.len() >= budget {
                break;
            }
            if !taken[slot as usize] {
                taken[slot as usize] = true;
                keep.push(slot);
            }
        }
        keep
    }

    fn step_cost(&self, live: usize, budget: usize, step: u32) -> (u64, u64) {
        if self.should_evict(live, budget, step) {
            let (s, r) = self.inner.step_cost(live, budget, step);
            (s.max(live as u64), r.max(super::ranking_cost(live)))
        } else {
            // between decisions only O(live) accumulation
            (live as u64, 0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{build, PolicyParams};
    use super::*;

    fn recs(n: usize) -> Vec<TokenRecord> {
        (0..n)
            .map(|i| {
                let mut r = TokenRecord::new(i as u32, i as u32);
                r.cum_attn = (n - i) as f32; // older = heavier
                r.last_attn = (n - i) as f32;
                r
            })
            .collect()
    }

    #[test]
    fn lagged_trigger() {
        let p = build("tova+window", &PolicyParams { window: 10, ..Default::default() }).unwrap();
        assert!(p.should_evict(100, 50, 20));
        assert!(!p.should_evict(100, 50, 21));
    }

    #[test]
    fn recent_w_pinned_even_if_inner_hates_them() {
        // inner=tova ranks by last_attn which is highest for OLD tokens here
        let p = Windowed {
            inner: Box::new(super::super::tova::Tova),
            window: 3,
        };
        let rs = recs(10);
        let keep = p.select_keep(&rs, 6, 30);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        for recent in [7, 8, 9] {
            assert!(pos.contains(&recent), "{pos:?}");
        }
        // and the inner policy fills the rest with its favorites (old ones)
        assert!(pos.contains(&0));
        assert_eq!(keep.len(), 6);
    }

    #[test]
    fn exact_budget_no_duplicates() {
        let p = Windowed {
            inner: Box::new(super::super::h2o::H2O { recent: 2 }),
            window: 4,
        };
        let rs = recs(20);
        let keep = p.select_keep(&rs, 9, 16);
        assert_eq!(keep.len(), 9);
        let mut sorted = keep.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 9);
    }
}
