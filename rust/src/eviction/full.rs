//! FullKV: the no-eviction upper bound (paper's accuracy reference).

use super::Policy;
use crate::kvcache::TokenRecord;

pub struct FullKv;

impl Policy for FullKv {
    fn name(&self) -> String {
        "full".into()
    }

    fn should_evict(&self, _live: usize, _budget: usize, _step: u32) -> bool {
        false
    }

    fn select_keep(&self, records: &[TokenRecord], _budget: usize, _step: u32) -> Vec<u32> {
        (0..records.len() as u32).collect()
    }

    fn step_cost(&self, _live: usize, _budget: usize, _step: u32) -> (u64, u64) {
        (0, 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn never_evicts() {
        let p = FullKv;
        assert!(!p.should_evict(10_000, 10, 5));
        let recs: Vec<TokenRecord> = (0..5).map(|i| TokenRecord::new(i, i)).collect();
        assert_eq!(p.select_keep(&recs, 2, 9).len(), 5);
    }
}
