//! LazyEviction (the paper's contribution, §4):
//!
//!   * lagged decisions — evictions run only at steps t = kW (Eq. 5 trigger),
//!     never per-step, so latent recurring tokens get an observation window
//!     in which their attention spike can be *seen* before they are judged;
//!   * the most recent W tokens are always retained (local coherence +
//!     the observation window itself);
//!   * the remaining B − W slots go to the tokens with the highest
//!     MRI-centric importance score I_t (Eq. 2; see eviction::score).

use super::score::{importance, ScoreConfig};
use super::{keep_with_pinned, recent_slots, Policy};
use crate::kvcache::TokenRecord;

pub struct LazyEviction {
    /// Observation window W (paper: the 80th-percentile MRI of the task,
    /// measured offline on 1% of samples — see trace::mri::suggest_window).
    pub window: usize,
    pub score: ScoreConfig,
}

impl Policy for LazyEviction {
    fn name(&self) -> String {
        let mut n = format!("lazy(W={}", self.window);
        if !self.score.use_h1 {
            n.push_str(",-H1");
        }
        if !self.score.use_h2 {
            n.push_str(",-H2");
        }
        n.push(')');
        n
    }

    fn should_evict(&self, live: usize, budget: usize, step: u32) -> bool {
        live > budget && step as usize % self.window.max(1) == 0
    }

    fn select_keep(&self, records: &[TokenRecord], budget: usize, step: u32) -> Vec<u32> {
        // Eq. 5: S' = Top_{B-W}(I_t) ∪ W_t
        let pinned = recent_slots(records, self.window.min(budget));
        keep_with_pinned(records, pinned, budget, |r| importance(r, step, &self.score))
    }

    fn step_cost(&self, live: usize, budget: usize, step: u32) -> (u64, u64) {
        // Tracking is O(B) every step (done by attention::observe);
        // scoring + one ranking only at decision steps: O(WB + BlogB)/window.
        let scoring = live as u64; // MRI/TS update per step
        let rank = if self.should_evict(live, budget, step) {
            super::ranking_cost(live)
        } else {
            0
        };
        (scoring, rank)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{observe, TrackerConfig};

    fn policy(w: usize) -> LazyEviction {
        LazyEviction {
            window: w,
            score: ScoreConfig::default(),
        }
    }

    #[test]
    fn evicts_only_on_window_boundary() {
        let p = policy(25);
        assert!(!p.should_evict(100, 50, 26));
        assert!(p.should_evict(100, 50, 50));
        assert!(!p.should_evict(40, 50, 50)); // under budget: never
    }

    #[test]
    fn recent_w_always_kept() {
        let p = policy(4);
        let rs: Vec<TokenRecord> = (0..20).map(|i| TokenRecord::new(i, i)).collect();
        let keep = p.select_keep(&rs, 8, 20);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        for recent in 16..20 {
            assert!(pos.contains(&recent), "recent {recent} missing: {pos:?}");
        }
        assert_eq!(keep.len(), 8);
    }

    #[test]
    fn recurring_token_survives_quiet_phase() {
        // Build a token that spikes every 20 steps (MRI 20) and is quiet
        // for 10 steps; greedy TOVA/RaaS would drop it, LazyEviction keeps
        // it because Δt < MRI keeps H1 high.
        let cfg = TrackerConfig { alpha: 0.1 };
        let mut rs: Vec<TokenRecord> = (0..30).map(|i| TokenRecord::new(i, i)).collect();
        // token 0 spikes at steps 30, 50, 70 (MRI becomes 30 then 20)
        for t in 30..=80 {
            let mut attn = vec![0.0f32; 30];
            if t % 20 == 10 {
                attn[0] = 0.9;
            }
            attn[29] = 0.9; // keep the tail alive
            observe(&mut rs, &attn, t, cfg);
        }
        // at step 80, token 0 last spiked at 70, Δt=10 < MRI=20
        let p = policy(5);
        let keep = p.select_keep(&rs, 10, 80);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(pos.contains(&0), "recurring token evicted: {pos:?}");
    }

    #[test]
    fn dead_token_evicted_after_mri_exceeded() {
        let cfg = TrackerConfig { alpha: 0.1 };
        let mut rs: Vec<TokenRecord> = (0..10).map(|i| TokenRecord::new(i, i)).collect();
        // token 0: one early spike (MRI small), then silence forever
        let mut attn = vec![0.0f32; 10];
        attn[0] = 0.9;
        observe(&mut rs, &attn, 12, cfg);
        for t in 13..100 {
            let mut a = vec![0.0f32; 10];
            a[5] = 0.9; // token 5 stays hot
            observe(&mut rs, &a, t, cfg);
        }
        let p = policy(2);
        // budget 3 = recent-2 + one scored slot: the hot token must win it
        let keep = p.select_keep(&rs, 3, 100);
        let pos: Vec<u32> = keep.iter().map(|&i| rs[i as usize].pos).collect();
        assert!(!pos.contains(&0), "dead token should go: {pos:?}");
        assert!(pos.contains(&5));
    }

    #[test]
    fn window_larger_than_budget_degrades_gracefully() {
        let p = policy(100);
        let rs: Vec<TokenRecord> = (0..50).map(|i| TokenRecord::new(i, i)).collect();
        let keep = p.select_keep(&rs, 10, 100);
        assert_eq!(keep.len(), 10);
    }

    #[test]
    fn step_cost_is_lagged() {
        let p = policy(25);
        let (s_on, r_on) = p.step_cost(100, 50, 50);
        let (s_off, r_off) = p.step_cost(100, 50, 51);
        assert_eq!(s_on, 100);
        assert!(r_on > 0);
        assert_eq!(s_off, 100);
        assert_eq!(r_off, 0);
    }
}
