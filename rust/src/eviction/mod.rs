//! KV eviction policies: the paper's LazyEviction plus every baseline it is
//! evaluated against (§5: FullKV, StreamingLLM, TOVA, H2O, Scissorhands,
//! RaaS, R-KV) and the observation-window wrapper of the Table-3 ablation.
//!
//! Policies are *stateless over the slot records* — every per-token signal
//! (ts, MRI, cumulative attention, hit counts, key sketches) lives in
//! `kvcache::TokenRecord`, so cache compaction reorders policy state
//! uniformly and the same `Policy` impls run in both the real engine and the
//! trace-driven simulator.

pub mod full;
pub mod h2o;
pub mod lazy;
pub mod observatory;
pub mod raas;
pub mod rkv;
pub mod scissorhands;
pub mod score;
pub mod streaming;
pub mod tova;
pub mod window;

use crate::kvcache::TokenRecord;

pub use observatory::RecurrenceObservatory;
pub use score::{H2Mode, ScoreConfig, ScoreForm};

/// An eviction policy decides *when* to evict and *which* slots to keep.
pub trait Policy: Send {
    fn name(&self) -> String;

    /// Run an eviction decision at this step? `live` is the current number
    /// of cached tokens. Greedy baselines trigger whenever live > budget;
    /// windowed policies only at step % W == 0 (the engine additionally
    /// forces eviction when the physical capacity is about to overflow).
    fn should_evict(&self, live: usize, budget: usize, step: u32) -> bool;

    /// Choose the keep-set: slot indices (any order) of size
    /// min(budget, records.len()).
    fn select_keep(&self, records: &[TokenRecord], budget: usize, step: u32) -> Vec<u32>;

    /// Per-step score work for the complexity accounting of Table 6:
    /// (score_ops, rank_ops) incurred *at this step* given `live` tokens.
    fn step_cost(&self, live: usize, budget: usize, _step: u32) -> (u64, u64) {
        // default: greedy per-step policy — score + rank every step when full
        if live > budget {
            (live as u64, ranking_cost(live))
        } else {
            (0, 0)
        }
    }
}

/// B log B comparison count for one ranking pass.
pub fn ranking_cost(n: usize) -> u64 {
    if n <= 1 {
        return 0;
    }
    (n as f64 * (n as f64).log2()).ceil() as u64
}

/// Slot indices of the `n` most recent tokens (by absolute position).
pub fn recent_slots(records: &[TokenRecord], n: usize) -> Vec<u32> {
    let mut idx: Vec<u32> = (0..records.len() as u32).collect();
    idx.sort_unstable_by_key(|&i| std::cmp::Reverse(records[i as usize].pos));
    idx.truncate(n);
    idx
}

/// Top-k slot indices by a score, descending, with a deterministic
/// tie-break (newer ts, then newer pos win). Uses partial selection —
/// O(n + k log k) — because this sits on the eviction hot path.
pub fn top_k_by<F: Fn(&TokenRecord) -> f64>(
    records: &[TokenRecord],
    exclude: &[bool],
    k: usize,
    score: F,
) -> Vec<u32> {
    debug_assert_eq!(exclude.len(), records.len());
    let mut scored: Vec<(f64, u32, u32, u32)> = records
        .iter()
        .enumerate()
        .filter(|(i, _)| !exclude[*i])
        .map(|(i, r)| (score(r), r.ts, r.pos, i as u32))
        .collect();
    let k = k.min(scored.len());
    if k == 0 {
        return Vec::new();
    }
    let cmp = |a: &(f64, u32, u32, u32), b: &(f64, u32, u32, u32)| {
        b.0.partial_cmp(&a.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(b.1.cmp(&a.1))
            .then(b.2.cmp(&a.2))
    };
    if k < scored.len() {
        scored.select_nth_unstable_by(k - 1, cmp);
        scored.truncate(k);
    }
    scored.sort_unstable_by(cmp);
    scored.into_iter().map(|(_, _, _, i)| i).collect()
}

/// Combine an always-keep set with a ranked fill to exactly `budget` slots.
pub fn keep_with_pinned<F: Fn(&TokenRecord) -> f64>(
    records: &[TokenRecord],
    pinned: Vec<u32>,
    budget: usize,
    score: F,
) -> Vec<u32> {
    let mut exclude = vec![false; records.len()];
    let mut keep: Vec<u32> = Vec::with_capacity(budget);
    for &p in pinned.iter().take(budget) {
        if !exclude[p as usize] {
            exclude[p as usize] = true;
            keep.push(p);
        }
    }
    let remaining = budget.saturating_sub(keep.len());
    keep.extend(top_k_by(records, &exclude, remaining, score));
    keep
}

/// Shared knobs for constructing policies from CLI/config strings.
#[derive(Clone, Debug)]
pub struct PolicyParams {
    /// Observation window W (LazyEviction and the +window wrapper).
    pub window: usize,
    /// Recent-token set size for H2O/Scissorhands/R-KV (paper sets = W).
    pub recent: usize,
    /// StreamingLLM sink size.
    pub sink: usize,
    /// R-KV importance/redundancy mix λ.
    pub rkv_lambda: f64,
    /// R-KV similarity threshold τ.
    pub rkv_tau: f64,
    /// LazyEviction score configuration.
    pub score: ScoreConfig,
}

impl Default for PolicyParams {
    fn default() -> Self {
        PolicyParams {
            window: 25,
            recent: 25,
            sink: 4,
            rkv_lambda: 0.6,
            rkv_tau: 0.9,
            score: ScoreConfig::default(),
        }
    }
}

/// Build a policy from its spec string: `full`, `streaming`, `tova`, `h2o`,
/// `scissorhands`, `raas`, `rkv`, `lazy`, or `<base>+window` (Table 3).
pub fn build(spec: &str, params: &PolicyParams) -> anyhow::Result<Box<dyn Policy>> {
    let (base, windowed) = match spec.strip_suffix("+window") {
        Some(b) => (b, true),
        None => (spec, false),
    };
    let inner: Box<dyn Policy> = match base {
        "full" => Box::new(full::FullKv),
        "streaming" => Box::new(streaming::StreamingLlm { sink: params.sink }),
        "tova" => Box::new(tova::Tova),
        "h2o" => Box::new(h2o::H2O {
            recent: params.recent,
        }),
        "scissorhands" => Box::new(scissorhands::Scissorhands {
            recent: params.recent,
        }),
        "raas" => Box::new(raas::Raas),
        "rkv" => Box::new(rkv::RKv {
            recent: params.recent,
            lambda: params.rkv_lambda,
            tau: params.rkv_tau,
        }),
        "lazy" => Box::new(lazy::LazyEviction {
            window: params.window,
            score: params.score,
        }),
        other => anyhow::bail!("unknown policy '{other}'"),
    };
    if windowed {
        anyhow::ensure!(base != "lazy" && base != "full", "+window on {base}");
        Ok(Box::new(window::Windowed {
            inner,
            window: params.window,
        }))
    } else {
        Ok(inner)
    }
}

/// All policy specs exercised by the paper's tables.
pub const PAPER_POLICIES: [&str; 6] = ["full", "raas", "h2o", "tova", "rkv", "lazy"];

#[cfg(test)]
mod tests {
    use super::*;

    fn recs(n: usize) -> Vec<TokenRecord> {
        (0..n)
            .map(|i| TokenRecord::new(i as u32, i as u32))
            .collect()
    }

    #[test]
    fn recent_slots_by_pos() {
        let mut rs = recs(5);
        rs.swap(0, 4); // slot order no longer pos order
        let r = recent_slots(&rs, 2);
        assert_eq!(
            r.iter().map(|&i| rs[i as usize].pos).collect::<Vec<_>>(),
            vec![4, 3]
        );
    }

    #[test]
    fn top_k_deterministic_ties() {
        let rs = recs(10);
        let ex = vec![false; 10];
        let a = top_k_by(&rs, &ex, 3, |_| 1.0);
        let b = top_k_by(&rs, &ex, 3, |_| 1.0);
        assert_eq!(a, b);
        // ties break toward newer pos
        assert_eq!(a.iter().map(|&i| rs[i as usize].pos).collect::<Vec<_>>(), vec![9, 8, 7]);
    }

    #[test]
    fn top_k_excludes() {
        let rs = recs(4);
        let mut ex = vec![false; 4];
        ex[3] = true;
        let got = top_k_by(&rs, &ex, 4, |r| r.pos as f64);
        assert_eq!(got, vec![2, 1, 0]);
    }

    #[test]
    fn keep_with_pinned_exact_budget() {
        let rs = recs(10);
        let keep = keep_with_pinned(&rs, vec![9, 8], 5, |r| r.pos as f64);
        assert_eq!(keep.len(), 5);
        assert_eq!(keep[..2], [9, 8]);
        assert!(!keep[2..].contains(&9));
    }

    #[test]
    fn registry_builds_all() {
        let p = PolicyParams::default();
        for spec in [
            "full", "streaming", "tova", "h2o", "scissorhands", "raas", "rkv", "lazy",
            "tova+window", "h2o+window", "raas+window",
        ] {
            let pol = build(spec, &p).unwrap();
            assert!(!pol.name().is_empty());
        }
        assert!(build("bogus", &p).is_err());
        assert!(build("lazy+window", &p).is_err());
    }

    #[test]
    fn ranking_cost_nlogn() {
        assert_eq!(ranking_cost(0), 0);
        assert_eq!(ranking_cost(1), 0);
        assert!(ranking_cost(1024) >= 10 * 1024);
    }

    #[test]
    fn property_top_k_is_correct_set() {
        crate::util::property_test("top_k_correct", 50, |rng| {
            let n = rng.range(1, 64);
            let mut rs = recs(n);
            for r in rs.iter_mut() {
                r.cum_attn = rng.f32();
            }
            let k = rng.range(0, n);
            let ex = vec![false; n];
            let got = top_k_by(&rs, &ex, k, |r| r.cum_attn as f64);
            assert_eq!(got.len(), k);
            // every kept score >= every dropped score
            let kept: Vec<f64> = got.iter().map(|&i| rs[i as usize].cum_attn as f64).collect();
            let min_kept = kept.iter().cloned().fold(f64::INFINITY, f64::min);
            let dropped_max = (0..n as u32)
                .filter(|i| !got.contains(i))
                .map(|i| rs[i as usize].cum_attn as f64)
                .fold(f64::NEG_INFINITY, f64::max);
            if k > 0 && k < n {
                assert!(min_kept >= dropped_max - 1e-12);
            }
        });
    }
}
