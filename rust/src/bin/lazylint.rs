//! `lazylint` — run the repo's static-analysis pass from the command line.
//!
//! ```text
//! cargo run --release --bin lazylint -- rust/src docs
//! ```
//!
//! Prints one `path:line: [rule] message` per finding and exits 1 if any
//! survive suppression, 0 on a clean tree, 2 on usage or IO errors. The
//! rule catalog and suppression syntax are in docs/analysis.md.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (src, docs) = match (args.first(), args.get(1)) {
        (Some(s), Some(d)) if args.len() == 2 => (Path::new(s.as_str()), Path::new(d.as_str())),
        _ => {
            eprintln!("usage: lazylint <rust-src-dir> <docs-dir>");
            eprintln!("  e.g. lazylint rust/src docs");
            return ExitCode::from(2);
        }
    };
    match lazyeviction::analysis::run(src, docs) {
        Ok(findings) if findings.is_empty() => {
            println!("lazylint: clean");
            ExitCode::SUCCESS
        }
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            println!("lazylint: {} finding(s)", findings.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("lazylint: {e}");
            ExitCode::from(2)
        }
    }
}
