//! Synthetic TIR workloads and attention traces.
//!
//! The paper's experiments run 7B–32B reasoning models over GSM8K / MATH-500
//! / AIME / GPQA / LiveCodeBench — none of which is runnable in this
//! environment (repro gate, DESIGN.md §5). The substitution: a trace
//! generator that reproduces the *attention statistics the paper measures*
//! (Fig. 2/3): >95% of tokens recur, MRI distributions per model×task
//! (80th-pct MRI ≈ the paper's W), attention sinks, local recency mass, and
//! answer-critical tokens whose eviction destroys the sample — plus token
//! redundancy levels that separate math (R-KV's favorable case) from QA/code.

pub mod generator;
pub mod mri;
pub mod workload;

pub use generator::{generate, Trace};
pub use workload::{ModelProfile, WorkloadProfile, DATASETS, MODELS};

/// One attention spike: token at `pos` receives aggregated score `score`
/// at some step. Background (non-spike) attention is treated as 0 by the
/// tracker (below any α).
#[derive(Clone, Copy, Debug)]
pub struct Activation {
    pub pos: u32,
    pub score: f32,
}

/// Per-generated-step trace record.
#[derive(Clone, Debug, Default)]
pub struct TraceStep {
    /// Attention spikes over *previous* tokens at this step.
    pub activations: Vec<Activation>,
    /// Positions whose information is REQUIRED by this step (recurrence of
    /// an answer-critical token). A missed need damages the sample.
    pub needs: Vec<u32>,
}

/// Static per-token metadata.
#[derive(Clone, Copy, Debug)]
pub struct TraceToken {
    /// Redundancy group (u32::MAX ⇒ unique content).
    pub sim_group: u32,
    pub is_critical: bool,
}
