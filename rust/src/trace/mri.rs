//! MRI analysis (Fig. 3c) and the paper's W-selection rule (§4: W = the MRI
//! value covering 80% of tokens, measured offline on ~1% of samples).

use super::Trace;
use crate::kvcache::TokenRecord;
use crate::util::stats;

/// Measured MRI values from a set of traces by replaying the tracker update
/// (Eq. 1) over every step — i.e. what the runtime would observe, not the
/// generator's hidden periods.
pub fn measure_mri(traces: &[Trace], alpha: f32) -> Vec<f64> {
    let mut out = Vec::new();
    for t in traces {
        // TS initializes to the token's creation step (prompt tokens are all
        // "born" during prefill at their own positions).
        let mut recs: Vec<TokenRecord> = (0..t.total_len).map(|p| TokenRecord::new(p, p)).collect();
        for (si, step) in t.steps.iter().enumerate() {
            let step_t = t.prompt_len + si as u32;
            for a in &step.activations {
                if a.score >= alpha {
                    let r = &mut recs[a.pos as usize];
                    let interval = step_t.saturating_sub(r.ts);
                    if interval > r.mri {
                        r.mri = interval;
                    }
                    r.ts = step_t;
                }
            }
        }
        out.extend(recs.iter().filter(|r| r.mri > 0).map(|r| r.mri as f64));
    }
    out
}

/// Fraction of tokens with MRI > 1 (the paper's ">95% recur" statistic).
pub fn recurrence_fraction(traces: &[Trace], alpha: f32) -> f64 {
    let mut recurring = 0usize;
    let mut total = 0usize;
    for t in traces {
        let mris = measure_mri(std::slice::from_ref(t), alpha);
        recurring += mris.iter().filter(|&&m| m > 1.0).count();
        total += t.total_len as usize;
    }
    recurring as f64 / total.max(1) as f64
}

/// The paper's W rule: the MRI percentile (default 80%) over sample traces.
pub fn suggest_window(traces: &[Trace], alpha: f32, pct: f64) -> usize {
    let mris = measure_mri(traces, alpha);
    if mris.is_empty() {
        return 25;
    }
    stats::quantile_of(&mris, pct).round().max(2.0) as usize
}

/// CDF points (x, F(x)) for plotting Fig. 3c.
pub fn mri_cdf(mris: &[f64], xs: &[f64]) -> Vec<(f64, f64)> {
    xs.iter().map(|&x| (x, stats::ecdf(mris, x))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::generator::generate;
    use crate::trace::workload::{dataset_profile, model_profile};

    fn traces(ds: &str, n: u64) -> Vec<Trace> {
        (0..n)
            .map(|s| generate(&dataset_profile(ds), &model_profile("ds-llama-8b"), s))
            .collect()
    }

    #[test]
    fn mri_measured_close_to_planted_periods() {
        let ts = traces("gsm8k", 3);
        let mris = measure_mri(&ts, 1e-3);
        assert!(!mris.is_empty());
        let med = crate::util::stats::percentile(&mris, 0.5);
        // medians within a small factor of the profile's median period
        assert!(med > 4.0 && med < 120.0, "median {med}");
    }

    #[test]
    fn recurrence_fraction_high_on_reasoning() {
        let ts = traces("gsm8k", 3);
        assert!(recurrence_fraction(&ts, 1e-3) > 0.85);
    }

    #[test]
    fn window_rule_scales_with_mri() {
        let w_gsm = suggest_window(&traces("gsm8k", 4), 1e-3, 0.8);
        let w_pg = suggest_window(&traces("pg19", 4), 1e-3, 0.8);
        assert!(
            w_gsm > w_pg,
            "reasoning W {w_gsm} should exceed LM W {w_pg}"
        );
        assert!(w_gsm >= 10 && w_gsm <= 400, "{w_gsm}");
    }

    #[test]
    fn cdf_monotone() {
        let ts = traces("math500", 2);
        let mris = measure_mri(&ts, 1e-3);
        let pts = mri_cdf(&mris, &[1.0, 10.0, 100.0, 1000.0]);
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
    }
}
