//! Dataset and model profiles (the paper's evaluation grid), plus the Rust
//! reasoning-sample generator used by the real-engine E2E driver (mirrors
//! python/compile/corpus.py exactly — same grammar, same charset).

use crate::util::rng::Rng;

/// Statistical profile of one benchmark dataset (DESIGN.md §5 substitution).
#[derive(Clone, Debug)]
pub struct WorkloadProfile {
    pub name: &'static str,
    /// Prompt length range (tokens).
    pub prompt_len: (usize, usize),
    /// Generated length range (tokens) — paper scales: GSM8K ≲4k,
    /// MATH-500 ≲8k, AIME/LCB ≲16k (divided by 8 for this testbed).
    pub out_len: (usize, usize),
    /// Fraction of tokens that exhibit importance recurrence (paper: >95%).
    pub recur_frac: f64,
    /// Lognormal MRI: median (steps) and sigma. Paper Fig. 3c: most MRIs are
    /// far below output length; 80% < 175 for Qwen on MATH-500.
    pub mri_median: f64,
    pub mri_sigma: f64,
    /// Local-recency attention span.
    pub locality: usize,
    /// Attention-sink tokens at the start.
    pub sink_n: usize,
    /// Fraction of tokens carrying near-duplicate content (math ≫ QA/code —
    /// what R-KV exploits, and why it collapses on GPQA/LCB: paper Table 2).
    pub redundancy: f64,
    /// Redundancy group size when redundant.
    pub group_size: usize,
    /// Answer-critical tokens per sample.
    pub n_critical: usize,
    /// Recurrences ("needs") per critical token.
    pub needs_per_critical: usize,
}

/// A reasoning-model profile = base accuracy per dataset + MRI scale.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    /// FullKV accuracy on [gsm8k, math500, aime, gpqa, lcb] (paper Tables
    /// 1–2; missing entries interpolated).
    pub base_acc: [f64; 5],
    /// Multiplier on MRI medians (bigger models re-reference further back).
    pub mri_scale: f64,
    /// Tracking threshold α (paper App. F.2).
    pub alpha: f32,
}

pub const DATASETS: [&str; 6] = ["gsm8k", "math500", "aime", "gpqa", "lcb", "pg19"];

pub fn dataset_profile(name: &str) -> WorkloadProfile {
    match name {
        "gsm8k" => WorkloadProfile {
            name: "gsm8k",
            prompt_len: (24, 56),
            out_len: (256, 512),
            recur_frac: 0.96,
            mri_median: 18.0,
            mri_sigma: 0.9,
            locality: 4,
            sink_n: 2,
            redundancy: 0.45,
            group_size: 4,
            n_critical: 6,
            needs_per_critical: 3,
        },
        "math500" => WorkloadProfile {
            name: "math500",
            prompt_len: (24, 56),
            out_len: (512, 1024),
            recur_frac: 0.96,
            mri_median: 28.0,
            mri_sigma: 1.0,
            locality: 4,
            sink_n: 2,
            redundancy: 0.5,
            group_size: 4,
            n_critical: 8,
            needs_per_critical: 3,
        },
        "aime" => WorkloadProfile {
            name: "aime",
            prompt_len: (24, 56),
            out_len: (1024, 2048),
            recur_frac: 0.97,
            mri_median: 40.0,
            mri_sigma: 1.1,
            locality: 4,
            sink_n: 2,
            redundancy: 0.5,
            group_size: 4,
            n_critical: 10,
            needs_per_critical: 4,
        },
        "gpqa" => WorkloadProfile {
            name: "gpqa",
            prompt_len: (40, 60),
            out_len: (512, 1024),
            recur_frac: 0.95,
            mri_median: 30.0,
            mri_sigma: 1.0,
            locality: 4,
            sink_n: 2,
            redundancy: 0.08, // low token similarity: R-KV's failure case
            group_size: 2,
            n_critical: 8,
            needs_per_critical: 3,
        },
        "lcb" => WorkloadProfile {
            name: "lcb",
            prompt_len: (40, 60),
            out_len: (1024, 2048),
            recur_frac: 0.95,
            mri_median: 36.0,
            mri_sigma: 1.1,
            locality: 6,
            sink_n: 2,
            redundancy: 0.12,
            group_size: 2,
            n_critical: 10,
            needs_per_critical: 3,
        },
        // PG-19-like language modelling: recurrence exists but with tiny MRI
        // (paper Limitations: recurring tokens have MRI < 10 on C4) and few
        // long-range needs — where greedy baselines do fine (Fig. 2a).
        "pg19" => WorkloadProfile {
            name: "pg19",
            prompt_len: (24, 56),
            out_len: (256, 512),
            recur_frac: 0.9,
            mri_median: 4.0,
            mri_sigma: 0.5,
            locality: 6,
            sink_n: 2,
            redundancy: 0.2,
            group_size: 2,
            n_critical: 2,
            needs_per_critical: 1,
        },
        other => panic!("unknown dataset profile '{other}'"),
    }
}

pub const MODELS: [&str; 4] = ["ds-llama-8b", "ds-qwen-7b", "qwen3-4b", "qwq-32b"];

pub fn model_profile(name: &str) -> ModelProfile {
    // base_acc: [gsm8k, math500, aime, gpqa, lcb] — FullKV rows of Tables 1–2
    match name {
        "ds-llama-8b" => ModelProfile {
            name: "ds-llama-8b",
            base_acc: [81.73, 74.8, 30.0, 37.4, 58.62],
            mri_scale: 1.0,
            alpha: 5e-4,
        },
        "ds-qwen-7b" => ModelProfile {
            name: "ds-qwen-7b",
            base_acc: [89.92, 86.0, 46.7, 55.7, 55.17],
            mri_scale: 1.1,
            alpha: 1e-4,
        },
        "qwen3-4b" => ModelProfile {
            name: "qwen3-4b",
            base_acc: [93.32, 87.2, 60.0, 62.0, 60.0],
            mri_scale: 1.25,
            alpha: 1e-4,
        },
        "qwq-32b" => ModelProfile {
            name: "qwq-32b",
            base_acc: [95.61, 87.2, 73.3, 68.0, 63.0],
            mri_scale: 1.5,
            alpha: 1e-4,
        },
        other => panic!("unknown model profile '{other}'"),
    }
}

pub fn dataset_index(name: &str) -> usize {
    DATASETS
        .iter()
        .position(|&d| d == name)
        .unwrap_or_else(|| panic!("unknown dataset '{name}'"))
        .min(4) // pg19 has no accuracy column; reuse lcb slot harmlessly
}

// ---------------------------------------------------------------------------
// Real-engine reasoning samples (mirror of python/compile/corpus.py)
// ---------------------------------------------------------------------------

const VARS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZ";

/// A generated reasoning sample for the served model: `prompt` plants the
/// facts, `template` replays queries with `?` holes at answer digits, and
/// `answers` holds the ground truth for each hole.
#[derive(Clone, Debug)]
pub struct ReasoningSample {
    pub prompt: String,
    pub template: String,
    pub answers: Vec<char>,
}

/// Mirrors corpus.gen_sample (recall / add / chain query mix) with answers
/// replaced by `?` holes in the template.
pub fn gen_reasoning_sample(
    rng: &mut Rng,
    n_facts: usize,
    n_queries: usize,
) -> ReasoningSample {
    let n_facts = n_facts.max(2);
    let mut names: Vec<u8> = VARS.to_vec();
    rng.shuffle(&mut names);
    names.truncate(n_facts + n_queries);

    let mut env: Vec<(u8, u32)> = Vec::new();
    let mut prompt = String::from("#");
    for &v in &names[..n_facts] {
        let d = rng.below(10) as u32;
        env.push((v, d));
        prompt.push(v as char);
        prompt.push('=');
        prompt.push(char::from_digit(d, 10).unwrap());
        prompt.push(';');
    }
    prompt.push_str("\n>");

    let mut template = String::new();
    let mut answers = Vec::new();
    let mut next_new = n_facts;
    for _ in 0..n_queries {
        let r = rng.f64();
        if r < 0.4 {
            // recall
            let (a, va) = env[rng.below(env.len())];
            template.push(a as char);
            template.push_str("=?;");
            answers.push(char::from_digit(va, 10).unwrap());
        } else {
            let (a, va) = env[rng.below(env.len())];
            let (b, vb) = env[rng.below(env.len())];
            let val = (va + vb) % 10;
            if r < 0.65 && next_new < names.len() {
                let nv = names[next_new];
                next_new += 1;
                template.push(nv as char);
                template.push('=');
                env.push((nv, val));
            }
            template.push(a as char);
            template.push('+');
            template.push(b as char);
            template.push_str("=?;");
            answers.push(char::from_digit(val, 10).unwrap());
        }
    }
    template.push('\n');
    ReasoningSample {
        prompt,
        template,
        answers,
    }
}

/// Score hole predictions against ground truth: fraction correct.
pub fn score_sample(sample: &ReasoningSample, holes: &[char]) -> f64 {
    if sample.answers.is_empty() {
        return 1.0;
    }
    let hits = sample
        .answers
        .iter()
        .zip(holes.iter())
        .filter(|(a, p)| a == p)
        .count();
    hits as f64 / sample.answers.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_resolve() {
        for d in DATASETS {
            let p = dataset_profile(d);
            assert!(p.recur_frac > 0.5 && p.out_len.1 >= p.out_len.0);
        }
        for m in MODELS {
            let p = model_profile(m);
            assert!(p.base_acc.iter().all(|&a| a > 0.0 && a <= 100.0));
        }
    }

    #[test]
    fn math_redundancy_exceeds_qa() {
        assert!(dataset_profile("math500").redundancy > 3.0 * dataset_profile("gpqa").redundancy);
    }

    #[test]
    fn pg19_has_tiny_mri() {
        assert!(dataset_profile("pg19").mri_median < 10.0);
    }

    #[test]
    fn reasoning_sample_well_formed() {
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let s = gen_reasoning_sample(&mut rng, 4, 6);
            assert!(s.prompt.starts_with('#') && s.prompt.ends_with('>'));
            assert_eq!(
                s.template.matches('?').count(),
                s.answers.len(),
                "{s:?}"
            );
            assert!(s.template.ends_with('\n'));
            // answers are digits
            assert!(s.answers.iter().all(|c| c.is_ascii_digit()));
        }
    }

    #[test]
    fn reasoning_sample_charset_closed() {
        const CS: &str = "0123456789+-*=();ABCDEFGHIJKLMNOPQRSTUVWXYZ?.,# >\n";
        let mut rng = Rng::new(5);
        let s = gen_reasoning_sample(&mut rng, 5, 8);
        for c in s.prompt.chars().chain(s.template.chars()) {
            assert!(CS.contains(c), "char {c:?} not in charset");
        }
    }

    #[test]
    fn score_sample_counts_matches() {
        let s = ReasoningSample {
            prompt: String::new(),
            template: String::new(),
            answers: vec!['1', '2', '3'],
        };
        assert!((score_sample(&s, &['1', 'x', '3']) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(score_sample(&s, &[]), 0.0);
    }

    #[test]
    fn deterministic_generation() {
        let a = gen_reasoning_sample(&mut Rng::new(7), 4, 5);
        let b = gen_reasoning_sample(&mut Rng::new(7), 4, 5);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.template, b.template);
    }
}
