//! TIR attention-trace generation from a (dataset, model) profile pair.
//!
//! Emits sparse per-step activation sets (attention ≥ threshold events) —
//! dense maps would be O(len²) and the trackers only react to spikes anyway.
//! The generator realizes the paper's measured structure:
//!   * sinks: initial tokens activated continually (StreamingLLM's insight);
//!   * locality: the last few tokens always get mass;
//!   * recurrence: recur_frac of tokens re-activate with period ~ lognormal
//!     (the MRI distribution of Fig. 3c, scaled per model);
//!   * criticals: facts/intermediates whose recurrences are *needs* — if the
//!     token (or a redundant twin) is evicted when needed, the sample is
//!     damaged (Finding 2: premature eviction ⇒ catastrophic degradation).

use super::workload::{ModelProfile, WorkloadProfile};
use super::{Activation, TraceStep, TraceToken};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Trace {
    pub dataset: String,
    pub model: String,
    pub prompt_len: u32,
    pub total_len: u32,
    pub tokens: Vec<TraceToken>,
    /// steps[i] describes decoding step prompt_len + i.
    pub steps: Vec<TraceStep>,
    /// FullKV accuracy of (model, dataset) — the ceiling for this sample.
    pub base_acc: f64,
    /// Ground-truth recurrence periods (pos → period) for MRI analysis.
    pub periods: Vec<(u32, u32)>,
}

struct RecurringTok {
    pos: u32,
    period: u32,
    next_fire: u32,
    is_critical: bool,
    needs_left: usize,
    /// Ordinary tokens recur a bounded number of times then go quiet
    /// (intermediate chatter); critical condition/summary tokens recur for
    /// the whole generation (fires_left = u32::MAX).
    fires_left: u32,
}

/// Deterministic trace for (profile, model, seed).
pub fn generate(wp: &WorkloadProfile, mp: &ModelProfile, seed: u64) -> Trace {
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let prompt_len = rng.range(wp.prompt_len.0, wp.prompt_len.1) as u32;
    let out_len = rng.range(wp.out_len.0, wp.out_len.1) as u32;
    let total = prompt_len + out_len;

    let mut tokens = Vec::with_capacity(total as usize);
    let mut recurring: Vec<RecurringTok> = Vec::new();
    let mut periods = Vec::new();
    let mut group_of_pos: Vec<u32> = vec![u32::MAX; total as usize];
    let mut next_group = 0u32;
    let mut open_groups: Vec<(u32, usize)> = Vec::new(); // (group, slots left)

    // choose critical positions: prefer prompt facts + early intermediates
    let mut crit_positions: Vec<u32> = Vec::new();
    for _ in 0..wp.n_critical {
        let pos = if rng.chance(0.6) {
            rng.range(1, (prompt_len as usize).saturating_sub(1).max(1)) as u32
        } else {
            prompt_len + rng.below((out_len as usize / 2).max(1)) as u32
        };
        if !crit_positions.contains(&pos) {
            crit_positions.push(pos);
        }
    }

    let draw_period = |rng: &mut Rng| -> u32 {
        let med = wp.mri_median * mp.mri_scale;
        let p = rng.lognormal(med.ln(), wp.mri_sigma);
        (p.round() as u32).clamp(2, (out_len / 2).max(3))
    };

    for pos in 0..total {
        // redundancy groups: open a group with prob redundancy/group_size,
        // subsequent members join as later tokens appear
        if rng.chance(wp.redundancy / wp.group_size as f64) {
            open_groups.push((next_group, wp.group_size - 1));
            group_of_pos[pos as usize] = next_group;
            next_group += 1;
        } else if !open_groups.is_empty() && rng.chance(wp.redundancy) {
            let gi = rng.below(open_groups.len());
            let (g, left) = &mut open_groups[gi];
            group_of_pos[pos as usize] = *g;
            *left -= 1;
            if *left == 0 {
                open_groups.swap_remove(gi);
            }
        }

        let is_critical = crit_positions.contains(&pos);
        tokens.push(TraceToken {
            sim_group: group_of_pos[pos as usize],
            is_critical,
        });

        if rng.chance(wp.recur_frac) || is_critical {
            let period = draw_period(&mut rng);
            let first = pos.max(prompt_len) + 1 + rng.below(period as usize) as u32;
            periods.push((pos, period));
            // bounded lifetime for ordinary tokens: 2 + Geom fires — they
            // exhibit TIR (MRI > 1) but eventually die, which is what the
            // MRI-centric score can see and greedy/cumulative scores cannot
            let fires = if is_critical {
                u32::MAX
            } else {
                2 + rng.geometric(0.45) as u32
            };
            recurring.push(RecurringTok {
                pos,
                period,
                next_fire: first,
                is_critical,
                needs_left: if is_critical {
                    wp.needs_per_critical
                } else {
                    0
                },
                fires_left: fires,
            });
        }
    }

    // critical tokens get a redundant twin in math-like (high redundancy)
    // profiles: a later token carrying the same content group
    if wp.redundancy > 0.3 {
        for &cp in &crit_positions {
            if cp < total && rng.chance(0.8) {
                let twin = (cp + 1 + rng.below((total - cp - 1).max(1) as usize) as u32)
                    .min(total - 1);
                let g = if group_of_pos[cp as usize] != u32::MAX {
                    group_of_pos[cp as usize]
                } else {
                    let g = next_group;
                    next_group += 1;
                    group_of_pos[cp as usize] = g;
                    tokens[cp as usize].sim_group = g;
                    g
                };
                group_of_pos[twin as usize] = g;
                tokens[twin as usize].sim_group = g;
            }
        }
    }

    // build steps
    let mut steps: Vec<TraceStep> = (0..out_len).map(|_| TraceStep::default()).collect();
    let score = |rng: &mut Rng, hot: bool| -> f32 {
        if hot {
            0.02 + 0.2 * rng.f32()
        } else {
            0.002 + 0.01 * rng.f32()
        }
    };
    for si in 0..out_len {
        let t = prompt_len + si;
        let step = &mut steps[si as usize];
        // sinks
        for s in 0..wp.sink_n.min(prompt_len as usize) {
            step.activations.push(Activation {
                pos: s as u32,
                score: score(&mut rng, false),
            });
        }
        // locality: previous few tokens
        for d in 1..=wp.locality.min(t as usize) {
            if rng.chance(0.8) {
                step.activations.push(Activation {
                    pos: t - d as u32,
                    score: score(&mut rng, d == 1),
                });
            }
        }
    }
    for r in recurring.iter_mut() {
        let mut fire = r.next_fire;
        let mut fires_left = r.fires_left;
        while fire < total && fires_left > 0 {
            let si = (fire - prompt_len) as usize;
            // "Token Importance Recurrence" with *imperfect* spikes: ~30% of
            // re-activations land below the tracking threshold α (the paper's
            // "attention score of recurring tokens may be low within an
            // interval"). Timestamp-only trackers (RaaS) go stale on these;
            // the MRI-based H1 carries the token through to the next spike.
            let strength = if rng.chance(0.30) {
                mp.alpha * (0.3 + 0.6 * rng.f32())
            } else {
                score(&mut rng, true)
            };
            steps[si].activations.push(Activation {
                pos: r.pos,
                score: strength,
            });
            if r.is_critical && r.needs_left > 0 && fire > r.pos + r.period {
                steps[si].needs.push(r.pos);
                r.needs_left -= 1;
            }
            // jittered periodic recurrence
            let jitter = (r.period as f64 * 0.2 * (rng.f64() - 0.5)) as i64;
            fire = (fire as i64 + r.period as i64 + jitter).max(fire as i64 + 2) as u32;
            fires_left = fires_left.saturating_sub(1);
        }
        // critical conditions are also *glanced at* between spikes with
        // moderate attention — below the spike level, around typical α —
        // which is what lets cumulative/current-attention baselines retain
        // some of them some of the time (paper: they lose ~10%, not all)
        if r.is_critical {
            let mut g = r.pos.max(prompt_len) + 3;
            while g < total {
                let si = (g - prompt_len) as usize;
                // glances weaken with distance, like background attention:
                // a dormant fact far back is only faintly re-read between
                // its true recurrence spikes
                let decay = 1.0 / (1.0 + (g - r.pos) as f32 / 64.0);
                steps[si].activations.push(Activation {
                    pos: r.pos,
                    score: mp.alpha * (0.15 + 0.75 * rng.f32()) * decay,
                });
                g += 3 + rng.below(5) as u32;
            }
        }
    }
    for s in steps.iter_mut() {
        s.activations
            .sort_unstable_by_key(|a| (a.pos, (a.score * -1e6) as i64));
        s.activations.dedup_by_key(|a| a.pos);
    }

    Trace {
        dataset: wp.name.to_string(),
        model: mp.name.to_string(),
        prompt_len,
        total_len: total,
        tokens,
        steps,
        base_acc: mp.base_acc[super::workload::dataset_index(wp.name)],
        periods,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::workload::{dataset_profile, model_profile};

    fn tr(seed: u64) -> Trace {
        generate(
            &dataset_profile("gsm8k"),
            &model_profile("ds-llama-8b"),
            seed,
        )
    }

    #[test]
    fn deterministic() {
        let a = tr(1);
        let b = tr(1);
        assert_eq!(a.total_len, b.total_len);
        assert_eq!(a.steps.len(), b.steps.len());
        assert_eq!(a.steps[5].activations.len(), b.steps[5].activations.len());
    }

    #[test]
    fn lengths_in_profile_range() {
        let p = dataset_profile("gsm8k");
        for seed in 0..10 {
            let t = tr(seed);
            let out = (t.total_len - t.prompt_len) as usize;
            assert!(out >= p.out_len.0 && out <= p.out_len.1);
        }
    }

    #[test]
    fn activations_point_backwards() {
        let t = tr(2);
        for (si, s) in t.steps.iter().enumerate() {
            let step_t = t.prompt_len + si as u32;
            for a in &s.activations {
                assert!(a.pos < step_t, "activation at {} >= step {}", a.pos, step_t);
            }
        }
    }

    #[test]
    fn needs_are_critical_tokens() {
        let t = tr(3);
        let mut total_needs = 0;
        for s in &t.steps {
            for &n in &s.needs {
                assert!(t.tokens[n as usize].is_critical);
                total_needs += 1;
            }
        }
        assert!(total_needs > 0, "trace must contain needs");
    }

    #[test]
    fn most_tokens_recur() {
        // paper Finding 2: >95% of tokens exhibit recurrence
        let t = tr(4);
        let frac = t.periods.len() as f64 / t.total_len as f64;
        assert!(frac > 0.9, "recurring fraction {frac}");
    }

    #[test]
    fn redundancy_separates_math_from_gpqa() {
        let math = generate(
            &dataset_profile("math500"),
            &model_profile("ds-llama-8b"),
            7,
        );
        let gpqa = generate(&dataset_profile("gpqa"), &model_profile("ds-llama-8b"), 7);
        let frac = |t: &Trace| {
            t.tokens.iter().filter(|k| k.sim_group != u32::MAX).count() as f64
                / t.tokens.len() as f64
        };
        assert!(frac(&math) > 2.0 * frac(&gpqa));
    }
}
