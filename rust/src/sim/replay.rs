//! Replay a trace through an eviction policy, tracking what the paper's
//! mechanisms actually depend on: which tokens are live when they are
//! needed, and how much attention mass the compressed cache loses (Eq. 4).

use std::collections::HashMap;
use std::time::Instant;

use crate::attention::{observe, TrackerConfig};
use crate::eviction::Policy;
use crate::kvcache::{SeqKv, TokenRecord};
use crate::trace::Trace;

#[derive(Clone, Copy, Debug)]
pub struct ReplayConfig {
    pub budget: usize,
    /// Physical capacity (>= budget + window headroom for lagged policies).
    pub capacity: usize,
    pub alpha: f32,
    /// Background attention noise ceiling, as a fraction of alpha. Real
    /// attention maps give every token a small nonzero score; without this
    /// floor, instantaneous-attention ranking (TOVA) could trivially
    /// separate "ever glanced at" from junk. 0.8 keeps noise strictly
    /// below the importance threshold.
    pub noise_frac: f32,
    /// Record live counts each step (memory curves).
    pub record_live: bool,
}

impl ReplayConfig {
    pub fn new(budget: usize, window_headroom: usize, alpha: f32) -> ReplayConfig {
        ReplayConfig {
            budget,
            capacity: budget + window_headroom.max(1),
            alpha,
            noise_frac: 0.8,
            record_live: false,
        }
    }
}

/// Deterministic per-(step, pos) background noise in [0, 1).
#[inline]
fn noise01(t: u32, pos: u32) -> f32 {
    let mut s = ((t as u64) << 32) ^ pos as u64 ^ 0x9E37_79B9_7F4A_7C15;
    let x = crate::util::rng::splitmix64(&mut s);
    (x >> 40) as f32 / (1u64 << 24) as f32
}

#[derive(Clone, Debug, Default)]
pub struct ReplayResult {
    pub needs_total: usize,
    pub needs_missed: usize,
    /// Σ s and Σ s² of all activation scores, and of those landing on
    /// evicted tokens — the Eq. 4 attention-output error proxy.
    pub mass_total: f64,
    pub mass_lost: f64,
    pub mass2_total: f64,
    pub mass2_lost: f64,
    pub evictions: usize,
    pub eviction_decisions: usize,
    pub live_curve: Vec<usize>,
    pub peak_live: usize,
    /// Table-6 complexity accounting accumulated over all steps.
    pub score_ops: u64,
    pub rank_ops: u64,
    pub wall_s: f64,
}

impl ReplayResult {
    /// Attention fidelity in [0,1]: 1 − relative L2 of dropped attention.
    pub fn fidelity(&self) -> f64 {
        if self.mass2_total == 0.0 {
            1.0
        } else {
            1.0 - (self.mass2_lost / self.mass2_total).sqrt()
        }
    }

    pub fn miss_rate(&self) -> f64 {
        if self.needs_total == 0 {
            0.0
        } else {
            self.needs_missed as f64 / self.needs_total as f64
        }
    }
}

/// Run `policy` over `trace` with the given budget. Semantics mirror the
/// engine: tokens enter the cache as they are generated; attention is
/// observed over *live* tokens; needs check liveness of the needed token or
/// any live member of its redundancy group.
pub fn replay(trace: &Trace, policy: &dyn Policy, cfg: ReplayConfig) -> ReplayResult {
    // lazylint: allow(determinism): wall-clock measures wall_s only; no replay decision reads it
    let t0 = Instant::now();
    let mut res = ReplayResult::default();
    let mut seq = SeqKv::new(cfg.capacity.max(trace.total_len as usize + 1));
    // For FullKV-like policies the capacity must hold everything; for
    // bounded policies we still allocate the full Vec but slot count stays
    // near budget — SeqKv is only metadata.
    let mut slot_of: HashMap<u32, usize> = HashMap::new();
    let mut live_groups: HashMap<u32, u32> = HashMap::new(); // group -> live count
    let tcfg = TrackerConfig { alpha: cfg.alpha };

    let push_tok = |seq: &mut SeqKv,
                        slot_of: &mut HashMap<u32, usize>,
                        live_groups: &mut HashMap<u32, u32>,
                        pos: u32,
                        step: u32| {
        let g = trace.tokens[pos as usize].sim_group;
        let mut rec = TokenRecord::new(pos, step).with_group(g);
        rec.last_attn = 1.0;
        let slot = seq.push(rec);
        slot_of.insert(pos, slot);
        if g != u32::MAX {
            *live_groups.entry(g).or_insert(0) += 1;
        }
    };

    for p in 0..trace.prompt_len {
        push_tok(&mut seq, &mut slot_of, &mut live_groups, p, p);
    }

    let mut attn_buf: Vec<f32> = Vec::new();
    for (si, step) in trace.steps.iter().enumerate() {
        let t = trace.prompt_len + si as u32;

        // 1) attention observation over live slots (sparse → dense, with a
        //    background-noise floor below alpha)
        attn_buf.clear();
        attn_buf.resize(seq.len(), 0.0);
        let noise_max = cfg.alpha * cfg.noise_frac;
        for (slot, r) in seq.records().iter().enumerate() {
            // background attention decays with distance (RoPE locality):
            // dormant far-back tokens score systematically below recent
            // ones — the mechanism that makes instantaneous-attention
            // ranking (TOVA) evict exactly the paper's recurring tokens.
            let dist = t.saturating_sub(r.pos) as f32;
            let decay = 1.0 / (1.0 + dist / 64.0);
            attn_buf[slot] = noise01(t, r.pos) * noise_max * decay;
        }
        for a in &step.activations {
            let s = a.score as f64;
            res.mass_total += s;
            res.mass2_total += s * s;
            match slot_of.get(&a.pos) {
                Some(&slot) => attn_buf[slot] = a.score,
                None => {
                    res.mass_lost += s;
                    res.mass2_lost += s * s;
                }
            }
        }
        observe(seq.records_mut(), &attn_buf, t, tcfg);

        // 2) needs: live token or live redundancy twin satisfies
        for &need in &step.needs {
            res.needs_total += 1;
            let ok = slot_of.contains_key(&need) || {
                let g = trace.tokens[need as usize].sim_group;
                g != u32::MAX && live_groups.get(&g).copied().unwrap_or(0) > 0
            };
            if !ok {
                res.needs_missed += 1;
            }
        }

        // 3) the new token enters the cache
        push_tok(&mut seq, &mut slot_of, &mut live_groups, t, t);
        if cfg.record_live {
            res.live_curve.push(seq.len());
        }
        res.peak_live = res.peak_live.max(seq.len());

        // 4) complexity accounting + eviction decision
        let (s_ops, r_ops) = policy.step_cost(seq.len(), cfg.budget, t);
        res.score_ops += s_ops;
        res.rank_ops += r_ops;
        let force = seq.len() >= cfg.capacity;
        if seq.len() > cfg.budget && (policy.should_evict(seq.len(), cfg.budget, t) || force)
        {
            let keep = policy.select_keep(seq.records(), cfg.budget, t);
            let evicted = seq.apply_keep(&keep, t);
            res.evictions += evicted.len();
            res.eviction_decisions += 1;
            for pos in &evicted {
                slot_of.remove(pos);
                let g = trace.tokens[*pos as usize].sim_group;
                if g != u32::MAX {
                    if let Some(c) = live_groups.get_mut(&g) {
                        *c = c.saturating_sub(1);
                    }
                }
            }
            // rebuild slot map after compaction
            slot_of.clear();
            for (slot, r) in seq.records().iter().enumerate() {
                slot_of.insert(r.pos, slot);
            }
        }
    }
    res.wall_s = t0.elapsed().as_secs_f64();
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eviction::{self, PolicyParams};
    use crate::trace::generator::generate;
    use crate::trace::workload::{dataset_profile, model_profile};

    fn trace() -> Trace {
        generate(&dataset_profile("gsm8k"), &model_profile("ds-llama-8b"), 11)
    }

    fn run(spec: &str, budget: usize) -> ReplayResult {
        let params = PolicyParams::default();
        let p = eviction::build(spec, &params).unwrap();
        let cfg = ReplayConfig::new(budget, params.window + 8, 1e-3);
        replay(&trace(), p.as_ref(), cfg)
    }

    #[test]
    fn fullkv_loses_nothing() {
        let r = run("full", 64);
        assert_eq!(r.needs_missed, 0);
        assert_eq!(r.mass_lost, 0.0);
        assert!((r.fidelity() - 1.0).abs() < 1e-12);
        assert_eq!(r.evictions, 0);
    }

    #[test]
    fn bounded_policies_respect_capacity() {
        for spec in ["tova", "h2o", "raas", "rkv", "lazy", "streaming"] {
            let r = run(spec, 96);
            let cap = 96 + PolicyParams::default().window + 8;
            assert!(r.peak_live <= cap, "{spec}: peak {} > {}", r.peak_live, cap);
            assert!(r.evictions > 0, "{spec} never evicted");
        }
    }

    #[test]
    fn lazy_beats_greedy_on_needs() {
        // the paper's core claim, at trace level — aggregated over seeds
        // (single traces are noisy; the ordering is a distributional claim)
        let params = PolicyParams::default();
        let agg = |spec: &str| -> f64 {
            let p = eviction::build(spec, &params).unwrap();
            let (mut miss, mut tot) = (0usize, 0usize);
            for seed in 0..8u64 {
                let tr = generate(
                    &dataset_profile("gsm8k"),
                    &model_profile("ds-llama-8b"),
                    100 + seed,
                );
                let cfg = ReplayConfig::new(96, params.window + 8, 1e-3);
                let r = replay(&tr, p.as_ref(), cfg);
                miss += r.needs_missed;
                tot += r.needs_total;
            }
            miss as f64 / tot as f64
        };
        let lazy = agg("lazy");
        let tova = agg("tova");
        let h2o = agg("h2o");
        assert!(
            lazy <= tova + 0.02 && lazy <= h2o + 0.02,
            "lazy {lazy} vs tova {tova} / h2o {h2o}"
        );
    }

    #[test]
    fn tighter_budget_loses_more() {
        let r1 = run("tova", 160);
        let r2 = run("tova", 48);
        assert!(r2.mass_lost >= r1.mass_lost);
        assert!(r2.fidelity() <= r1.fidelity() + 1e-9);
    }

    #[test]
    fn lazy_makes_fewer_ranking_ops_than_greedy() {
        // Table 6: O(WB + BlogB) vs O(W(B + BlogB)) per window
        let lazy = run("lazy", 96);
        let tova = run("tova", 96);
        assert!(
            lazy.rank_ops < tova.rank_ops,
            "lazy {} vs tova {}",
            lazy.rank_ops,
            tova.rank_ops
        );
    }

    #[test]
    fn fewer_decisions_for_lagged() {
        let lazy = run("lazy", 96);
        let h2o = run("h2o", 96);
        assert!(lazy.eviction_decisions < h2o.eviction_decisions);
    }
}
