//! Trace-driven eviction simulation: run the *same* Policy implementations
//! the engine uses over synthetic TIR traces, and score retention, attention
//! fidelity (Eq. 4 proxy) and task accuracy. This powers the big table
//! sweeps (Tables 1–5, 9, 10; Figs. 2, 5) where thousands of full real-model
//! generations per cell would be prohibitive (DESIGN.md §5.3).
//!
//! `capacity` is the serving-scale replay mode: per-policy live curves from
//! `replay` packed into one fixed `kvpool` block budget, reporting the
//! sustained concurrent batch each policy achieves (benches/pool.rs).

pub mod accuracy;
pub mod capacity;
pub mod replay;

pub use accuracy::{accuracy_over, AccuracyModel};
pub use capacity::{
    run_capacity, run_fleet, CapacityReport, CapacitySpec, FleetReport, FleetRouting, FleetSpec,
};
pub use replay::{replay, ReplayConfig, ReplayResult};
