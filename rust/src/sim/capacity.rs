//! Pool-capacity replay: how many concurrent sequences a *fixed global
//! block budget* sustains per eviction policy — the serving-scale payoff of
//! lagged eviction. Per-sequence live-token curves come from the trace
//! replayer ([`super::replay`]); this module packs them into one
//! [`BlockPool`] with the same iteration-level mechanics as the engine:
//! watermark-gated admission, block-at-a-time growth, whole-block
//! reclamation after eviction, and youngest-first preemption when the pool
//! runs dry — with recompute-mode resume (re-prefill the live set at the
//! preemption cursor and continue; the engine's default), swap-mode resume
//! (`kvtier`: park the table in a byte-budgeted host tier and copy it back —
//! charged as bytes moved, not tokens recomputed), or restart-from-prompt
//! (the pre-resume baseline) as the re-admission cost model, selected by
//! `CapacitySpec::{recompute_resume, swap_resume}`. The headline metric is
//! `mean_concurrency` — the sustained batch size under the budget; a policy
//! whose live set collapses to ≈ B+W (LazyEviction) sustains several times
//! the concurrency of FullKV's unbounded growth.

use std::collections::VecDeque;

use crate::eviction::{self, PolicyParams};
use crate::kvcache::memory::KvCost;
use crate::kvpool::{BlockPool, BlockTable, PoolConfig};
use crate::sim::replay::{replay, ReplayConfig};
use crate::trace::generator::generate;
use crate::trace::workload::{dataset_profile, model_profile};

#[derive(Clone, Debug)]
pub struct CapacitySpec {
    pub policy: String,
    pub dataset: String,
    pub model: String,
    pub n_requests: usize,
    /// Per-sequence KV budget B.
    pub budget: usize,
    /// Observation window W (also the recent set for the W-baselines).
    pub window: usize,
    pub alpha: f32,
    /// The fixed global budget being contended for.
    pub pool: PoolConfig,
    /// Engine row cap (compiled batch dimension analog).
    pub max_rows: usize,
    pub seed: u64,
    /// Identical system-prompt header prepended to every request (tokens).
    /// The header stays resident for a sequence's lifetime (sink-style);
    /// eviction operates on the reasoning tail as before. 0 = none.
    pub shared_prefix_tokens: usize,
    /// Serve the header through prefix sharing: one donor table holds the
    /// header's whole blocks for the whole run (the prefix-cache pin) and
    /// every admission forks it, so only the header remainder + tail are
    /// paid privately. false = every row pays for the header itself — the
    /// PR-1 baseline the sharing win is measured against.
    pub share_prefix: bool,
    /// Per-token KV footprint used to report physical bytes (paper scale by
    /// default, so the reclaimed memory reads in real GB).
    pub kv_cost: KvCost,
    /// Preemption cost model. `true` = recompute-mode resume (the engine's
    /// behavior since the resume PR): a preempted sequence re-admits by
    /// re-prefilling its live set at the preemption point in one pass
    /// (`recomputed_tokens` counts that cost) and continues decoding at the
    /// cursor it was stopped at. `false` = restart (the pre-resume
    /// baseline): the sequence re-prefills the prompt only and replays its
    /// whole live curve from step 0, throwing away `restarted_steps` of
    /// decode work per preemption. Default `false` so baseline capacity
    /// numbers stay comparable across PRs; the delta is the cost model.
    pub recompute_resume: bool,
    /// Swap-mode preemption (`kvtier`; overrides `recompute_resume` for
    /// mid-decode victims): the victim's whole table parks in a host tier
    /// and re-admission copies it back — no re-prefill at all. Costs are
    /// charged as bytes moved (`swap_out_bytes`/`swap_in_bytes`) instead of
    /// `recomputed_tokens`; scheduling is unchanged, so a swap run and a
    /// recompute run are step-for-step identical and the delta is purely
    /// the cost model — the crossover `benches/pool.rs` reports.
    pub swap_resume: bool,
    /// Host-tier budget for swap mode, in blocks. Parked tables hold tier
    /// capacity until re-admission; a preemption that would overflow it
    /// falls back to the recompute model (`swap_fallbacks`). Unlimited by
    /// default.
    pub host_tier_blocks: usize,
    /// Client-abort process: every `abort_every`-th request disconnects —
    /// mid-decode at half its live curve (row torn down, blocks reclaimed),
    /// or at re-admission time if it was preempted first (the client gave
    /// up during the stall; any swap-parked tier state is released, the
    /// serving path's `Engine::release_discarded_state`). 0 = no aborts
    /// (the default, keeping earlier capacity numbers comparable).
    pub abort_every: usize,
}

impl CapacitySpec {
    pub fn new(policy: &str, n_requests: usize) -> CapacitySpec {
        CapacitySpec {
            policy: policy.into(),
            dataset: "gsm8k".into(),
            model: "ds-llama-8b".into(),
            n_requests,
            budget: 96,
            window: 16,
            alpha: 1e-3,
            pool: PoolConfig {
                block_size: 16,
                n_blocks: 96,
                low_watermark: 4,
                high_watermark: 8,
            },
            max_rows: 16,
            seed: 7,
            shared_prefix_tokens: 0,
            share_prefix: false,
            kv_cost: KvCost::paper_7b(),
            recompute_resume: false,
            swap_resume: false,
            host_tier_blocks: usize::MAX,
            abort_every: 0,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct CapacityReport {
    pub completed: usize,
    /// Sequences that could never fit the pool alone (misconfiguration
    /// guard; 0 in any sane setup).
    pub failed: usize,
    pub steps: u64,
    /// Mean decoding sequences per step — the sustained batch size.
    pub mean_concurrency: f64,
    pub peak_concurrency: usize,
    pub preemptions: u64,
    pub peak_used_blocks: usize,
    pub total_blocks: usize,
    /// Free blocks after the run drains (== total when leak-free).
    pub end_free_blocks: usize,
    /// Whole blocks the shared header pins for the run (0 without sharing).
    pub shared_header_blocks: usize,
    /// Admissions that forked the shared header instead of paying for it.
    pub prefix_forks: u64,
    /// Peak physical KV bytes actually held in live blocks — what a paged
    /// arena must really store at the worst moment.
    pub peak_kv_bytes: usize,
    /// The paged arena's fixed physical footprint (total_blocks worth).
    pub arena_kv_bytes: usize,
    /// The per-row worst-case baseline this PR removed: `max_rows` dense
    /// `[L, H, S, dh]` buffers sized to the replay cache cap.
    pub dense_kv_bytes: usize,
    /// Preempted sequences re-admitted in recompute mode.
    pub resumes: u64,
    /// Tokens re-prefilled by those resumes — prompt + generated-so-far per
    /// resume, matching the engine's one-pass recompute prefill cost (NOT
    /// the smaller post-eviction live set the re-admitted blocks hold).
    pub recomputed_tokens: u64,
    /// Decode steps thrown away by restart-mode preemptions (zero with
    /// `recompute_resume`) — the work the resume path saves.
    pub restarted_steps: u64,
    /// Total per-sequence decode steps advanced. With recompute resume this
    /// is exactly the sum of the live-curve lengths; with restarts it is
    /// that plus `restarted_steps` — the identity the cost-model test pins.
    pub decode_steps: u64,
    /// Swap-mode: blocks parked in the host tier by preemptions.
    pub swapped_blocks: u64,
    /// Swap-mode: bytes copied device→host at preemption time.
    pub swap_out_bytes: u64,
    /// Swap-mode: bytes copied host→device at re-admission. Equals
    /// `swap_out_bytes` once the run drains (every parked table resumes).
    pub swap_in_bytes: u64,
    /// Swap preemptions that fell back to the recompute model because the
    /// tier budget could not hold the table.
    pub swap_fallbacks: u64,
    /// Requests whose client disconnected (see `CapacitySpec::abort_every`).
    pub cancelled: u64,
    /// Pool blocks released by tearing down aborted *active* rows.
    pub reclaimed_blocks: u64,
    /// Host-tier blocks released by aborting *queued swap-parked* victims —
    /// state that only a resume (or this sweep) would ever free.
    pub reclaimed_tier_blocks: u64,
    /// Host-tier blocks still occupied after the run drains (must be 0:
    /// every parked table either resumed or was reclaimed by an abort).
    pub end_tier_blocks: usize,
}

/// One queued/active sequence: its live curve and (when active) its table.
struct SeqSim {
    prompt_tokens: usize,
    live_curve: Vec<usize>,
}

struct ActiveSeq {
    idx: usize,
    cursor: usize,
    table: BlockTable,
    admit_seq: u64,
}

/// Replay `n_requests` traces through `spec.policy`, then pack the live
/// curves into the fixed pool. Deterministic for a given spec.
pub fn run_capacity(spec: &CapacitySpec) -> anyhow::Result<CapacityReport> {
    let wp = dataset_profile(&spec.dataset);
    let mp = model_profile(&spec.model);
    let params = PolicyParams {
        window: spec.window,
        recent: spec.window,
        ..PolicyParams::default()
    };
    let policy = eviction::build(&spec.policy, &params)?;

    // per-row replay cache cap — also the dense per-row provisioning the
    // physical-bytes baseline charges (keep the two derived from one place)
    let replay_headroom = spec.window + wp.locality + 2;
    let mut seqs = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        let tr = generate(
            &wp,
            &mp,
            spec.seed.wrapping_mul(7919).wrapping_add(i as u64),
        );
        let mut cfg = ReplayConfig::new(spec.budget, replay_headroom, spec.alpha);
        cfg.record_live = true;
        let r = replay(&tr, policy.as_ref(), cfg);
        seqs.push(SeqSim {
            prompt_tokens: tr.prompt_len as usize,
            live_curve: r.live_curve,
        });
    }

    let mut pool = BlockPool::new(spec.pool.clone())?;
    let mut rep = CapacityReport {
        total_blocks: pool.total_blocks(),
        ..CapacityReport::default()
    };

    // The shared header: one donor table pins its whole blocks for the run
    // (the prefix-cache pin) and every admission forks it. The header's
    // partial trailing block — and the whole header without sharing — is
    // paid per-row.
    let header = spec.shared_prefix_tokens;
    let mut donor: Option<BlockTable> = None;
    if spec.share_prefix && header >= pool.block_size() {
        let whole = (header / pool.block_size()) * pool.block_size();
        let mut t = BlockTable::new(pool.block_size());
        for _ in 0..whole {
            anyhow::ensure!(
                t.push_token(&mut pool),
                "pool of {} blocks cannot hold the {}-token shared header",
                pool.total_blocks(),
                header
            );
        }
        rep.shared_header_blocks = t.n_blocks();
        donor = Some(t);
    }

    // queue entries carry a resume cursor (0 for fresh sequences, the
    // preemption point for re-admissions) plus the parked-token count of a
    // swap-mode victim (0 = nothing parked: fresh, restart, or recompute)
    let mut queue: VecDeque<(usize, usize, usize)> = VecDeque::new();
    for (i, s) in seqs.iter().enumerate() {
        // a sequence whose peak demand exceeds the whole pool can never run
        let peak =
            header + s.live_curve.iter().copied().max().unwrap_or(0).max(s.prompt_tokens);
        if pool.blocks_for(peak + 1) > pool.total_blocks() {
            rep.failed += 1;
        } else {
            queue.push_back((i, 0, 0));
        }
    }

    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut admit_seq = 0u64;
    let mut conc_sum = 0u64;
    // host-tier occupancy (blocks) while swap-mode victims sit queued
    let mut tier_used = 0usize;
    let bytes_per_token = spec.kv_cost.bytes_per_token() as u64;
    // deterministic client-abort process: which requests disconnect, and at
    // which step of their curve (halfway — late enough to hold real state)
    let marked = |i: usize| spec.abort_every > 0 && (i + 1) % spec.abort_every == 0;
    let abort_at = |len: usize| (len / 2).max(1);

    while !(queue.is_empty() && active.is_empty()) {
        // iteration-level admission, watermark-reserved unless idle. With
        // sharing, the forked header blocks are free — only the private
        // remainder of header+prompt (plus the decode block) is demanded.
        // A recompute-mode resume (cursor > 0) demands its live set at the
        // preemption point instead of the prompt: that one-pass re-prefill
        // is the resume cost, charged to `recomputed_tokens`.
        while active.len() < spec.max_rows {
            let Some(&(next, cursor, parked_tokens)) = queue.front() else { break };
            if marked(next) && cursor > 0 {
                // the client hung up during the preemption stall: drop the
                // re-admission instead of paying for it, and release any
                // tier state parked with the snapshot — nothing else would
                // ever free it (only a resume consumes parked entries)
                queue.pop_front();
                if parked_tokens > 0 {
                    let blocks = pool.blocks_for(parked_tokens);
                    tier_used -= blocks;
                    rep.reclaimed_tier_blocks += blocks as u64;
                }
                rep.cancelled += 1;
                continue;
            }
            let fill = if cursor > 0 {
                header + seqs[next].live_curve[cursor].max(1)
            } else {
                header + seqs[next].prompt_tokens
            };
            let shared = donor.as_ref().map_or(0, |d| d.n_blocks());
            let needed = pool.blocks_for(fill + 1).saturating_sub(shared);
            let reserve = if active.is_empty() {
                0
            } else {
                spec.pool.low_watermark
            };
            if pool.free_blocks() < needed + reserve {
                break;
            }
            queue.pop_front();
            let mut table = match donor.as_ref() {
                Some(d) => {
                    rep.prefix_forks += 1;
                    BlockTable::fork_prefix(d, header, &mut pool)
                }
                None => BlockTable::new(pool.block_size()),
            };
            let mut ok = true;
            while table.len() < fill {
                if !table.push_token(&mut pool) {
                    ok = false;
                    break;
                }
            }
            debug_assert!(ok, "admission check covered the fill");
            if !ok {
                table.release_all(&mut pool);
                break;
            }
            if cursor > 0 {
                rep.resumes += 1;
                if parked_tokens > 0 {
                    // swap resume: the parked table comes back host→device;
                    // no model compute at all
                    rep.swap_in_bytes += parked_tokens as u64 * bytes_per_token;
                    tier_used -= pool.blocks_for(parked_tokens);
                } else {
                    // the engine's recompute prefill runs over the whole fed
                    // stream (prompt + tokens generated up to the preemption
                    // cursor), not just the surviving live set the blocks
                    // hold — charge the same so engine and sim
                    // `recomputed_tokens` stay comparable in one report
                    rep.recomputed_tokens +=
                        (header + seqs[next].prompt_tokens + cursor) as u64;
                }
            }
            active.push(ActiveSeq {
                idx: next,
                cursor,
                table,
                admit_seq,
            });
            admit_seq += 1;
        }
        if active.is_empty() {
            // queue non-empty but nothing admissible even at zero reserve:
            // impossible for per-seq-fitting traces with all blocks free,
            // kept as a hard stop against livelock
            if queue.pop_front().is_some() {
                rep.failed += 1;
            }
            continue;
        }

        // one decode step, oldest row first (preemption victims are always
        // younger rows that have not advanced yet this step)
        active.sort_by_key(|a| a.admit_seq);
        let mut advanced = 0usize;
        let mut r = 0usize;
        while r < active.len() {
            // mid-decode disconnect: tear the row down where it stands —
            // blocks return to the pool this step, nothing is re-queued
            if marked(active[r].idx)
                && active[r].cursor >= abort_at(seqs[active[r].idx].live_curve.len())
            {
                let mut v = active.remove(r);
                rep.reclaimed_blocks += v.table.n_blocks() as u64;
                v.table.release_all(&mut pool);
                rep.cancelled += 1;
                continue;
            }
            // the resident header rides on top of the tail's live target, so
            // a shrink never dips into the shared whole-block region
            let target = {
                let a = &active[r];
                header + seqs[a.idx].live_curve[a.cursor].max(1)
            };
            // shrink first: eviction reclaims whole blocks
            if target <= active[r].table.len() {
                active[r].table.truncate(target, &mut pool);
            }
            // a preemption re-queues at the cursor with its table parked in
            // the tier (swap mode), at the cursor with nothing parked
            // (recompute mode, or a swap that overflowed the tier budget),
            // or at 0 (restart — the replayed steps are thrown away)
            let requeue = |v: &mut ActiveSeq,
                           pool: &mut BlockPool,
                           rep: &mut CapacityReport,
                           queue: &mut VecDeque<(usize, usize, usize)>,
                           tier_used: &mut usize| {
                let parked_tokens = if spec.swap_resume && v.cursor > 0 {
                    let blocks = v.table.n_blocks();
                    if *tier_used + blocks <= spec.host_tier_blocks {
                        *tier_used += blocks;
                        rep.swapped_blocks += blocks as u64;
                        rep.swap_out_bytes +=
                            v.table.len() as u64 * spec.kv_cost.bytes_per_token() as u64;
                        v.table.len()
                    } else {
                        rep.swap_fallbacks += 1;
                        0
                    }
                } else {
                    0
                };
                v.table.release_all(pool);
                if spec.swap_resume || spec.recompute_resume {
                    queue.push_front((v.idx, v.cursor, parked_tokens));
                } else {
                    rep.restarted_steps += v.cursor as u64;
                    queue.push_front((v.idx, 0, 0));
                }
                rep.preemptions += 1;
            };
            let mut preempted_self = false;
            while active[r].table.len() < target {
                if active[r].table.push_token(&mut pool) {
                    continue;
                }
                if r == active.len() - 1 {
                    // this row is the youngest: preempt it
                    let mut v = active.remove(r);
                    requeue(&mut v, &mut pool, &mut rep, &mut queue, &mut tier_used);
                    preempted_self = true;
                    break;
                }
                // preempt the youngest (last after the sort) and retry
                let mut v = active.pop().expect("len > r + 1");
                requeue(&mut v, &mut pool, &mut rep, &mut queue, &mut tier_used);
            }
            if preempted_self {
                continue; // active[r] is now the next row (or none)
            }
            let a = &mut active[r];
            a.cursor += 1;
            advanced += 1;
            if a.cursor >= seqs[a.idx].live_curve.len() {
                a.table.release_all(&mut pool);
                rep.completed += 1;
                active.remove(r);
            } else {
                r += 1;
            }
        }
        rep.steps += 1;
        conc_sum += advanced as u64;
        rep.peak_concurrency = rep.peak_concurrency.max(advanced);
        rep.peak_used_blocks = rep.peak_used_blocks.max(pool.used_blocks());
    }

    rep.decode_steps = conc_sum;
    rep.mean_concurrency = if rep.steps == 0 {
        0.0
    } else {
        conc_sum as f64 / rep.steps as f64
    };
    // physical-memory accounting: live blocks vs the fixed arena vs the
    // removed per-row worst-case provisioning (replay cache cap per row)
    let block_bytes = spec.pool.block_size * spec.kv_cost.bytes_per_token();
    rep.peak_kv_bytes = rep.peak_used_blocks * block_bytes;
    rep.arena_kv_bytes = rep.total_blocks * block_bytes;
    rep.dense_kv_bytes =
        spec.max_rows * spec.kv_cost.bytes_for(spec.budget + replay_headroom.max(1));
    // drop the run-lifetime header pin before the leak check
    if let Some(mut d) = donor {
        d.release_all(&mut pool);
    }
    rep.end_free_blocks = pool.free_blocks();
    rep.end_tier_blocks = tier_used;
    Ok(rep)
}

/// Fleet placement policy for the capacity model — the sim analog of the
/// server router ([`crate::scheduler::routing`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetRouting {
    /// Requests of one header group always land on the same replica
    /// (`group % replicas`) — the idealized prefix-affinity router.
    Affinity,
    /// Request order round-robin, blind to headers.
    RoundRobin,
    /// Seeded uniform placement, blind to headers.
    Random,
    /// Everything on replica 0 — the degenerate hot-replica assignment a
    /// broken router (or a single-header workload under naive affinity)
    /// produces. Used to model preemption storms.
    OneHot,
}

impl FleetRouting {
    pub fn as_str(&self) -> &'static str {
        match self {
            FleetRouting::Affinity => "affinity",
            FleetRouting::RoundRobin => "rr",
            FleetRouting::Random => "random",
            FleetRouting::OneHot => "one-hot",
        }
    }
}

/// A fleet of `replicas` independent pools serving one request stream.
/// Requests carry one of `header_groups` distinct prompt headers
/// (`header_tokens` each, request `i` belongs to group `i % header_groups`
/// — a steady interleaved mix, the adversarial case for routing).
#[derive(Clone, Debug)]
pub struct FleetSpec {
    /// Per-replica pool/policy settings; `base.n_requests` is the total
    /// request count across the fleet.
    pub base: CapacitySpec,
    pub replicas: usize,
    pub routing: FleetRouting,
    pub header_groups: usize,
    pub header_tokens: usize,
}

impl FleetSpec {
    pub fn new(base: CapacitySpec, replicas: usize, routing: FleetRouting) -> FleetSpec {
        FleetSpec {
            base,
            replicas,
            routing,
            header_groups: replicas.max(1),
            header_tokens: 64,
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct FleetReport {
    pub replicas: usize,
    pub completed: usize,
    pub failed: usize,
    pub preemptions: u64,
    /// Fleet-wide sustained batch: the sum of each replica's
    /// `mean_concurrency` over its own active steps.
    pub sustained_batch: f64,
    /// Requests whose header was already resident on their replica.
    pub header_hits: u64,
    /// Cold header materializations — one per distinct (replica, group)
    /// pair the placement produces. Duplication is the routing tax: the
    /// affinity floor is `header_groups`, the blind ceiling is
    /// `replicas * header_groups`.
    pub header_misses: u64,
    pub hit_rate: f64,
    pub per_replica_requests: Vec<usize>,
    pub per_replica_preemptions: Vec<u64>,
    pub per_replica_concurrency: Vec<f64>,
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// Run the fleet model: place `base.n_requests` requests on `replicas`
/// pools per the routing policy, account header residency analytically
/// (first request of a group on a replica pins its header there for the
/// run; later ones fork it), then replay each replica's share through
/// [`run_capacity`]. Headers a replica holds *beyond* its donor pin usable
/// blocks without donating to the majority of admissions — that shrinking
/// of the effective pool is how blind routing's duplication costs
/// sustained batch. Deterministic for a given spec.
pub fn run_fleet(spec: &FleetSpec) -> anyhow::Result<FleetReport> {
    anyhow::ensure!(spec.replicas >= 1, "fleet needs at least one replica");
    anyhow::ensure!(spec.header_groups >= 1, "fleet needs at least one header group");
    let n = spec.base.n_requests;
    let group = |i: usize| i % spec.header_groups;
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); spec.replicas];
    for i in 0..n {
        let r = match spec.routing {
            FleetRouting::Affinity => group(i) % spec.replicas,
            FleetRouting::RoundRobin => i % spec.replicas,
            FleetRouting::Random => {
                (splitmix64(spec.base.seed ^ (i as u64)) % spec.replicas as u64) as usize
            }
            FleetRouting::OneHot => 0,
        };
        assigned[r].push(i);
    }

    let mut rep = FleetReport {
        replicas: spec.replicas,
        ..FleetReport::default()
    };
    // header residency: request order within a replica does not matter —
    // a group's first arrival is the cold miss, every later one the hit
    let mut resident = vec![vec![false; spec.header_groups]; spec.replicas];
    for (r, reqs) in assigned.iter().enumerate() {
        for &i in reqs {
            if resident[r][group(i)] {
                rep.header_hits += 1;
            } else {
                resident[r][group(i)] = true;
                rep.header_misses += 1;
            }
        }
    }
    rep.hit_rate = if n == 0 {
        0.0
    } else {
        rep.header_hits as f64 / n as f64
    };

    // whole blocks a resident header pins (partial tails are paid per-row)
    let header_blocks = spec.header_tokens / spec.base.pool.block_size;
    for (r, reqs) in assigned.iter().enumerate() {
        rep.per_replica_requests.push(reqs.len());
        if reqs.is_empty() {
            rep.per_replica_preemptions.push(0);
            rep.per_replica_concurrency.push(0.0);
            continue;
        }
        let groups_here = resident[r].iter().filter(|&&x| x).count();
        let mut cs = spec.base.clone();
        cs.n_requests = reqs.len();
        cs.seed = spec.base.seed.wrapping_add(r as u64);
        cs.shared_prefix_tokens = spec.header_tokens;
        cs.share_prefix = header_blocks > 0;
        // duplicated resident headers pin blocks the donor does not model
        let extra_pins = (groups_here - 1) * header_blocks;
        anyhow::ensure!(
            spec.base.pool.n_blocks > extra_pins + spec.base.pool.high_watermark + header_blocks,
            "replica {r}: {groups_here} resident headers overwhelm a {}-block pool",
            spec.base.pool.n_blocks
        );
        cs.pool.n_blocks = spec.base.pool.n_blocks - extra_pins;
        let cr = run_capacity(&cs)?;
        rep.completed += cr.completed;
        rep.failed += cr.failed;
        rep.preemptions += cr.preemptions;
        rep.sustained_batch += cr.mean_concurrency;
        rep.per_replica_preemptions.push(cr.preemptions);
        rep.per_replica_concurrency.push(cr.mean_concurrency);
    }
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(policy: &str) -> CapacitySpec {
        let mut s = CapacitySpec::new(policy, 10);
        // small but representative: pool fits ~4 full sequences' worth of
        // lazy-compressed state, or ~1.5 uncompressed ones
        s.pool.n_blocks = 64;
        s
    }

    #[test]
    fn all_requests_complete_and_pool_drains() {
        for policy in ["full", "lazy"] {
            let r = run_capacity(&spec(policy)).unwrap();
            assert_eq!(r.failed, 0, "{policy}: nothing should be unservable");
            assert_eq!(r.completed, 10, "{policy}: all requests complete");
            assert_eq!(
                r.end_free_blocks, r.total_blocks,
                "{policy}: pool must drain leak-free"
            );
            assert!(r.peak_used_blocks <= r.total_blocks);
        }
    }

    #[test]
    fn physical_bytes_scale_with_live_blocks_not_rows() {
        let r = run_capacity(&spec("lazy")).unwrap();
        assert!(r.peak_kv_bytes <= r.arena_kv_bytes);
        assert_eq!(
            r.peak_kv_bytes,
            r.peak_used_blocks * 16 * KvCost::paper_7b().bytes_per_token()
        );
        // 64 blocks x 16 tokens = 1024 pooled tokens vs 16 rows x 118-token
        // dense caches = 1888 worst-case tokens: the arena is strictly
        // smaller than what per-row provisioning would have reserved
        assert!(
            r.arena_kv_bytes < r.dense_kv_bytes,
            "arena {} must undercut dense worst case {}",
            r.arena_kv_bytes,
            r.dense_kv_bytes
        );
    }

    #[test]
    fn lazy_sustains_at_least_full_batch() {
        // The acceptance headline: under the same global budget, lagged
        // eviction (live ≈ B+W) sustains at least the concurrency of
        // FullKV's unbounded growth — in practice several times more.
        let lazy = run_capacity(&spec("lazy")).unwrap();
        let full = run_capacity(&spec("full")).unwrap();
        assert!(
            lazy.mean_concurrency >= full.mean_concurrency,
            "lazy {} < full {}",
            lazy.mean_concurrency,
            full.mean_concurrency
        );
        assert!(
            lazy.peak_used_blocks <= lazy.total_blocks,
            "peak accounting out of range"
        );
    }

    #[test]
    fn shared_prefix_sustains_strictly_more_rows() {
        // The PR acceptance headline: under the same fixed block budget and
        // the same per-request work (a 64-token system header + reasoning
        // tail), serving the header through prefix sharing sustains
        // strictly more concurrent rows than each row paying for it.
        let mut base = spec("lazy");
        base.shared_prefix_tokens = 64;
        base.share_prefix = false;
        let mut shared = base.clone();
        shared.share_prefix = true;
        let b = run_capacity(&base).unwrap();
        let s = run_capacity(&shared).unwrap();
        assert_eq!(b.failed, 0);
        assert_eq!(s.failed, 0);
        assert_eq!(b.completed, 10);
        assert_eq!(s.completed, 10);
        assert_eq!(s.shared_header_blocks, 4); // 64 tokens / 16 per block
        assert_eq!(s.prefix_forks, 10 + s.preemptions);
        assert!(
            s.mean_concurrency > b.mean_concurrency,
            "sharing must strictly beat the private baseline: {} <= {}",
            s.mean_concurrency,
            b.mean_concurrency
        );
        // both leak-free, including the donor pin
        assert_eq!(b.end_free_blocks, b.total_blocks);
        assert_eq!(s.end_free_blocks, s.total_blocks);
        assert!(s.peak_used_blocks <= s.total_blocks);
    }

    #[test]
    fn shared_header_smaller_than_a_block_shares_nothing() {
        let mut s = spec("lazy");
        s.shared_prefix_tokens = 10; // < block_size 16: no whole block
        s.share_prefix = true;
        let r = run_capacity(&s).unwrap();
        assert_eq!(r.shared_header_blocks, 0);
        assert_eq!(r.prefix_forks, 0);
        assert_eq!(r.completed, 10);
        assert_eq!(r.end_free_blocks, r.total_blocks);
    }

    #[test]
    fn recompute_resume_saves_exactly_the_restarted_steps() {
        // The cost model's invariant: every sequence's live curve is walked
        // exactly once under recompute resume, while restart mode re-walks
        // the pre-preemption prefix. So across any schedule,
        //   restart.decode_steps − restart.restarted_steps
        //     == recompute.decode_steps,
        // and the recompute run pays a bounded one-pass prefill cost
        // (`recomputed_tokens`) instead.
        let mut restart = spec("full"); // 64 blocks: full-KV rows collide
        restart.n_requests = 10;
        let mut recompute = restart.clone();
        recompute.recompute_resume = true;
        let a = run_capacity(&restart).unwrap();
        let b = run_capacity(&recompute).unwrap();
        assert_eq!(a.failed, 0);
        assert_eq!(b.failed, 0);
        assert_eq!(a.completed, 10);
        assert_eq!(b.completed, 10);
        assert!(a.preemptions > 0, "full-KV rows in 64 blocks must collide");
        assert!(b.preemptions > 0);
        assert!(a.restarted_steps > 0, "restart mode throws decode work away");
        assert_eq!(b.restarted_steps, 0, "recompute throws nothing away");
        // every mid-decode preemption resumes; a cursor-0 victim (preempted
        // before its first step) re-admits as a fresh fill in either mode
        assert!(b.resumes > 0 && b.resumes <= b.preemptions);
        assert!(b.recomputed_tokens > 0);
        assert_eq!(a.resumes, 0);
        assert_eq!(
            a.decode_steps - a.restarted_steps,
            b.decode_steps,
            "recompute must save exactly the restarted decode steps"
        );
        // both leak-free
        assert_eq!(a.end_free_blocks, a.total_blocks);
        assert_eq!(b.end_free_blocks, b.total_blocks);
    }

    #[test]
    fn swap_resume_is_step_identical_and_charges_bytes_not_tokens() {
        // Swap mode changes only the cost accounting, never the schedule:
        // the run is step-for-step identical to recompute mode, pays zero
        // recomputed tokens, and every parked byte comes back exactly once.
        let mut recompute = spec("full");
        recompute.recompute_resume = true;
        let mut swap = spec("full");
        swap.swap_resume = true;
        let a = run_capacity(&recompute).unwrap();
        let b = run_capacity(&swap).unwrap();
        assert!(a.preemptions > 0 && b.preemptions > 0);
        assert_eq!(a.preemptions, b.preemptions, "schedules must match");
        assert_eq!(a.decode_steps, b.decode_steps, "swap replays nothing");
        assert_eq!(a.completed, b.completed);
        assert_eq!(b.restarted_steps, 0);
        assert_eq!(b.recomputed_tokens, 0, "unlimited tier: no fallback");
        assert_eq!(b.swap_fallbacks, 0);
        assert!(b.swapped_blocks > 0 && b.swap_out_bytes > 0);
        assert_eq!(
            b.swap_in_bytes, b.swap_out_bytes,
            "every parked table must resume exactly once"
        );
        assert!(a.recomputed_tokens > 0, "the recompute run pays in tokens");
        assert_eq!(b.end_free_blocks, b.total_blocks);
    }

    #[test]
    fn tier_budget_overflow_falls_back_to_recompute() {
        // An 8-block tier cannot hold a full-KV table (~20+ blocks), so
        // every swap attempt falls back — and the run still completes,
        // paying the recompute cost instead.
        let mut s = spec("full");
        s.swap_resume = true;
        s.host_tier_blocks = 8;
        let r = run_capacity(&s).unwrap();
        assert_eq!(r.completed, 10);
        assert!(r.preemptions > 0);
        assert!(r.swap_fallbacks > 0, "tiny tier must force fallbacks");
        assert_eq!(r.swapped_blocks, 0, "nothing fits an 8-block tier");
        assert!(r.recomputed_tokens > 0, "fallbacks pay the recompute cost");
        assert_eq!(r.restarted_steps, 0);
        assert_eq!(r.end_free_blocks, r.total_blocks);
    }

    #[test]
    fn client_aborts_reclaim_blocks_and_rest_complete() {
        // every 3rd request disconnects at half its curve: those rows tear
        // down where they stand, everyone else still completes, and the
        // pool drains leak-free — cancellation cannot strand blocks
        let mut s = spec("lazy");
        s.abort_every = 3;
        let r = run_capacity(&s).unwrap();
        assert_eq!(r.failed, 0);
        assert_eq!(r.cancelled, 3, "requests 3, 6, 9 disconnect");
        assert_eq!(r.completed, 7);
        assert!(r.reclaimed_blocks > 0, "aborted rows held real state");
        assert_eq!(r.end_free_blocks, r.total_blocks);
        assert_eq!(r.end_tier_blocks, 0);
        // a no-abort run is unchanged by the knob existing
        let base = run_capacity(&spec("lazy")).unwrap();
        assert_eq!(base.cancelled, 0);
        assert_eq!(base.reclaimed_blocks, 0);
    }

    #[test]
    fn aborts_under_swap_release_parked_tier_state() {
        // full-KV rows in 64 blocks collide constantly; with swap-mode
        // resume the victims park pinned tier state. A client that gives up
        // during the stall must get that state swept — the tier ends the
        // run empty either way, and any swept park shows up as reclaimed
        // tier blocks with the matching swap bytes never copied back.
        let mut s = spec("full");
        s.swap_resume = true;
        s.abort_every = 2;
        let r = run_capacity(&s).unwrap();
        assert_eq!(r.cancelled, 5, "every 2nd of 10 requests disconnects");
        assert_eq!(r.completed + r.failed, 5);
        assert!(r.preemptions > 0, "full-KV rows in 64 blocks must collide");
        assert_eq!(
            r.end_tier_blocks, 0,
            "every parked table must be resumed or reclaimed"
        );
        assert_eq!(r.end_free_blocks, r.total_blocks);
        if r.reclaimed_tier_blocks > 0 {
            assert!(
                r.swap_in_bytes < r.swap_out_bytes,
                "reclaimed parks never swap back in"
            );
        } else {
            assert_eq!(r.swap_in_bytes, r.swap_out_bytes);
        }
    }

    fn fleet(policy: &str, replicas: usize, routing: FleetRouting) -> FleetSpec {
        let mut base = spec(policy);
        base.n_requests = 12;
        let mut f = FleetSpec::new(base, replicas, routing);
        // coprime-ish with the replica count so round-robin (i % N) does
        // not accidentally coincide with affinity (group(i) % N)
        f.header_groups = replicas + 1;
        f.header_tokens = 64;
        f
    }

    #[test]
    fn affinity_beats_blind_routing_on_hit_rate_and_batch() {
        // 3 header groups on 3 replicas: affinity pays exactly 3 cold
        // misses fleet-wide; blind routing re-materializes every header on
        // every replica it touches. The duplication shows up twice — a
        // strictly higher hit rate AND at least as much sustained batch
        // (the rr replicas pin duplicated headers out of their pools).
        let a = run_fleet(&fleet("lazy", 3, FleetRouting::Affinity)).unwrap();
        let rr = run_fleet(&fleet("lazy", 3, FleetRouting::RoundRobin)).unwrap();
        let rand = run_fleet(&fleet("lazy", 3, FleetRouting::Random)).unwrap();
        assert_eq!(a.completed, 12);
        assert_eq!(rr.completed, 12);
        assert_eq!(a.failed + rr.failed, 0);
        assert_eq!(a.header_misses, 4, "affinity floor: one miss per group");
        assert!(
            rr.header_misses > a.header_misses,
            "blind routing must duplicate headers: rr {} vs affinity {}",
            rr.header_misses,
            a.header_misses
        );
        assert!(a.hit_rate > rr.hit_rate, "{} <= {}", a.hit_rate, rr.hit_rate);
        assert!(a.hit_rate > rand.hit_rate, "{} <= {}", a.hit_rate, rand.hit_rate);
        assert!(
            a.sustained_batch >= rr.sustained_batch,
            "affinity batch {} < rr {}",
            a.sustained_batch,
            rr.sustained_batch
        );
    }

    #[test]
    fn sustained_batch_scales_with_replica_count() {
        // Same workload, growing fleet: each added replica brings its own
        // pool, so the fleet-wide sustained batch is monotone in N.
        let n1 = run_fleet(&fleet("lazy", 1, FleetRouting::Affinity)).unwrap();
        let n2 = run_fleet(&fleet("lazy", 2, FleetRouting::Affinity)).unwrap();
        let n4 = run_fleet(&fleet("lazy", 4, FleetRouting::Affinity)).unwrap();
        assert_eq!(n1.completed, 12);
        assert_eq!(n2.completed, 12);
        assert_eq!(n4.completed, 12);
        assert!(
            n2.sustained_batch >= n1.sustained_batch,
            "2 replicas {} < 1 replica {}",
            n2.sustained_batch,
            n1.sustained_batch
        );
        assert!(
            n4.sustained_batch >= n2.sustained_batch,
            "4 replicas {} < 2 replicas {}",
            n4.sustained_batch,
            n2.sustained_batch
        );
    }

    #[test]
    fn one_hot_replica_storms_while_the_rest_idle() {
        // The degenerate assignment a broken router produces: every
        // request on replica 0. Full-KV rows in one 64-block pool collide
        // constantly — the preemption storm concentrates entirely on the
        // hot replica, the other three contribute nothing, and the fleet's
        // sustained batch collapses to a fraction of the spread placement.
        let hot = run_fleet(&fleet("full", 4, FleetRouting::OneHot)).unwrap();
        let spread = run_fleet(&fleet("full", 4, FleetRouting::Affinity)).unwrap();
        assert_eq!(hot.per_replica_requests[0], 12);
        assert!(hot.per_replica_requests[1..].iter().all(|&c| c == 0));
        assert!(hot.preemptions > 0, "full-KV pileup must preempt");
        assert_eq!(
            hot.per_replica_preemptions[0], hot.preemptions,
            "the storm lives entirely on the hot replica"
        );
        assert!(hot.per_replica_concurrency[1..].iter().all(|&c| c == 0.0));
        assert!(
            spread.sustained_batch > hot.sustained_batch,
            "spread {} must beat one-hot {}",
            spread.sustained_batch,
            hot.sustained_batch
        );
        assert!(
            spread.preemptions < hot.preemptions,
            "spreading the load must relieve the storm: {} >= {}",
            spread.preemptions,
            hot.preemptions
        );
    }

    #[test]
    fn fleet_model_is_deterministic() {
        let s = fleet("lazy", 3, FleetRouting::Random);
        let a = run_fleet(&s).unwrap();
        let b = run_fleet(&s).unwrap();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.preemptions, b.preemptions);
        assert_eq!(a.header_hits, b.header_hits);
        assert_eq!(a.header_misses, b.header_misses);
        assert_eq!(a.per_replica_requests, b.per_replica_requests);
        assert!((a.sustained_batch - b.sustained_batch).abs() < 1e-12);
    }

    #[test]
    fn tighter_pool_preempts_or_serializes() {
        // 30 blocks (480 tokens): a single full-cache sequence (~300-570
        // tokens) barely fits; concurrency collapses toward 1 and the run
        // still completes everything that can fit alone
        let mut s = spec("full");
        s.pool.n_blocks = 30;
        let r = run_capacity(&s).unwrap();
        assert_eq!(r.completed + r.failed, 10);
        assert!(r.mean_concurrency <= 3.0, "mean {}", r.mean_concurrency);
        assert_eq!(r.end_free_blocks, r.total_blocks);
    }
}
