//! Task-accuracy model on top of replay results (DESIGN.md §5.3).
//!
//! A sample's success probability is its model ceiling (FullKV accuracy of
//! the (model, dataset) cell) damped per missed need: the paper's Finding 2
//! says premature eviction of recurring tokens causes *catastrophic*
//! degradation, so each missed need retains only `miss_survival` of the
//! success probability. Fidelity loss adds a softer, graded penalty
//! (attention-output error per Eq. 4 degrades reasoning even when no
//! hard need is missed).

use super::replay::ReplayResult;

#[derive(Clone, Copy, Debug)]
pub struct AccuracyModel {
    /// Success retention per missed critical need (hard failure mode).
    pub miss_survival: f64,
    /// Weight of the graded fidelity penalty.
    pub fidelity_weight: f64,
}

impl Default for AccuracyModel {
    fn default() -> Self {
        AccuracyModel {
            miss_survival: 0.25,
            fidelity_weight: 0.35,
        }
    }
}

impl AccuracyModel {
    /// Per-sample success probability in [0, base_acc/100].
    pub fn sample_success(&self, base_acc: f64, r: &ReplayResult) -> f64 {
        let hard = self.miss_survival.powi(r.needs_missed as i32);
        let soft = 1.0 - self.fidelity_weight * (1.0 - r.fidelity());
        (base_acc / 100.0) * hard * soft.clamp(0.0, 1.0)
    }
}

/// Dataset-level accuracy (0–100) over many replayed samples.
pub fn accuracy_over(
    model: &AccuracyModel,
    base_acc: f64,
    results: &[ReplayResult],
) -> f64 {
    if results.is_empty() {
        return f64::NAN;
    }
    let s: f64 = results
        .iter()
        .map(|r| model.sample_success(base_acc, r))
        .sum();
    100.0 * s / results.len() as f64
}

/// Mean fidelity (0–1) over results — reported alongside accuracy.
pub fn mean_fidelity(results: &[ReplayResult]) -> f64 {
    if results.is_empty() {
        return f64::NAN;
    }
    results.iter().map(|r| r.fidelity()).sum::<f64>() / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn res(missed: usize, fid_lost2: f64) -> ReplayResult {
        ReplayResult {
            needs_total: 10,
            needs_missed: missed,
            mass2_total: 1.0,
            mass2_lost: fid_lost2,
            ..Default::default()
        }
    }

    #[test]
    fn no_loss_recovers_base() {
        let m = AccuracyModel::default();
        let acc = accuracy_over(&m, 81.73, &[res(0, 0.0)]);
        assert!((acc - 81.73).abs() < 1e-9);
    }

    #[test]
    fn misses_are_catastrophic() {
        let m = AccuracyModel::default();
        let one = accuracy_over(&m, 80.0, &[res(1, 0.0)]);
        let three = accuracy_over(&m, 80.0, &[res(3, 0.0)]);
        assert!(one < 80.0 * 0.3);
        assert!(three < one * 0.2);
    }

    #[test]
    fn fidelity_penalty_is_graded() {
        let m = AccuracyModel::default();
        let a = accuracy_over(&m, 80.0, &[res(0, 0.04)]); // 20% L2 error
        let b = accuracy_over(&m, 80.0, &[res(0, 0.25)]); // 50% L2 error
        assert!(a > b && b > 50.0);
    }

    #[test]
    fn averaging_over_samples() {
        let m = AccuracyModel::default();
        let acc = accuracy_over(&m, 100.0, &[res(0, 0.0), res(10, 0.0)]);
        assert!(acc < 55.0 && acc > 45.0);
    }
}
