//! Byte-level tokenizer over the restricted charset shared with the Python
//! compile path (manifest.json `charset`; index == token id).

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Tokenizer {
    chars: Vec<char>,
    lookup: HashMap<char, u32>,
}

#[derive(Debug)]
pub enum TokenizerError {
    UnknownChar(char),
    BadId(u32, usize),
}

impl std::fmt::Display for TokenizerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TokenizerError::UnknownChar(c) => {
                write!(f, "character {c:?} is not in the model charset")
            }
            TokenizerError::BadId(id, vocab) => {
                write!(f, "token id {id} out of range (vocab {vocab})")
            }
        }
    }
}

impl std::error::Error for TokenizerError {}

impl Tokenizer {
    pub fn new(charset: &str) -> Tokenizer {
        let chars: Vec<char> = charset.chars().collect();
        let lookup = chars.iter().enumerate().map(|(i, &c)| (c, i as u32)).collect();
        Tokenizer { chars, lookup }
    }

    pub fn vocab(&self) -> usize {
        self.chars.len()
    }

    pub fn encode(&self, text: &str) -> Result<Vec<u32>, TokenizerError> {
        text.chars()
            .map(|c| self.lookup.get(&c).copied().ok_or(TokenizerError::UnknownChar(c)))
            .collect()
    }

    /// Encode, replacing unknown characters with space (lossy ingestion path).
    pub fn encode_lossy(&self, text: &str) -> Vec<u32> {
        let space = self.lookup.get(&' ').copied().unwrap_or(0);
        text.chars()
            .map(|c| self.lookup.get(&c).copied().unwrap_or(space))
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> Result<String, TokenizerError> {
        ids.iter()
            .map(|&i| {
                self.chars
                    .get(i as usize)
                    .copied()
                    .ok_or(TokenizerError::BadId(i, self.chars.len()))
            })
            .collect()
    }

    pub fn id(&self, c: char) -> Option<u32> {
        self.lookup.get(&c).copied()
    }

    pub fn char_of(&self, id: u32) -> Option<char> {
        self.chars.get(id as usize).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const CS: &str = "0123456789+-*=();ABCDEFGHIJKLMNOPQRSTUVWXYZ?.,# >\n";

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new(CS);
        let s = "#A=3;B=7;\n>A+B=0;\n";
        let ids = t.encode(s).unwrap();
        assert_eq!(t.decode(&ids).unwrap(), s);
    }

    #[test]
    fn ids_are_charset_indices() {
        let t = Tokenizer::new(CS);
        assert_eq!(t.encode("0").unwrap(), vec![0]);
        assert_eq!(t.encode("9").unwrap(), vec![9]);
        assert_eq!(t.id('+'), Some(10));
    }

    #[test]
    fn unknown_char_errors() {
        let t = Tokenizer::new(CS);
        assert!(t.encode("abc").is_err());
        assert_eq!(t.encode_lossy("a").len(), 1);
    }

    #[test]
    fn bad_id_errors() {
        let t = Tokenizer::new(CS);
        assert!(t.decode(&[10_000]).is_err());
    }

    #[test]
    fn vocab_size() {
        assert_eq!(Tokenizer::new(CS).vocab(), CS.chars().count());
    }
}
